"""A miniature solver server on the prepared-session API.

Simulates the many-concurrent-small-solves serving workload the ROADMAP
names: requests (Poisson right-hand sides) arrive in bursts, a single
prepared ``Solver`` owns the compiled sweeps, and a ``SolverPool``
micro-batches each burst into one padded batched sweep -- the engines'
per-RHS convergence masking means one compilation per pad bucket serves
every queue depth.  The three rungs of the serving ladder are timed
against each other on the same request stream:

  1. one-shot    -- ``solve(A, b)`` per request (full per-call setup);
  2. prepared    -- ``solver(b)`` per request (setup amortized to zero);
  3. pooled      -- ``pool.submit(b)`` + one flush per burst
                    (setup amortized AND reductions shared across the
                    whole burst, the arXiv:1905.06850 regime).

  PYTHONPATH=src python examples/solver_server.py
  PYTHONPATH=src python examples/solver_server.py --nx 64 --bursts 4 \\
      --burst-size 8 --max-batch 8

Note on reading the numbers: on CPU the lanes of a batched sweep run
sequentially, so pooling wins only while per-iteration dispatch overhead
dominates (small grids, full buckets); partially-filled pad lanes are
pure overhead.  On an accelerator the batched lanes share the hardware
and every per-iteration reduction is fused across the burst, which is
the regime the pool is built for (arXiv:1905.06850).
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--nx", type=int, default=16)
    ap.add_argument("--l", type=int, default=2)
    ap.add_argument("--tol", type=float, default=1e-4)
    ap.add_argument("--maxiter", type=int, default=400)
    ap.add_argument("--bursts", type=int, default=4)
    ap.add_argument("--burst-size", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.core import Solver, SolverPool, solve
    from repro.operators import poisson2d

    A = poisson2d(args.nx, args.nx)
    rng = np.random.default_rng(args.seed)
    bursts = [[np.asarray(A @ rng.standard_normal(A.n))
               for _ in range(args.burst_size)]
              for _ in range(args.bursts)]
    nreq = args.bursts * args.burst_size
    kw = dict(l=args.l, tol=args.tol, maxiter=args.maxiter,
              spectrum=(0.0, 8.0))

    # rung 1: one-shot front-end per request
    for b in bursts[0]:
        solve(A, b, method="plcg_scan", **kw)        # warm the caches
    t0 = time.perf_counter()
    for burst in bursts:
        for b in burst:
            solve(A, b, method="plcg_scan", **kw)
    t_oneshot = time.perf_counter() - t0

    # rung 2: prepared session, still one call per request
    t0 = time.perf_counter()
    solver = Solver(A, "plcg_scan", **kw)
    t_setup = time.perf_counter() - t0
    solver(bursts[0][0])                             # warm the compile
    t0 = time.perf_counter()
    for burst in bursts:
        for b in burst:
            solver(b)
    t_prepared = time.perf_counter() - t0

    # rung 3: pooled micro-batching, one flush per burst
    pool = SolverPool(solver, max_batch=args.max_batch)
    for b in bursts[0]:
        pool.submit(b)
    pool.flush()                                     # warm the batch shape
    lat = []
    t0 = time.perf_counter()
    for burst in bursts:
        t_burst = time.perf_counter()
        handles = [pool.submit(b) for b in burst]
        pool.flush()
        results = [h.result() for h in handles]
        lat.append((time.perf_counter() - t_burst) / len(burst))
    t_pooled = time.perf_counter() - t0
    assert all(r.converged for r in results)

    worst = max(np.linalg.norm(b - np.asarray(A @ np.asarray(r.x)))
                for b, r in zip(bursts[-1], results))
    print(f"{nreq} requests of {args.nx}x{args.nx} Poisson "
          f"(l={args.l}, tol={args.tol:g}), bursts of {args.burst_size}:")
    print(f"  one-shot : {t_oneshot / nreq * 1e3:8.2f} ms/req "
          "(per-call validate+normalize+cache-lookup)")
    print(f"  prepared : {t_prepared / nreq * 1e3:8.2f} ms/req "
          f"(setup {t_setup * 1e3:.2f} ms, paid once; "
          f"{t_oneshot / max(t_prepared, 1e-9):.2f}x)")
    print(f"  pooled   : {t_pooled / nreq * 1e3:8.2f} ms/req "
          f"({t_oneshot / max(t_pooled, 1e-9):.2f}x; "
          f"mean in-burst latency {np.mean(lat) * 1e3:.2f} ms/req)")
    print(f"  pool: batches={pool.stats['batches']} "
          f"occupancy={pool.occupancy:.3f} "
          f"prepared_sweeps={solver.prepared_sweeps} "
          f"worst |b-Ax|={worst:.2e}")
    return pool.stats


if __name__ == "__main__":
    main()
