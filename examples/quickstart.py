"""Quickstart: solve a 2D Poisson system with deep-pipelined CG (p(l)-CG).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.cg import classic_cg
from repro.core.plcg import plcg
from repro.operators import poisson2d

# the paper's model problem: unscaled 5-point stencil, spectrum in (0, 8)
A = poisson2d(100, 100)
x_true = np.ones(A.n)
b = A @ x_true

print("method      iters  converged   |b - A x|")
ref = classic_cg(A, b, tol=1e-8, maxiter=1000)
print(f"CG         {ref.iters:6d}  {ref.converged!s:9}  "
      f"{np.linalg.norm(b - A @ ref.x):.3e}")

for l in (1, 2, 3):
    r = plcg(A, b, l=l, tol=1e-8, maxiter=1000, spectrum=(0.0, 8.0))
    print(f"p({l})-CG    {r.iters:6d}  {r.converged!s:9}  "
          f"{np.linalg.norm(b - A @ r.x):.3e}   "
          f"(breakdowns: {r.breakdowns})")

print("\nIn exact arithmetic all rows produce identical iterates; the "
      "pipelined variants\nhide the global reduction of iteration i behind "
      "the next l SPMVs (paper Alg. 3).")
