"""Quickstart: solve a 2D Poisson system through the unified front-end.

Every Krylov method in the library dispatches through one call:

    repro.core.solve(A, b, method=..., l=..., M=...)

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import describe_methods, solve
from repro.operators import poisson2d

# the paper's model problem: unscaled 5-point stencil, spectrum in (0, 8)
A = poisson2d(100, 100)
x_true = np.ones(A.n)
b = A @ x_true

print("method        l   iters  converged   |b - A x|")
# the numpy reference methods run in fp64; the jitted scan engine runs in
# jax's default fp32, so it gets an fp32-appropriate tolerance
for method, l, tol in [("cg", 1, 1e-8), ("pcg", 1, 1e-8),
                       ("dlanczos", 1, 1e-8), ("plcg", 1, 1e-8),
                       ("plcg", 2, 1e-8), ("plcg", 3, 1e-8),
                       ("plcg_scan", 2, 1e-5)]:
    r = solve(A, b, method=method, l=l, tol=tol, maxiter=1000,
              spectrum=(0.0, 8.0))
    res = np.linalg.norm(b - A @ np.asarray(r.x))
    print(f"{method:12s} {l:2d}  {r.iters:6d}  {r.converged!s:9}  "
          f"{res:.3e}   (breakdowns: {r.breakdowns})")

# batched multi-RHS: one jitted vmap(lax.scan) over all right-hand sides;
# converged lanes freeze (per-lane select) while the others keep iterating
rng = np.random.default_rng(0)
B = np.stack([np.asarray(A @ rng.standard_normal(A.n)) for _ in range(4)])
rb = solve(A, B, method="plcg_scan", l=2, tol=1e-5, maxiter=1000,
           spectrum=(0.0, 8.0))
print(f"\nbatched p(2)-CG over {B.shape[0]} right-hand sides: "
      f"per-RHS iters = {[int(k) for k in rb.info['per_rhs_iters']]}, "
      f"per-RHS converged = {[bool(c) for c in rb.info['per_rhs_converged']]}")

print("\nRegistered methods:")
for name, desc in describe_methods().items():
    print(f"  {name:10s} {desc}")

print("\nIn exact arithmetic all rows produce identical iterates; the "
      "pipelined variants\nhide the global reduction of iteration i behind "
      "the next l SPMVs (paper Alg. 3).")
