"""Strong-scaling model (paper Figs. 3/4): speedup of p(l)-CG over classic
CG as a function of node count, from the Table-1 time model
    t_CG  = 2 t_glred + t_spmv        t_p(l)  = max(t_glred / l, t_spmv)
with a measured local SPMV throughput and a log-tree reduction latency.

    PYTHONPATH=src python examples/scaling_model.py
"""
import time

import numpy as np

from repro.operators import poisson2d

A = poisson2d(256, 256)
x = np.ones(A.n)
A @ x
t0 = time.perf_counter()
for _ in range(20):
    A @ x
t_spmv_meas = (time.perf_counter() - t0) / 20

alpha = 5e-6                    # per-hop reduction latency (s)
n_grid = 1000 * 1000            # paper test setup 1

print(f"{'nodes':>6} | {'CG':>8} | " + " | ".join(f"p({l})-CG" for l in (1, 2, 3)))
for nodes in (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024):
    t_spmv = t_spmv_meas * (n_grid / A.n) / nodes
    t_glred = alpha * np.log2(max(nodes, 2))
    t_cg = 2 * t_glred + t_spmv
    row = [f"{1e6*t_cg:7.1f}u"]
    for l in (1, 2, 3):
        t_pl = max(t_glred / l, t_spmv)
        row.append(f"{t_cg/t_pl:7.2f}x")
    print(f"{nodes:>6} | " + " | ".join(row))
print("\nDeeper pipelines keep scaling after p(1) saturates -- the paper's "
      "headline result.\nTheoretical ceiling: (2l+1)x when t_glred = l*t_spmv.")
