"""Reproduce the paper's attainable-accuracy experiments (Figs. 1/6/9).

Runs classic CG, Ghysels p-CG and p(l)-CG for l = 1,2,3 -- all through the
unified ``repro.core.solve`` front-end -- on the 200x200 Poisson problem
and reports where each variant's true residual stagnates, plus the
rounding-error diagnostics of Sec. 4 (basis/residual gaps).
"""
import numpy as np

from repro.core import solve
from repro.operators import poisson2d

n = 200
A = poisson2d(n, n)
b = A @ (np.ones(A.n) / np.sqrt(A.n))
iters = 400

rows = []
r = solve(A, b, method="cg", tol=0.0, maxiter=iters,
          trace_true_residual=True)
rows.append(("CG", min(r.true_resnorms)))
r = solve(A, b, method="pcg", tol=0.0, maxiter=iters,
          trace_true_residual=True)
rows.append(("p-CG (Ghysels)", min(r.true_resnorms)))
for l in (1, 2, 3):
    r = solve(A, b, method="plcg", l=l, tol=0.0, maxiter=iters,
              spectrum=(0.0, 8.0), trace_gaps=True, max_restarts=0)
    tr = r.true_resnorms or [float("nan")]
    gaps = r.info["traces"][0].residual_gap_norms if r.info.get("traces") else []
    rows.append((f"p({l})-CG", min(tr)))
    if gaps:
        print(f"p({l})-CG final residual gap ||(b-Ax)-zeta v||: {gaps[-1]:.3e}")

print("\nmaximal attainable accuracy (min true residual over "
      f"{iters} iterations):")
for name, acc in rows:
    print(f"  {name:16s} {acc:.3e}")
print("\nDeeper pipelines trade attainable accuracy for scalability "
      "(paper Sec. 4/5).")
