"""Train a small LM with Newton-pCG: the paper's deep-pipelined CG as the
inner solver of a second-order optimizer (HVP = the overlapped 'SPMV').

    PYTHONPATH=src python examples/newton_cg_training.py
"""
import jax

from repro.configs import get_reduced
from repro.models import init_params, loss_fn
from repro.training import NewtonPCGConfig, newton_pcg_step
from repro.training.data import synth_batch

cfg = get_reduced("qwen3-14b")
params = init_params(cfg, jax.random.PRNGKey(0))
ncfg = NewtonPCGConfig(l=2, cg_iters=8, lr=0.5)
lf = lambda p, b: loss_fn(cfg, p, b)  # noqa: E731
step = jax.jit(lambda p, b: newton_pcg_step(lf, p, b, ncfg))

for i in range(5):
    batch = synth_batch(cfg, i, batch=4, seq=64)
    params, stats = step(params, batch)
    print(f"step {i}: loss {float(stats['loss']):.4f} "
          f"|g| {float(stats['grad_norm']):.3f} "
          f"cg_breakdown={bool(stats['cg_breakdown'])}")
