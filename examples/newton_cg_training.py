"""Train a small LM with Newton-pCG: the paper's deep-pipelined CG as the
inner solver of a second-order optimizer (HVP = the overlapped 'SPMV').

    PYTHONPATH=src python examples/newton_cg_training.py
    PYTHONPATH=src python examples/newton_cg_training.py --l auto
    XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \
        python examples/newton_cg_training.py --mesh 2x2 --comm overlap

The prepared NewtonPCGTrainer compiles its sweeps at step 1 and rebinds
fresh (params, batch) into them afterwards -- watch the reported compile
counts stay at 1 while the loss falls.
"""
import argparse

import jax
import numpy as np

from repro.configs import get_reduced
from repro.models import init_params, loss_fn
from repro.training import NewtonPCGConfig, NewtonPCGTrainer
from repro.training.data import synth_batch


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--l", default="2",
                    help="pipeline depth: an int, or 'auto' to calibrate "
                         "against the measured HVP latency")
    ap.add_argument("--cg-iters", type=int, default=8)
    ap.add_argument("--lr", type=float, default=0.5)
    ap.add_argument("--mesh", default=None, metavar="RxC",
                    help="force a (data, model) mesh, e.g. 2x2 (needs "
                         "enough devices: XLA_FLAGS="
                         "--xla_force_host_platform_device_count=4)")
    ap.add_argument("--comm", default=None,
                    choices=["blocking", "overlap", "ring", "auto"],
                    help="reduction policy of the inner solve on a mesh")
    ap.add_argument("--precision", default=None, choices=["bf16"],
                    help="inner-solve window storage precision")
    args = ap.parse_args(argv)

    mesh = None
    if args.mesh:
        r, c = (int(x) for x in args.mesh.lower().split("x"))
        if len(jax.devices()) < r * c:
            raise SystemExit(
                f"--mesh {args.mesh} needs {r * c} devices, have "
                f"{len(jax.devices())} (set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={r * c})")
        mesh = jax.sharding.Mesh(
            np.array(jax.devices()[:r * c]).reshape(r, c),
            ("data", "model"))

    cfg = get_reduced(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    depth = args.l if args.l == "auto" else int(args.l)
    ncfg = NewtonPCGConfig(l=depth, cg_iters=args.cg_iters, lr=args.lr)
    lf = lambda p, b: loss_fn(cfg, p, b)  # noqa: E731
    trainer = NewtonPCGTrainer(lf, ncfg, mesh=mesh, comm=args.comm,
                               precision=args.precision)

    for i in range(args.steps):
        batch = synth_batch(cfg, i, batch=4, seq=64)
        params, stats = trainer.step(params, batch)
        compiles = max(trainer.compile_counts().values(), default=0)
        line = (f"step {i}: loss {float(stats['loss']):.4f} "
                f"|g| {float(stats['grad_norm']):.3f} "
                f"cg_iters={stats['cg_iters']} "
                f"converged={stats['cg_converged']} compiles={compiles}")
        if i == 0 and stats.get("auto"):
            line += (f"  [auto: l={stats['auto']['l']} "
                     f"comm={stats['auto']['comm']}]")
        print(line, flush=True)
    return params


if __name__ == "__main__":
    main()
