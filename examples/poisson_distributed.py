"""Distributed p(l)-CG through the unified front-end: pass ``mesh=`` to
``repro.core.solve`` and the same registry method runs shard_map domain
decomposition inside (ppermute halos + ONE fused psum per iteration) with
vmap RHS batching outside.

Run with several host devices to see real sharding:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/poisson_distributed.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BlockJacobi, residual_gap, solve
from repro.launch.mesh import make_solver_mesh_for
from repro.operators import poisson2d

nx = ny = 80
ndev = len(jax.devices())
mesh = make_solver_mesh_for(ndev, ny, nx=nx)
print(f"mesh: {dict(mesh.shape)}")

A = poisson2d(nx, ny)
b = jnp.asarray((A @ np.ones(nx * ny)).reshape(nx, ny))

r = solve(A, b, method="plcg", l=2, tol=1e-8, maxiter=1000,
          spectrum=(0.0, 8.0), mesh=mesh)
res = np.linalg.norm((A @ np.ones(nx * ny))
                     - A @ np.asarray(r.x).reshape(-1))
print(f"p(2)-CG (1 fused psum/iter): {r.iters} iters, |b-Ax| = {res:.3e}, "
      f"restarts={r.restarts}")

rc = solve(A, b, method="cg", tol=1e-8, maxiter=1000, mesh=mesh)
res = np.linalg.norm((A @ np.ones(nx * ny))
                     - A @ np.asarray(rc.x).reshape(-1))
print(f"classic CG (2 sync psums/iter): {rc.iters} iters, "
      f"|b-Ax| = {res:.3e}")

# shard-local preconditioning: BlockJacobi's block grid IS the mesh's
# processor grid, so the apply is communication-free and the iteration
# STILL carries exactly one psum -- the paper's Fig. 5 setup with the
# ILU block solve replaced by a TPU-friendly Chebyshev polynomial
M = BlockJacobi.for_mesh(A, mesh)
rp = solve(A, b, method="plcg", l=2, tol=1e-8, maxiter=1000, mesh=mesh,
           M=M)
gap = residual_gap(A, np.asarray(b), rp)
print(f"p(2)-CG + {M.name}: {rp.iters} iters (vs {r.iters} "
      f"unpreconditioned), psums/iter={rp.info['psums_per_iter']}, "
      f"residual gap={gap['rel_gap']:.1e}")

# batched multi-RHS: vmap over lanes OUTSIDE the domain decomposition --
# all lanes' (2l+1)-scalar payloads ride one stacked (nrhs, 2l+1) psum
rng = np.random.default_rng(0)
B = jnp.asarray(np.stack(
    [np.asarray(A @ rng.standard_normal(A.n)).reshape(nx, ny)
     for _ in range(4)]))
rb = solve(A, B, method="plcg_scan", l=2, tol=1e-6, maxiter=1000,
           spectrum=(0.0, 8.0), mesh=mesh)
print(f"batched 4-RHS: per-lane iters "
      f"{[int(k) for k in rb.info['per_rhs_iters']]}, converged "
      f"{[bool(c) for c in rb.info['per_rhs_converged']]}")
