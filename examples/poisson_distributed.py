"""Distributed p(l)-CG on a 2-D device mesh (shard_map + ppermute halos +
one fused psum per iteration).

Run with several host devices to see real sharding:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/poisson_distributed.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.shifts import chebyshev_shifts
from repro.distributed import DistPoisson, dist_cg, dist_plcg_solve
from repro.launch.mesh import make_mesh_for

ndev = len(jax.devices())
mp = 2 if ndev % 2 == 0 and ndev > 1 else 1
mesh = make_mesh_for(ndev, model_parallel=mp)
print(f"mesh: {dict(mesh.shape)}")

nx = ny = 80
op = DistPoisson(nx, ny, mesh)
from repro.operators import poisson2d
A = poisson2d(nx, ny)
b = jnp.asarray((A @ np.ones(nx * ny)).reshape(nx, ny))

x, resn, info = dist_plcg_solve(op, b, l=2, sigma=chebyshev_shifts(0, 8, 2),
                                tol=1e-8, maxiter=1000)
res = np.linalg.norm((A @ np.ones(nx * ny)) - A @ np.asarray(x).reshape(-1))
print(f"p(2)-CG: {len(resn)} iters, |b-Ax| = {res:.3e}, {info}")

xc, resn_c, conv = dist_cg(op, b, iters=1000, tol=1e-8)
res = np.linalg.norm((A @ np.ones(nx * ny)) - A @ np.asarray(xc).reshape(-1))
print(f"classic CG (2 sync reductions/iter): |b-Ax| = {res:.3e}")
