"""Autotuned depth & policy: let the session pick l and comm= for you.

``l="auto"`` / ``comm="auto"`` calibrate on the actual target at session
construction -- one local SPMV, one stacked global reduction per comm=
mode, a short probe sweep per candidate depth -- then solve the paper's
latency model ``max(glred/l, spmv)`` for the fastest admissible pick,
clamped so the storage-precision floor ``~ eps * (2l+1)`` never misses
the requested tol (repro.core.autotune).  The decision and its evidence
come back in ``SolveResult.info["auto"]``.

    PYTHONPATH=src python examples/autotune_decision.py
    # with a forced multi-device host, the same script calibrates the
    # mesh reduction modes (psum vs scatter/gather vs ppermute ring):
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/autotune_decision.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import Solver, override_latencies
from repro.operators import poisson2d

A = poisson2d(64, 64)
b = np.asarray(A @ np.ones(A.n))
kw = dict(method="plcg_scan", tol=1e-6, maxiter=400)

ndev = len(jax.devices())
if ndev > 1:
    from repro.launch.mesh import make_solver_mesh_for
    mesh = make_solver_mesh_for(ndev, 64, nx=64)
    kw["mesh"] = mesh
    b = b.reshape(64, 64)
    print(f"calibrating on a live {dict(mesh.shape)} mesh "
          f"({ndev} devices)")
else:
    print("calibrating on 1 device (reductions are local; force 8 host "
          "devices via XLA_FLAGS to see the comm= modes measured)")

# measured calibration happens ONCE, at construction; same-config
# sessions reuse the cached table
s = Solver(A, l="auto", comm="auto", **kw)
r = s.solve(b)

info = r.info["auto"]
lat = info["latencies"]
print(f"\nchosen: l={info['l']} comm={info['comm']} "
      f"(depth budget {info['budget']}, source {info['source']})")
print(f"model score: {info['score_us']:.0f} us/iter = "
      "max(glred/l, local)")
print(f"measured spmv: {lat['spmv_us']:.0f} us")
for mode, us in sorted(lat["glred_us"].items()):
    print(f"measured glred[{mode}]: {us:.0f} us")
print(f"solve: {r.iters} iters, converged={r.converged}, "
      f"|b-Ax| = {np.linalg.norm(b.reshape(-1) - A @ np.asarray(r.x).reshape(-1)):.3e}")

# tests (and curious users) can pin the decision with a fake table: the
# injection hook bypasses measurement AND the cache -- with a 300 us
# reduction against a 100 us SPMV the model breaks even at l=3
with override_latencies({"spmv_us": 100.0,
                         "glred_us": {"blocking": 300.0}}):
    s3 = Solver(A, l="auto", **kw)
print(f"\ninjected table (glred=300us, spmv=100us) -> l={s3.l} "
      f"(source {s3.auto.source}): the depth the paper's model predicts")
