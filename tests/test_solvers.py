"""Solver correctness: the paper's exact-arithmetic claims, numerically.

All in numpy fp64 (the reference implementations are array-library
agnostic); p(l)-CG must reproduce classic CG / D-Lanczos iterates, the
implicit residual must equal the true residual to rounding, preconditioning
must preserve all of it in the M-norm, and p(l)-GMRES must exhibit the
structure (tridiagonal H, banded G) the derivation exploits.
"""
import numpy as np
import pytest

from repro.core.cg import classic_cg
from repro.core.dlanczos import d_lanczos
from repro.core.pcg import ghysels_pcg
from repro.core.plcg import plcg
from repro.core.plgmres import plgmres
from repro.core.shifts import chebyshev_shifts, monomial_shifts
from repro.operators import (poisson2d, poisson2d_dense, poisson3d,
                             random_spd_dense)
from repro.operators.precond import block_jacobi_for, jacobi


@pytest.fixture(scope="module")
def poisson():
    A = poisson2d(24, 24)
    b = A @ np.ones(A.n)
    return A, b


def test_cg_dlanczos_equivalent(poisson):
    A, b = poisson
    r1 = classic_cg(A, b, tol=1e-11, maxiter=500)
    r2 = d_lanczos(A, b, tol=1e-11, maxiter=500)
    assert r1.converged and r2.converged
    assert np.allclose(r1.x, r2.x, atol=1e-8)
    m = min(len(r1.resnorms), len(r2.resnorms))
    assert np.allclose(r1.resnorms[:m], r2.resnorms[:m], rtol=1e-6)


def test_ghysels_pcg_matches_cg(poisson):
    A, b = poisson
    r1 = classic_cg(A, b, tol=1e-11, maxiter=500)
    r2 = ghysels_pcg(A, b, tol=1e-11, maxiter=500)
    assert r2.converged and abs(r1.iters - r2.iters) <= 1
    assert np.allclose(r1.x, r2.x, atol=1e-7)


@pytest.mark.parametrize("l", [1, 2, 3, 5])
def test_plcg_matches_cg_iterates(poisson, l):
    """Paper Sec. 2.2 / Fig. 1: identical convergence in exact arithmetic."""
    A, b = poisson
    ref = classic_cg(A, b, tol=1e-11, maxiter=500)
    r = plcg(A, b, l=l, tol=1e-11, maxiter=500, spectrum=(0, 8))
    assert r.converged
    # rounding amplification grows with l (Sec. 4); compare the pre-
    # stagnation segment with a depth-dependent tolerance
    m = min(len(ref.resnorms), len(r.resnorms), int(ref.iters * 0.7))
    assert np.allclose(r.resnorms[:m], ref.resnorms[:m], rtol=1e-4 * l * l)
    assert np.linalg.norm(b - A @ r.x) <= 20 * np.linalg.norm(b - A @ ref.x)


@pytest.mark.parametrize("l", [1, 3])
def test_plcg_implicit_residual_is_true_residual(poisson, l):
    """Theorem 9: |zeta_k| == ||b - A x_k|| up to rounding."""
    A, b = poisson
    r = plcg(A, b, l=l, tol=1e-8, maxiter=300, spectrum=(0, 8),
             trace_gaps=True)
    imp = np.array(r.info["traces"][0].implicit_resnorms)
    true = np.array(r.info["traces"][0].true_resnorms)
    m = min(len(imp), len(true))
    mask = true[:m] > 1e-10        # before stagnation rounding dominates
    assert np.allclose(imp[:m][mask], true[:m][mask], rtol=1e-4)


def test_plcg_symmetry_exploit_consistent(poisson):
    A, b = poisson
    r1 = plcg(A, b, l=3, tol=1e-10, maxiter=200, spectrum=(0, 8),
              exploit_symmetry=True)
    r2 = plcg(A, b, l=3, tol=1e-10, maxiter=200, spectrum=(0, 8),
              exploit_symmetry=False)
    m = min(len(r1.resnorms), len(r2.resnorms)) - 2
    assert np.allclose(r1.resnorms[:m], r2.resnorms[:m], rtol=1e-6)


def test_plcg_preconditioned(poisson):
    A, b = poisson
    dense = poisson2d_dense(24, 24)
    M = block_jacobi_for(A, dense, nblocks=4)
    ref = classic_cg(A, b, tol=1e-10, maxiter=500, M=M)
    for l in (1, 2):
        r = plcg(A, b, l=l, tol=1e-10, maxiter=500, M=M, spectrum=(0, 2))
        assert r.converged
        assert np.linalg.norm(b - A @ r.x) < 1e-7
    assert ref.converged


def test_plcg_breakdown_restart():
    """Ill-conditioned + deliberately bad (monomial) shifts must break down
    and restart (paper Remark 8 / Fig. 1 right)."""
    A = random_spd_dense(120, cond=1e8, spectrum="geometric", seed=3)
    b = A @ np.ones(120)
    r = plcg(A, b, l=3, tol=1e-9, maxiter=600, sigma=monomial_shifts(3),
             max_restarts=3)
    assert r.breakdowns >= 1          # monomial basis must collapse


def test_plcg_accuracy_degrades_with_depth():
    """Paper Sec. 4 / Table 2: attainable accuracy decreases with l."""
    A = poisson2d(40, 40)
    b = A @ (np.ones(A.n) / 40.0)
    accs = {}
    for l in (1, 3):
        r = plcg(A, b, l=l, tol=0.0, maxiter=250, spectrum=(0, 8),
                 trace_gaps=True, max_restarts=0)
        tr = r.true_resnorms
        accs[l] = min(tr) if tr else np.inf
    assert accs[3] >= accs[1] * 0.5   # deeper pipeline never (much) better


def test_poisson3d_solve():
    A = poisson3d(8, 8, 8)
    b = A @ np.ones(A.n)
    r = plcg(A, b, l=2, tol=1e-10, maxiter=200, spectrum=(0, 12))
    assert r.converged


def test_jacobi_preconditioner(poisson):
    A, b = poisson
    M = jacobi(A)
    r = classic_cg(A, b, tol=1e-10, maxiter=500, M=M)
    assert r.converged


# ----------------------------- p(l)-GMRES ---------------------------------

@pytest.mark.parametrize("l", [1, 2, 3])
def test_plgmres_structure(l):
    A = poisson2d(12, 12)
    b = A @ np.ones(A.n)
    r = plgmres(A, b, l=l, m=12, spectrum=(0, 8))
    H, V = r.info["H"], r.info["V"]
    k = H.shape[1]
    # symmetric A => tridiagonal Hessenberg (Corollary 4)
    assert np.max(np.abs(np.triu(H[:-1], 2))) < 1e-8
    # orthonormal Krylov basis
    Vk = V[: k + 1]
    assert np.max(np.abs(Vk @ Vk.T - np.eye(k + 1))) < 1e-5
    # Arnoldi relation A V_k = V_{k+1} H
    AV = np.stack([A @ V[j] for j in range(k)])
    assert np.max(np.abs(AV - H[: k + 1, :k].T @ Vk)) < 1e-8
    # banded G (Lemma 5): zero below the 2l+1 band
    G = r.info["G"]
    for i in range(G.shape[1]):
        assert np.max(np.abs(G[: max(0, i - 2 * l), i]), initial=0.0) < 1e-8


def test_plgmres_fom_equals_cg():
    """Remark 6: p(l)-FOM == CG iterates for SPD systems."""
    A = poisson2d(12, 12)
    b = A @ np.ones(A.n)
    rf = plgmres(A, b, l=2, m=12, spectrum=(0, 8), mode="fom")
    rc = classic_cg(A, b, tol=0.0, maxiter=12)
    assert np.linalg.norm(rf.x - rc.x) < 1e-8


def test_chebyshev_shifts_minimize_poly_norm():
    """Chebyshev shifts beat monomial shifts on ||P_l(A)|| (Lemma 15)."""
    A = poisson2d_dense(12, 12)
    for l in (2, 3):
        cheb = chebyshev_shifts(0, 8, l)
        Pc = np.eye(A.shape[0])
        Pm = np.eye(A.shape[0])
        for i in range(l):
            Pc = (A - cheb[i] * np.eye(A.shape[0])) @ Pc
            Pm = A @ Pm
        assert np.linalg.norm(Pc, 2) < np.linalg.norm(Pm, 2)


def test_plminres_indefinite():
    """Remark 6: pipelined MINRES solves symmetric indefinite systems."""
    from repro.core.linop import dense_operator
    from repro.core.plminres import plminres
    rng = np.random.default_rng(1)
    n = 80
    Q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    eigs = np.concatenate([-np.linspace(0.5, 1.0, n // 4),
                           np.linspace(0.2, 1.0, n - n // 4)])
    A = dense_operator((Q * eigs) @ Q.T)
    b = A @ np.ones(n)
    r = plminres(A, b, l=2, m=n, spectrum=(float(eigs.min()),
                                           float(eigs.max())))
    assert np.linalg.norm(b - A @ r.x) < 1e-6 * np.linalg.norm(b)


def test_plminres_residual_optimality():
    """MINRES residual never exceeds the CG residual on SPD systems."""
    from repro.core.plminres import plminres
    A = poisson2d(12, 12)
    b = A @ np.ones(A.n)
    for m in (5, 10, 15):
        rm = plminres(A, b, l=1, m=m, spectrum=(0, 8))
        rc = classic_cg(A, b, tol=0.0, maxiter=m)
        assert (np.linalg.norm(b - A @ rm.x)
                <= np.linalg.norm(b - A @ rc.x) * (1 + 1e-8))
