"""Per-kernel allclose vs the pure-jnp oracles (interpret mode executes the
TPU kernel bodies exactly), swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.multidot import multidot
from repro.kernels.stencil2d import stencil2d
from repro.kernels.window_axpy import window_axpy

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("shape", [(32, 128), (64, 128), (128, 256), (40, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bh", [8, 16])
def test_stencil2d(shape, dtype, bh):
    H, W = shape
    x = jax.random.normal(KEY, (H, W), jnp.float32).astype(dtype)
    hn = jax.random.normal(jax.random.PRNGKey(1), (W,), jnp.float32).astype(dtype)
    hs = jax.random.normal(jax.random.PRNGKey(2), (W,), jnp.float32).astype(dtype)
    hw = jax.random.normal(jax.random.PRNGKey(3), (H,), jnp.float32).astype(dtype)
    he = jax.random.normal(jax.random.PRNGKey(4), (H,), jnp.float32).astype(dtype)
    out = stencil2d(x, hn, hs, hw, he, bh=bh, interpret=True)
    want = ref.stencil2d_ref(x, hn, hs, hw, he)
    tol = 1e-5 if dtype == jnp.float32 else 8e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


def test_stencil2d_matches_poisson_operator():
    """With zero halos the kernel IS the paper's Poisson operator."""
    from repro.operators import poisson2d
    H = W = 128
    A = poisson2d(H, W)
    x = np.random.default_rng(0).standard_normal(H * W).astype(np.float32)
    z = jnp.zeros
    out = stencil2d(jnp.asarray(x.reshape(H, W)), z(W), z(W), z(H), z(H),
                    interpret=True)
    np.testing.assert_allclose(np.asarray(out).reshape(-1), A @ x,
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,n", [(3, 1024), (5, 4096), (9, 2048), (7, 1536)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_multidot(m, n, dtype):
    W = jax.random.normal(KEY, (m, n), jnp.float32).astype(dtype)
    z = jax.random.normal(jax.random.PRNGKey(9), (n,), jnp.float32).astype(dtype)
    out = multidot(W, z, bn=512, interpret=True)
    want = ref.multidot_ref(W, z)
    rel = np.max(np.abs(np.asarray(out) - np.asarray(want))) / (
        np.max(np.abs(np.asarray(want))) + 1e-9)
    assert rel < (1e-5 if dtype == jnp.float32 else 3e-2)


@pytest.mark.parametrize("m,n", [(2, 1024), (6, 4096), (10, 2048)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_window_axpy(m, n, dtype):
    V = jax.random.normal(KEY, (m, n), jnp.float32).astype(dtype)
    z = jax.random.normal(jax.random.PRNGKey(2), (n,), jnp.float32).astype(dtype)
    g = jax.random.normal(jax.random.PRNGKey(3), (m,), jnp.float32)
    out = window_axpy(V, z, g, 1.25, bn=512, interpret=True)
    want = ref.window_axpy_ref(V, z, g, 1.25)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=1e-4 if dtype == jnp.float32 else 1e-1)


def test_kernels_drive_a_full_solve():
    """The fused kernels plugged into the reference solver reproduce it."""
    from repro.core.plcg import plcg
    from repro.operators import poisson2d
    A = poisson2d(16, 16)
    b = A @ np.ones(A.n)
    r = plcg(A, b, l=2, tol=1e-9, maxiter=200, spectrum=(0, 8))
    assert r.converged
