"""Per-kernel allclose vs the pure-jnp oracles (interpret mode executes the
TPU kernel bodies exactly), swept over shapes and dtypes.  Window kernels
take lane-major (n, window) operands."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels import ops as kops
from repro.kernels.fused_body import fused_body
from repro.kernels.multidot import multidot
from repro.kernels.stencil2d import stencil2d, stencil2d_batched
from repro.kernels.window_axpy import window_axpy

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("shape", [(32, 128), (64, 128), (128, 256), (40, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bh", [8, 16])
def test_stencil2d(shape, dtype, bh):
    H, W = shape
    x = jax.random.normal(KEY, (H, W), jnp.float32).astype(dtype)
    hn = jax.random.normal(jax.random.PRNGKey(1), (W,), jnp.float32).astype(dtype)
    hs = jax.random.normal(jax.random.PRNGKey(2), (W,), jnp.float32).astype(dtype)
    hw = jax.random.normal(jax.random.PRNGKey(3), (H,), jnp.float32).astype(dtype)
    he = jax.random.normal(jax.random.PRNGKey(4), (H,), jnp.float32).astype(dtype)
    out = stencil2d(x, hn, hs, hw, he, bh=bh, interpret=True)
    want = ref.stencil2d_ref(x, hn, hs, hw, he)
    tol = 1e-5 if dtype == jnp.float32 else 8e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


def test_stencil2d_matches_poisson_operator():
    """With zero halos the kernel IS the paper's Poisson operator."""
    from repro.operators import poisson2d
    H = W = 128
    A = poisson2d(H, W)
    x = np.random.default_rng(0).standard_normal(H * W).astype(np.float32)
    z = jnp.zeros
    out = stencil2d(jnp.asarray(x.reshape(H, W)), z(W), z(W), z(H), z(H),
                    interpret=True)
    np.testing.assert_allclose(np.asarray(out).reshape(-1), A @ x,
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,n", [(3, 1024), (5, 4096), (9, 2048), (7, 1536)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_multidot(m, n, dtype):
    W = jax.random.normal(KEY, (n, m), jnp.float32).astype(dtype)
    z = jax.random.normal(jax.random.PRNGKey(9), (n,), jnp.float32).astype(dtype)
    out = multidot(W, z, bn=512, interpret=True)
    want = ref.multidot_ref(W, z)
    rel = np.max(np.abs(np.asarray(out) - np.asarray(want))) / (
        np.max(np.abs(np.asarray(want))) + 1e-9)
    assert rel < (1e-5 if dtype == jnp.float32 else 3e-2)


def test_multidot_preserves_f64():
    """x64 accumulation stays f64 (the tight-parity requirement of the
    backend ladder)."""
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        W = jax.random.normal(KEY, (2048, 5), jnp.float64)
        z = jax.random.normal(jax.random.PRNGKey(9), (2048,), jnp.float64)
        out = multidot(W, z, bn=512, interpret=True)
        assert out.dtype == jnp.float64
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(ref.multidot_ref(W, z)),
                                   rtol=1e-14)
    finally:
        jax.config.update("jax_enable_x64", old)


@pytest.mark.parametrize("m,n", [(2, 1024), (6, 4096), (10, 2048)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_window_axpy(m, n, dtype):
    V = jax.random.normal(KEY, (n, m), jnp.float32).astype(dtype)
    z = jax.random.normal(jax.random.PRNGKey(2), (n,), jnp.float32).astype(dtype)
    g = jax.random.normal(jax.random.PRNGKey(3), (m,), jnp.float32)
    out = window_axpy(V, z, g, 1.25, bn=512, interpret=True)
    want = ref.window_axpy_ref(V, z, g, 1.25)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=1e-4 if dtype == jnp.float32 else 1e-1)


@pytest.mark.parametrize("B", [1, 3, 8])
@pytest.mark.parametrize("bh", [8, 16])
def test_stencil2d_batched_matches_per_lane(B, bh):
    """The lane-leading (B, H, W) batched kernel is bit-identical to B
    single-lane applications."""
    H, W = 32, 128
    ks = [jax.random.PRNGKey(i) for i in range(5)]
    x = jax.random.normal(ks[0], (B, H, W), jnp.float32)
    hn = jax.random.normal(ks[1], (B, W), jnp.float32)
    hs = jax.random.normal(ks[2], (B, W), jnp.float32)
    hw = jax.random.normal(ks[3], (B, H), jnp.float32)
    he = jax.random.normal(ks[4], (B, H), jnp.float32)
    out = stencil2d_batched(x, hn, hs, hw, he, bh=bh, interpret=True)
    want = jnp.stack([ref.stencil2d_ref(x[i], hn[i], hs[i], hw[i], he[i])
                      for i in range(B)])
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(ref.stencil2d_batched_ref(x, hn, hs, hw, he)),
        np.asarray(want), atol=0)


def test_stencil2d_apply_vmaps_to_one_launch():
    """jax.vmap of the halo stencil (the mesh engine's multi-RHS SPMV)
    lowers to ONE pallas_call streaming the whole lane batch -- the
    custom_vmap rule installs stencil2d_batched."""
    from repro.kernels.introspect import count_pallas_calls
    B, H, W = 4, 16, 128
    x = jax.random.normal(KEY, (B, H, W), jnp.float32)
    hn = jnp.zeros((B, W))
    hw = jnp.zeros((B, H))

    def one(xx, a, b, c, d):
        return kops.stencil2d_apply(xx, a, b, c, d, use_pallas=True)

    assert count_pallas_calls(jax.vmap(one), x, hn, hn, hw, hw) == 1
    got = jax.vmap(one)(x, hn, hn, hw, hw)
    want = ref.stencil2d_batched_ref(x, hn, hn, hw, hw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)
    # the jnp-oracle path batches through the same custom_vmap rule
    got_ref = jax.vmap(lambda *a: kops.stencil2d_apply(*a,
                                                       use_pallas=False))(
        x, hn, hn, hw, hw)
    np.testing.assert_allclose(np.asarray(got_ref), np.asarray(want), atol=0)


# ---------------------- fused iteration megakernel ------------------------

def _fused_inputs(l, n, dtype, prec=False):
    m = 2 * l + 1
    Vw = jax.random.normal(KEY, (n, m), jnp.float32).astype(dtype)
    Zw = jax.random.normal(jax.random.PRNGKey(1), (n, l + 1),
                           jnp.float32).astype(dtype)
    Zhw = (jax.random.normal(jax.random.PRNGKey(2), (n, 3),
                             jnp.float32).astype(dtype) if prec else None)
    t = jax.random.normal(jax.random.PRNGKey(3), (n,),
                          jnp.float32).astype(dtype)
    th = (jax.random.normal(jax.random.PRNGKey(4), (n,),
                            jnp.float32).astype(dtype) if prec else None)
    g = jax.random.normal(jax.random.PRNGKey(5), (2 * l,),
                          jnp.float32).astype(dtype)
    scalars = dict(s_warm=jnp.asarray(0.7, dtype), gam=jnp.asarray(1.3, dtype),
                   dlt=jnp.asarray(0.9, dtype), dsub=jnp.asarray(0.4, dtype),
                   gcc=jnp.asarray(1.1, dtype), g=g)
    return Vw, Zw, Zhw, t, th, scalars


def _pack_scal(steady, scalars, l, dtype, invd_s=0.0):
    # layout must match fused_body.N_FIXED_SCALARS (incl. the scalar
    # inverse-diagonal slot of the fused preconditioner apply)
    return jnp.concatenate([
        jnp.stack([jnp.asarray(1.0 if steady else 0.0, dtype),
                   scalars["s_warm"], scalars["gam"], scalars["dlt"],
                   scalars["dsub"], scalars["gcc"],
                   jnp.asarray(invd_s, dtype)]),
        scalars["g"]]).reshape(1, 7 + 2 * l).astype(dtype)


@pytest.mark.parametrize("l", [1, 2, 4])
@pytest.mark.parametrize("steady", [True, False])
@pytest.mark.parametrize("prec", [False, True])
def test_fused_body_matches_oracle(l, steady, prec):
    n, dtype = 2048, jnp.float32
    Vw, Zw, Zhw, t, th, scalars = _fused_inputs(l, n, dtype, prec=prec)
    scal = _pack_scal(steady, scalars, l, dtype)
    got = fused_body(Vw, Zw, scal, Zhw, t, th, l=l, bn=512, interpret=True)
    want = ref.fused_body_ref(Vw, Zw, Zhw, t, th, l=l,
                              steady=jnp.bool_(steady), **scalars)
    labels = ("Vw2", "Zw2", "Zhw2", "dots")
    for lab, a, b in zip(labels, got, want):
        if a is None and b is None:
            continue
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-4,
                                   err_msg=lab)


@pytest.mark.parametrize("hw", [(16, 128), (32, 128), (24, 256)])
def test_fused_body_in_kernel_stencil(hw):
    """t=None folds the 5-point Dirichlet SPMV into the kernel; must match
    the oracle that applies stencil2d_ref to Zw[:, 0]."""
    H, W = hw
    l, n, dtype = 2, H * W, jnp.float32
    Vw, Zw, _, _, _, scalars = _fused_inputs(l, n, dtype)
    scal = _pack_scal(True, scalars, l, dtype)
    got = fused_body(Vw, Zw, scal, None, None, None, l=l,
                     stencil_hw=(H, W), bn=8 * W, interpret=True)
    want = ref.fused_body_ref(Vw, Zw, None, None, None, l=l,
                              steady=jnp.bool_(True), stencil_hw=(H, W),
                              **scalars)
    for a, b in zip(got, want):
        if a is None and b is None:
            continue
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-4)


@pytest.mark.parametrize("mode", ["scalar", "vector"])
@pytest.mark.parametrize("stencil", [False, True])
def test_fused_body_diag_preconditioner(mode, stencil):
    """The in-kernel diagonal preconditioner apply (scalar slot or (n, 1)
    operand), with and without the fused stencil SPMV, matches the oracle
    that applies t = invd * t_hat."""
    H, W = 16, 128
    l, n, dtype = 2, H * W, jnp.float32
    Vw, Zw, Zhw, t, th, scalars = _fused_inputs(l, n, dtype, prec=True)
    if mode == "scalar":
        invd = jnp.asarray(0.25, dtype)
        scal = _pack_scal(True, scalars, l, dtype, invd_s=0.25)
        vec = None
    else:
        invd = 1.0 / jnp.linspace(3.5, 4.5, n).astype(dtype)
        scal = _pack_scal(True, scalars, l, dtype)
        vec = invd.reshape(n, 1)
    if stencil:
        got = fused_body(Vw, Zw, scal, Zhw, None, None, vec, l=l,
                         stencil_hw=(H, W), diag=mode, bn=4 * W,
                         interpret=True)
        want = ref.fused_body_ref(Vw, Zw, Zhw, None, None, l=l,
                                  steady=jnp.bool_(True), invd=invd,
                                  stencil_hw=(H, W), **scalars)
    else:
        got = fused_body(Vw, Zw, scal, Zhw, None, th, vec, l=l,
                         diag=mode, bn=512, interpret=True)
        want = ref.fused_body_ref(Vw, Zw, Zhw, None, th, l=l,
                                  steady=jnp.bool_(True), invd=invd,
                                  **scalars)
    for lab, a, b in zip(("Vw2", "Zw2", "Zhw2", "dots"), got, want):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-4,
                                   err_msg=lab)


def test_fused_body_batches_to_one_launch():
    """vmap over the megakernel (the batched multi-RHS engine) must lower
    to ONE pallas_call handling the whole (B, n, window) batch."""
    from repro.kernels.introspect import count_pallas_calls
    l, n, B, dtype = 2, 1024, 3, jnp.float32
    Vw, Zw, _, t, _, scalars = _fused_inputs(l, n, dtype)
    scal = _pack_scal(True, scalars, l, dtype)
    stack = lambda a: jnp.stack([a] * B)  # noqa: E731
    fn = jax.vmap(lambda V, Z, s, tt: fused_body(V, Z, s, None, tt, None,
                                                 l=l, bn=512, interpret=True))
    assert count_pallas_calls(fn, stack(Vw), stack(Zw), stack(scal),
                              stack(t)) == 1
    out = fn(stack(Vw), stack(Zw), stack(scal), stack(t))
    want = ref.fused_body_ref(Vw, Zw, None, t, None, l=l,
                              steady=jnp.bool_(True), **scalars)
    np.testing.assert_allclose(np.asarray(out[0][1]), np.asarray(want[0]),
                               atol=2e-4)


def test_kernels_drive_a_full_solve():
    """The fused kernels plugged into the reference solver reproduce it."""
    from repro.core.plcg import plcg
    from repro.operators import poisson2d
    A = poisson2d(16, 16)
    b = A @ np.ones(A.n)
    r = plcg(A, b, l=2, tol=1e-9, maxiter=200, spectrum=(0, 8))
    assert r.converged
