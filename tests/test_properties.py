"""Property-based tests (hypothesis) on the system's invariants.

hypothesis ships via the ``test`` extra (``pip install -e ".[test]"``);
without it this module skips cleanly instead of breaking collection."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need the 'test' extra "
    '(pip install -e ".[test]")')
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import solve  # noqa: E402
from repro.core.cg import classic_cg  # noqa: E402
from repro.core.plcg import plcg  # noqa: E402
from repro.operators.spd import spd_with_spectrum  # noqa: E402

SPECTRA = st.sampled_from(["uniform", "geometric", "clustered"])


def _make_spd(n, cond, kind, seed):
    if kind == "uniform":
        eigs = np.linspace(1.0 / cond, 1.0, n)
    elif kind == "geometric":
        eigs = np.geomspace(1.0 / cond, 1.0, n)
    else:
        eigs = np.concatenate([[1.0 / cond], np.linspace(0.9, 1.1, n - 1)])
    from repro.core.linop import dense_operator
    return dense_operator(spd_with_spectrum(eigs, seed=seed)), eigs


@settings(max_examples=12, deadline=None)
@given(n=st.integers(24, 64), cond=st.sampled_from([1e2, 1e3]),
       kind=SPECTRA, l=st.integers(1, 3), seed=st.integers(0, 5))
def test_plcg_converges_on_random_spd(n, cond, kind, l, seed):
    """For any well-conditioned SPD system, p(l)-CG reaches the tolerance
    (possibly via restarts) and the solution solves the system."""
    A, eigs = _make_spd(n, cond, kind, seed)
    x_true = np.linspace(-1, 1, n)
    b = A @ x_true
    r = plcg(A, b, l=l, tol=1e-7, maxiter=20 * n, max_restarts=10,
             spectrum=(float(eigs.min()) * 0.9, float(eigs.max()) * 1.1))
    assert r.converged
    assert np.linalg.norm(b - A @ r.x) <= 1e-5 * max(np.linalg.norm(b), 1)


@settings(max_examples=8, deadline=None)
@given(n=st.integers(24, 48), l=st.integers(1, 3), seed=st.integers(0, 3))
def test_plcg_monotone_krylov_property(n, l, seed):
    """The p(l)-CG iterates match classic CG while both are far from
    stagnation (exact-arithmetic identity, Remark 7)."""
    A, eigs = _make_spd(n, 1e3, "uniform", seed)
    b = A @ np.ones(n)
    ref = classic_cg(A, b, tol=1e-10, maxiter=3 * n)
    r = plcg(A, b, l=l, tol=1e-10, maxiter=3 * n, max_restarts=0,
             spectrum=(float(eigs.min()) * 0.9, float(eigs.max()) * 1.1))
    m = min(len(ref.resnorms), len(r.resnorms))
    m = min(m, ref.iters // 2)
    assert np.allclose(r.resnorms[:m], ref.resnorms[:m], rtol=1e-3)


@settings(max_examples=6, deadline=None)
@given(l=st.integers(1, 3), seed=st.integers(0, 4))
def test_G_band_structure(l, seed):
    """Lemma 5: G has bandwidth 2l+1 for symmetric A."""
    A, eigs = _make_spd(40, 1e2, "uniform", seed)
    b = A @ np.ones(40)
    r = plcg(A, b, l=l, tol=0.0, maxiter=20, record_G=True, max_restarts=0,
             spectrum=(float(eigs.min()) * 0.9, float(eigs.max()) * 1.1))
    G = r.info["traces"][0].G
    k = 18
    for i in range(k):
        assert np.max(np.abs(G[: max(0, i - 2 * l), i]), initial=0.0) < 1e-8


@settings(max_examples=6, deadline=None)
@given(step=st.integers(0, 50), batch=st.sampled_from([2, 4]),
       seq=st.sampled_from([16, 32]))
def test_data_pipeline_deterministic(step, batch, seq):
    """Exact-restart property: (step, shape) fully determines the batch."""
    from repro.configs import get_reduced
    from repro.training.data import synth_batch
    cfg = get_reduced("qwen3-14b")
    b1 = synth_batch(cfg, step, batch, seq, seed=1)
    b2 = synth_batch(cfg, step, batch, seq, seed=1)
    for k in b1:
        np.testing.assert_array_equal(b1[k], b2[k])
    b3 = synth_batch(cfg, step + 1, batch, seq, seed=1)
    assert any(not np.array_equal(b1[k], b3[k]) for k in b1)


# --------------------- unified solve() registry ---------------------------

@settings(max_examples=10, deadline=None)
@given(method=st.sampled_from(["cg", "pcg", "plcg", "dlanczos", "plminres"]),
       n=st.integers(24, 48), seed=st.integers(0, 3))
def test_registry_methods_agree_with_cg(method, n, seed):
    """Every registered method on a random well-conditioned SPD system
    converges to the CG answer within tolerance (exact-arithmetic
    equivalence of the whole family, paper Remarks 6/7)."""
    from repro.core.linop import dense_operator
    eigs = np.linspace(1e-2, 1.0, n)
    A = dense_operator(spd_with_spectrum(eigs, seed=seed))
    b = A @ np.linspace(-1, 1, n)
    ref = solve(A, b, method="cg", tol=1e-10, maxiter=10 * n)
    # 1e-6: attainable by every member of the family, incl. the rounding-
    # limited depth-2 pipelined MINRES basis (paper Sec. 4)
    r = solve(A, b, method=method, l=2, tol=1e-6, maxiter=10 * n,
              spectrum=(float(eigs.min()) * 0.9, float(eigs.max()) * 1.1))
    assert r.converged
    assert np.linalg.norm(np.asarray(r.x) - np.asarray(ref.x)) <= 1e-3


@settings(max_examples=6, deadline=None)
@given(nrhs=st.sampled_from([2, 4]), n=st.integers(24, 40),
       l=st.integers(1, 2), seed=st.integers(0, 3))
def test_batched_solve_matches_single_rhs_loop(nrhs, n, l, seed):
    """Batched multi-RHS solve (one jitted vmap(scan)) equals a loop of
    single-RHS solves on every right-hand side."""
    import jax
    from repro.core.linop import dense_operator
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        eigs = np.linspace(1e-2, 1.0, n)
        A = dense_operator(spd_with_spectrum(eigs, seed=seed))
        rng = np.random.default_rng(seed)
        B = np.stack([np.asarray(A @ rng.standard_normal(n))
                      for _ in range(nrhs)])
        spect = (float(eigs.min()) * 0.9, float(eigs.max()) * 1.1)
        rb = solve(A, B, method="plcg_scan", l=l, tol=1e-10, maxiter=6 * n,
                   spectrum=spect)
        for j in range(nrhs):
            rj = solve(A, B[j], method="plcg_scan", l=l, tol=1e-10,
                       maxiter=6 * n, spectrum=spect)
            num = np.linalg.norm(np.asarray(rb.x)[j] - np.asarray(rj.x))
            assert num <= 1e-8 * max(np.linalg.norm(np.asarray(rj.x)), 1.0)
    finally:
        jax.config.update("jax_enable_x64", old)


@settings(max_examples=10, deadline=None)
@given(shape=st.sampled_from([(8, 256), (3, 512), (16, 64), (5, 1000)]),
       seed=st.integers(0, 5))
def test_q8_roundtrip_bounded_error(shape, seed):
    """Block int8 quantization: |x - dq(q(x))| <= scale/2 per block."""
    import jax.numpy as jnp
    from repro.training.optim import _dq8, _q8
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(shape) * 10, jnp.float32)
    q, s = _q8(x)
    back = _dq8(q, s, shape)
    err = np.max(np.abs(np.asarray(back) - np.asarray(x)))
    assert err <= float(np.max(np.asarray(s))) * 0.51 + 1e-6
