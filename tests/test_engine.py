"""Unified solver front-end: registry dispatch, batched multi-RHS
vmap(scan) engine (single compilation, per-RHS convergence masking),
kernel-backend switch, and operator coercion."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import as_operator, clear_batch_trace, methods, solve
from repro.core import engine
from repro.operators import poisson2d, poisson2d_dense
from repro.operators.precond import jacobi


@pytest.fixture(scope="module", autouse=True)
def x64():
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", old)


@pytest.fixture(scope="module")
def poisson():
    A = poisson2d(20, 20)
    b = A @ np.ones(A.n)
    return A, b


# ------------------------------- registry ---------------------------------

def test_registry_has_all_six_methods():
    assert methods() == ("cg", "dlanczos", "pcg", "plcg", "plcg_scan",
                         "plminres")


def test_unknown_method_raises_with_listing():
    A = poisson2d(8, 8)
    b = A @ np.ones(A.n)
    with pytest.raises(ValueError, match="plcg_scan"):
        solve(A, b, method="nope")


@pytest.mark.parametrize("method", ["cg", "pcg", "plcg", "plcg_scan",
                                    "dlanczos", "plminres"])
def test_every_method_matches_cg_through_one_signature(poisson, method):
    """Acceptance: all six registered methods dispatch through one
    signature and agree with classic CG on an SPD system."""
    A, b = poisson
    ref = solve(A, b, method="cg", tol=1e-10, maxiter=500)
    r = solve(A, b, method=method, l=2, tol=1e-10, maxiter=400,
              spectrum=(0.0, 8.0))
    assert r.converged
    assert np.linalg.norm(np.asarray(r.x) - np.asarray(ref.x)) < 1e-7
    assert r.info["method"]          # common SolveResult contract


def test_solve_accepts_dense_matrix_and_callable(poisson):
    A, b = poisson
    dense = poisson2d_dense(20, 20)
    r1 = solve(dense, b, method="cg", tol=1e-10, maxiter=500)
    r2 = solve(lambda v: dense @ v, b, method="cg", tol=1e-10, maxiter=500)
    assert r1.converged and r2.converged
    assert np.allclose(np.asarray(r1.x), np.asarray(r2.x), atol=1e-9)
    with pytest.raises(ValueError):
        as_operator(lambda v: v)            # callable without b: no dim


def test_preconditioned_dispatch(poisson):
    A, b = poisson
    M = jacobi(A)
    r = solve(A, b, method="cg", tol=1e-10, maxiter=500, M=M)
    assert r.converged
    rs = solve(A, b, method="plcg_scan", l=2, tol=1e-10, maxiter=400,
               M=M, spectrum=(0.0, 2.0))
    assert rs.converged
    assert np.linalg.norm(b - A @ np.asarray(rs.x)) < 5e-8


# ------------------------- batched multi-RHS ------------------------------

def _batch(A, nrhs, seed=0):
    rng = np.random.default_rng(seed)
    return np.stack([np.asarray(A @ rng.standard_normal(A.n))
                     for _ in range(nrhs)])


def test_batched_matches_single_rhs_and_compiles_once(poisson):
    """Acceptance: solve(A, B) with B.shape == (8, n) matches 8 single-RHS
    solves to 1e-8 relative and runs as ONE jitted vmap(scan)."""
    A, _ = poisson
    B = _batch(A, 8)
    clear_batch_trace()
    rb = solve(A, B, method="plcg_scan", l=2, tol=1e-10, maxiter=200,
               spectrum=(0.0, 8.0))
    # exactly one trace event == exactly one XLA compilation of the engine
    assert len(engine.BATCH_TRACE_EVENTS) == 1
    name, shape, l = engine.BATCH_TRACE_EVENTS[0]
    assert shape == (8, A.n) and l == 2
    assert rb.converged and np.asarray(rb.x).shape == (8, A.n)
    for j in range(8):
        rj = solve(A, B[j], method="plcg_scan", l=2, tol=1e-10, maxiter=200,
                   spectrum=(0.0, 8.0))
        d = np.linalg.norm(np.asarray(rb.x)[j] - np.asarray(rj.x))
        assert d <= 1e-8 * np.linalg.norm(np.asarray(rj.x))


def test_batched_default_method_uses_vmap_engine(poisson):
    """The default method ('plcg') routes batched input through the same
    jitted vmap(scan) production engine."""
    A, _ = poisson
    B = _batch(A, 3, seed=1)
    clear_batch_trace()
    rb = solve(A, B, l=2, tol=1e-10, maxiter=200, spectrum=(0.0, 8.0))
    assert len(engine.BATCH_TRACE_EVENTS) == 1
    assert rb.info["batched"] == "vmap"
    assert rb.converged


def test_batched_per_rhs_convergence_masking(poisson):
    """Converged lanes freeze (per-lane select) while others iterate: the
    smooth A@1 RHS converges well before a rough random RHS, and the
    frozen lane's residual trace stops growing."""
    A, b = poisson
    rough = np.asarray(A @ np.random.default_rng(3).standard_normal(A.n))
    B = np.stack([np.asarray(b), rough])
    rb = solve(A, B, method="plcg_scan", l=2, tol=1e-10, maxiter=200,
               spectrum=(0.0, 8.0))
    iters = np.asarray(rb.info["per_rhs_iters"])
    conv = np.asarray(rb.info["per_rhs_converged"])
    assert conv.all()
    assert iters[0] < iters[1] - 10        # eigenvector lane stops early
    # the frozen lane emits exactly iters[0] nonzero residuals, the live
    # lane keeps writing its own trace
    assert len(rb.resnorms[0]) < len(rb.resnorms[1])


def test_batched_loop_fallback_for_reference_methods(poisson):
    A, _ = poisson
    B = _batch(A, 2, seed=2)
    rb = solve(A, B, method="cg", tol=1e-10, maxiter=400)
    assert rb.info["batched"] == "loop"
    assert rb.converged
    for j in range(2):
        rj = solve(A, B[j], method="cg", tol=1e-10, maxiter=400)
        assert np.allclose(np.asarray(rb.x)[j], np.asarray(rj.x))


def test_mesh_dispatch_through_front_end(poisson):
    """solve(..., mesh=...) routes the SAME registry method through the
    mesh execution layer: on a trivial (1, 1) mesh the batched result
    matches the single-device vmap(scan) engine to 1e-10 relative, the
    SolveResult carries the per-RHS info contract, and the mesh engine
    logs its own trace event."""
    from repro.launch.mesh import make_mesh_compat
    A, _ = poisson
    B = _batch(A, 2)
    kw = dict(method="plcg_scan", l=2, tol=1e-10, maxiter=200,
              spectrum=(0.0, 8.0))
    rb = solve(A, B, **kw)
    mesh = make_mesh_compat((1, 1), ("data", "model"))
    clear_batch_trace()
    rm = solve(A, B, mesh=mesh, **kw)
    assert [e[0] for e in engine.BATCH_TRACE_EVENTS] == ["plcg@mesh"]
    assert rm.info["batched"] == "shard_map+vmap"
    assert rm.info["psums_per_iter"] == 1
    assert np.asarray(rm.x).shape == (2, A.n)       # flat in, flat out
    for j in range(2):
        d = np.linalg.norm(np.asarray(rm.x)[j] - np.asarray(rb.x)[j])
        assert d <= 1e-10 * np.linalg.norm(np.asarray(rb.x)[j])
    assert list(rm.info["per_rhs_iters"]) == list(rb.info["per_rhs_iters"])


# --------------------------- kernel backends ------------------------------

def test_backend_ref_matches_inline(poisson):
    """The fused jnp oracle backend is numerically identical to the inline
    scan math in fp64 (same promote_types accumulation)."""
    A, b = poisson
    r0 = solve(A, b, method="plcg_scan", l=2, tol=1e-10, maxiter=200,
               spectrum=(0.0, 8.0), backend=None)
    r1 = solve(A, b, method="plcg_scan", l=2, tol=1e-10, maxiter=200,
               spectrum=(0.0, 8.0), backend="ref")
    assert r0.converged and r1.converged
    assert np.allclose(np.asarray(r0.x), np.asarray(r1.x), atol=1e-12)


def test_backend_pallas_converges_at_f32_accuracy():
    """The Pallas kernels (interpret mode on CPU) drive the scan engine to
    fp32-level accuracy: the TPU hot path is numerically exercised."""
    A = poisson2d(12, 12)
    b = A @ np.ones(A.n)
    r = solve(A, b, method="plcg_scan", l=2, tol=1e-4, maxiter=150,
              spectrum=(0.0, 8.0), backend="pallas")
    assert r.converged
    assert np.linalg.norm(b - A @ np.asarray(r.x)) < 1e-2


def test_backend_rejects_unknown():
    A = poisson2d(8, 8)
    b = A @ np.ones(A.n)
    with pytest.raises(ValueError, match="backend"):
        solve(A, b, method="plcg_scan", l=1, maxiter=20, backend="cuda")
