"""Precision-policy tests: the ``precision=`` knob end to end.

Covers the ``as_precision_policy`` one-normalization-point contract
(mirroring ``as_preconditioner`` / ``as_comm_policy``), the engine's
capability gating, and the two structural acceptance gates of the
mixed-precision design:

* a ``precision="bf16"`` storage policy must change what each shard
  streams through HBM *locally* and NOTHING about the wire -- identical
  collective ``(primitive, shape)`` signature for all three ``comm=``
  modes, with every payload in the f32/f64 *compute* dtype (never
  bfloat16);
* pooled lanes (``SolverPool``) keep the masked-sweep contract under
  bf16 storage: lanes converging at different iterations mask exactly
  as the shape-identical batched one-shot does.

Mesh coverage runs in-process on a (1, 1) mesh (the traced collective
signature is mesh-size independent); the CI precision lane additionally
runs this file with 4 forced host devices.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (PRECISION_MODES, PrecisionPolicy, Solver, SolverPool,
                        as_precision_policy, methods_supporting, solve)
from repro.operators import poisson2d


@pytest.fixture(scope="module", autouse=True)
def x64():
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", old)


# --------------------------- policy normalization --------------------------

def test_policy_promotion_forms():
    """as_precision_policy is the one normalization point: None, ladder
    names, explicit compounds, dtype-likes and pass-through policies."""
    assert as_precision_policy(None).is_default
    assert as_precision_policy(None) == PrecisionPolicy()
    p = as_precision_policy("bf16")
    assert p.storage == "bfloat16" and p.compute is None
    assert as_precision_policy("F32").storage == "float32"
    comp = as_precision_policy("bf16x64")
    assert comp.storage == "bfloat16" and comp.compute == "float64"
    assert as_precision_policy("f32x64") == PrecisionPolicy("f32", "f64")
    assert as_precision_policy(jnp.bfloat16).storage == "bfloat16"
    assert as_precision_policy(np.float64).storage == "float64"
    q = PrecisionPolicy(storage="bf16")
    assert as_precision_policy(q) is q
    # hashable: policies key the weak sweep caches
    assert hash(PrecisionPolicy("bf16")) == hash(PrecisionPolicy("bfloat16"))
    assert "bf16" in PRECISION_MODES


def test_policy_validation():
    with pytest.raises(ValueError, match="tf32"):
        as_precision_policy("tf32")
    with pytest.raises(ValueError, match="unknown precision"):
        as_precision_policy("int8")
    with pytest.raises(ValueError, match="compute dtype must be"):
        PrecisionPolicy(storage="f32", compute="bf16")
    with pytest.raises(ValueError, match="compute dtype must be"):
        as_precision_policy("bf16x16")
    with pytest.raises(TypeError, match="precision"):
        as_precision_policy(16)


def test_policy_resolution():
    """The default policy is exactly the pre-policy engine (b.dtype for
    both sides); declared storage keeps compute at promote(b.dtype, f32)
    -- scalars never drop below the problem's own precision."""
    assert PrecisionPolicy().resolve(jnp.float64) == (jnp.float64,
                                                     jnp.float64)
    sdt, cdt = as_precision_policy("bf16").resolve(jnp.float32)
    assert (sdt, cdt) == (jnp.bfloat16, jnp.float32)
    sdt, cdt = as_precision_policy("bf16").resolve(jnp.float64)
    assert (sdt, cdt) == (jnp.bfloat16, jnp.float64)
    sdt, cdt = as_precision_policy("bf16x64").resolve(jnp.float32)
    assert (sdt, cdt) == (jnp.bfloat16, jnp.float64)
    assert (as_precision_policy("f16").resolve(jnp.float32)
            == (jnp.float16, jnp.float32))
    assert (as_precision_policy("bf16").compute_dtype(jnp.float32)
            == jnp.float32)


# ----------------------------- capability gating ---------------------------

def test_front_end_rejects_precision_uniformly():
    """Only precision-capable methods accept a non-default policy -- the
    same knob-table error through solve() and Solver; the default policy
    is accepted everywhere (it selects nothing)."""
    assert set(methods_supporting("precision")) == {"plcg_scan"}
    A = poisson2d(8, 8)
    b = np.asarray(A @ np.ones(A.n))
    with pytest.raises(ValueError, match="does not support precision"):
        solve(A, b, method="cg", precision="bf16")
    with pytest.raises(ValueError, match="does not support precision"):
        Solver(A, method="cg", precision="bf16")
    r = solve(A, b, method="cg", tol=1e-8, maxiter=200, precision=None)
    assert r.converged


# ------------------- structural: nothing changes on the wire ---------------

def test_mesh_collective_signature_unchanged_under_bf16():
    """Acceptance gate: for every comm mode, bf16 storage leaves the
    traced scan body's collective (primitive, shape) signature exactly
    as the default-precision sweep traces it, and every collective
    payload stays in the f64 compute dtype -- bfloat16 never reaches
    a psum/reduce_scatter/all_gather/ppermute operand."""
    from repro.core.shifts import chebyshev_shifts
    from repro.distributed import DistPoisson, plcg_mesh_sweep
    from repro.kernels.introspect import (
        collective_payload_dtypes_in_scan_bodies)
    from repro.launch.mesh import make_mesh_compat

    mesh = make_mesh_compat((1, 1), ("data", "model"))
    op = DistPoisson(16, 16, mesh)
    sig = tuple(chebyshev_shifts(0, 8, 3))
    b = jnp.ones((16, 16))

    def triples(comm, precision):
        f = plcg_mesh_sweep(op, l=3, iters=30, sigma=sig, tol=1e-8,
                            comm=comm, precision=precision)
        return collective_payload_dtypes_in_scan_bodies(f, b, b * 0, 30)[0]

    for comm in ("blocking", "overlap", "ring"):
        base = triples(comm, None)
        bf16 = triples(comm, "bf16")
        assert [(p, s) for p, s, _ in bf16] == [(p, s) for p, s, _ in base], \
            comm
        assert all(dt == jnp.float64 for _, _, dt in bf16), comm
        assert not any(dt == jnp.bfloat16 for _, _, dt in bf16), comm


# ------------------------- pooled lanes under bf16 -------------------------

def test_pool_lane_masking_under_bf16():
    """Pooled lanes keep the masked-sweep contract at bf16 storage: the
    flush packs into one batched sweep whose per-lane results are
    bitwise against the shape-identical batched one-shot, lanes
    converge at (potentially) different iterations, and every converged
    lane sits at the bf16 attainable-accuracy floor."""
    A = poisson2d(20, 20)
    rng = np.random.default_rng(7)
    B = np.stack([np.asarray(A @ np.ones(A.n)),
                  np.asarray(A @ rng.standard_normal(A.n)),
                  0.01 * np.asarray(A @ np.ones(A.n))])
    kw = dict(l=1, tol=5e-2, maxiter=200, spectrum=(0.0, 8.0),
              precision="bf16")
    solver = Solver(A, "plcg_scan", **kw)
    assert solver.precision == PrecisionPolicy("bf16")
    pool = SolverPool(solver, max_batch=4)
    handles = [pool.submit(B[j]) for j in range(3)]
    pool.flush()
    rb = solve(A, B, method="plcg_scan", **kw)          # one-shot batched
    iters = []
    for j, h in enumerate(handles):
        r = h.result()
        assert r.info["pooled"] and r.info["lane"] == j
        assert np.array_equal(np.asarray(r.x), np.asarray(rb.x)[j])
        assert bool(r.converged) == bool(np.asarray(rb.info
                                                    ["per_rhs_converged"])[j])
        iters.append(int(np.asarray(rb.info["per_rhs_iters"])[j]))
        if r.converged:
            true = np.linalg.norm(np.asarray(A @ np.asarray(r.x)) - B[j])
            assert true / np.linalg.norm(B[j]) <= 0.2
    assert any(r.converged for r in (h.result() for h in handles))
    # different RHS really do stop at different iterations -- the mask
    # (not a shared early-exit) is what froze the finished lanes
    assert len(set(iters)) > 1
