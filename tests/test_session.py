"""Prepared-solver sessions (``repro.core.session``): the Solver /
SolverPool serving API.

Covers the two-phase lifecycle (validate/normalize/build once, then
zero Python-side re-setup per call), the zero-retrace gate for
same-shape right-hand sides, micro-batched dispatch through
``submit``/``SolveHandle``/``SolverPool`` with pad bucketing (single
device AND mesh), the thin-wrapper contract of ``engine.solve``, the
per-method declared-option validation, and the solver-cache
interactions: a live session survives ``clear_solver_cache()``, and
dropping the last Solver reference releases the operator.

Mesh coverage runs in-process on a (1, 1) mesh everywhere (collective
semantics identical) and on a live (2, 2) decomposition when the main
process has >= 4 devices (the CI serve lane forces 4 via XLA_FLAGS).
"""
import gc
import inspect
import weakref

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SolveHandle, Solver, SolverPool, clear_batch_trace,
                        clear_solver_cache, solve)
from repro.core import engine
from repro.core.session import _default_buckets
from repro.launch.mesh import make_mesh_compat
from repro.operators import poisson2d


@pytest.fixture(scope="module", autouse=True)
def x64():
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", old)


@pytest.fixture(scope="module")
def poisson():
    A = poisson2d(20, 20)
    b = np.asarray(A @ np.ones(A.n))
    return A, b


@pytest.fixture(scope="module")
def mesh11():
    return make_mesh_compat((1, 1), ("data", "model"))


KW = dict(l=2, tol=1e-10, maxiter=200, spectrum=(0.0, 8.0))


def _batch(A, nrhs, seed=0):
    rng = np.random.default_rng(seed)
    return np.stack([np.asarray(A @ rng.standard_normal(A.n))
                     for _ in range(nrhs)])


# ------------------------- two-phase lifecycle ----------------------------

def test_prepared_solver_matches_one_shot_solve(poisson):
    """Solver(A, ...) then solver(b) returns exactly what the one-shot
    front-end returns (same compiled sweep, same SolveResult contract)."""
    A, b = poisson
    solver = Solver(A, "plcg_scan", **KW)
    r1 = solver(b)
    r2 = solve(A, b, method="plcg_scan", **KW)
    assert r1.converged and r2.converged
    assert np.array_equal(np.asarray(r1.x), np.asarray(r2.x))
    assert r1.iters == r2.iters
    assert r1.info["method"] == r2.info["method"]


def test_prepared_solver_zero_retraces_same_shape(poisson):
    """Acceptance: after the first call, repeated same-shape solves show
    ZERO retraces -- every prepared sweep's jit cache stays at its
    first-call size, and no new sweeps are built."""
    A, b = poisson
    solver = Solver(A, "plcg_scan", **KW)
    solver(b)
    builds1 = solver.stats["prepared_builds"]          # lazy-once build
    counts1 = solver.compile_counts()
    assert builds1 >= 1
    assert any(c >= 1 for c in counts1.values())
    for _ in range(5):
        solver(b)
    assert solver.compile_counts() == counts1          # zero retraces
    assert solver.stats["prepared_builds"] == builds1  # zero rebuilds
    assert solver.stats["calls"] == 6


def test_prepared_batched_compiles_once(poisson):
    """The batched engine of a prepared solver traces exactly once for a
    given RHS shape across repeated solver(B) calls."""
    A, _ = poisson
    B = _batch(A, 4)
    solver = Solver(A, "plcg_scan", **KW)
    clear_batch_trace()
    for _ in range(3):
        rb = solver(B)
    assert len(engine.BATCH_TRACE_EVENTS) == 1
    assert engine.BATCH_TRACE_EVENTS[0][1] == (4, A.n)
    assert rb.converged


def test_tol_override_prepares_new_sweep(poisson):
    """A per-call tol override keys an additional prepared sweep; the
    session default stays live alongside it."""
    A, b = poisson
    solver = Solver(A, "plcg_scan", **KW)
    r1 = solver(b)
    builds = solver.stats["prepared_builds"]
    r2 = solver.solve(b, tol=1e-6)
    assert solver.stats["prepared_builds"] == builds + 1
    assert r2.iters <= r1.iters
    r3 = solver(b)                      # default-tol sweep still prepared
    assert solver.stats["prepared_builds"] == builds + 1
    assert np.array_equal(np.asarray(r1.x), np.asarray(r3.x))


def test_matvec_callable_needs_dimension(poisson):
    """A bare matvec callable takes n= at construction (the one-shot
    path infers it from b; the session defers promotion otherwise)."""
    A, b = poisson
    solver = Solver(A.matvec, "plcg_scan", n=A.n, **KW)
    r = solver(b)
    assert r.converged
    deferred = Solver(A.matvec, "plcg_scan", **KW)
    assert deferred(b).converged        # promoted at first call


def test_solver_construction_validates_up_front(poisson):
    A, b = poisson
    with pytest.raises(ValueError, match="plcg_scan"):
        Solver(A, "nope")
    with pytest.raises(ValueError, match="does not support precondition"):
        Solver(A, "plminres", M=lambda v: v / 4.0)
    with pytest.raises(ValueError, match="options"):
        Solver(A, "plcg_scan", record_G=True)


# -------------------- engine.solve() thin-wrapper contract ----------------

def test_solve_signature_unchanged():
    """engine.solve keeps its public signature (the session redesign must
    not break any existing caller) -- extended only by appended
    keyword-only knobs (``comm=``, then the stability pair ``restart=`` /
    ``residual_replacement=``, then ``precision=``), so positional
    callers are unaffected."""
    params = list(inspect.signature(solve).parameters)
    assert params == ["A", "b", "method", "x0", "tol", "maxiter", "M", "l",
                      "sigma", "spectrum", "backend", "mesh", "comm",
                      "restart", "residual_replacement", "precision",
                      "options"]


def test_unknown_option_rejected_uniformly(poisson):
    """Satellite: unknown **options no longer leak into method bodies;
    every method raises one uniform error naming its accepted keys."""
    A, b = poisson
    with pytest.raises(ValueError, match=r"options.*record_G.*accepted"):
        solve(A, b, method="plcg_scan", maxiter=20, record_G=True)
    with pytest.raises(ValueError, match="trace_true_residual"):
        solve(A, b, method="cg", maxiter=20, bogus=1)
    with pytest.raises(ValueError, match="accepted options.*none"):
        solve(A, b, method="dlanczos", maxiter=20, prune=True)
    # session-only constructor keywords (n=) must not absorb a
    # same-named unknown option through the one-shot passthrough
    with pytest.raises(ValueError, match=r"options \['n'\]"):
        solve(A, b, method="plcg_scan", maxiter=20, n=999)
    # declared options still pass through to the method bodies
    r = solve(A, b, method="cg", tol=1e-8, maxiter=300,
              trace_true_residual=True)
    assert r.converged and r.true_resnorms is not None


# ------------------------- micro-batched dispatch -------------------------

def test_submit_returns_pending_handle_and_result_flushes(poisson):
    A, b = poisson
    solver = Solver(A, "plcg_scan", **KW)
    h = solver.submit(b)
    assert isinstance(h, SolveHandle) and not h.done
    assert solver.pending == 1
    r = h.result()                      # implicit flush
    assert h.done and solver.pending == 0
    assert r.converged
    # a lone request still takes the batched sweep: pooled lanes keep
    # ONE contract (masked single sweep) regardless of queue depth
    assert r.info["pooled"] and r.info["flush_nrhs"] == 1
    assert np.linalg.norm(b - np.asarray(A @ np.asarray(r.x))) < 5e-7


def test_pool_packs_queue_into_one_batched_call():
    """Acceptance: >= 4 queued RHS pack into ONE batched sweep call, with
    per-RHS results matching one-shot solve() -- bitwise against the
    shape-identical batched one-shot, <= 1e-10 rel against per-RHS
    single solves.  (Fresh operator: the trace-count gate must not hit
    engines other tests already compiled for the shared fixture.)"""
    A = poisson2d(20, 20)
    B = _batch(A, 4, seed=3)
    solver = Solver(A, "plcg_scan", **KW)
    pool = SolverPool(solver, max_batch=8)
    handles = [pool.submit(B[j]) for j in range(4)]
    clear_batch_trace()
    recs = pool.flush()
    assert recs == [(4, 4)]             # one batch, no padding (bucket 4)
    assert len(engine.BATCH_TRACE_EVENTS) == 1          # ONE sweep call
    assert engine.BATCH_TRACE_EVENTS[0][1] == (4, A.n)
    rb = solve(A, B, method="plcg_scan", **KW)          # one-shot batched
    for j, h in enumerate(handles):
        r = h.result()
        assert r.converged and r.info["pooled"] and r.info["lane"] == j
        assert np.array_equal(np.asarray(r.x), np.asarray(rb.x)[j])
        rj = solve(A, B[j], method="plcg_scan", **KW)   # one-shot single
        rel = (np.linalg.norm(np.asarray(r.x) - np.asarray(rj.x))
               / np.linalg.norm(np.asarray(rj.x)))
        assert rel <= 1e-10
    assert pool.occupancy == 1.0


def test_pool_pad_bucketing_bounds_compilations():
    """5 pending RHS pad to the 8-bucket; a later 3-RHS flush reuses a
    smaller bucket -- repeated ragged queue depths touch at most the
    bucket ladder's worth of batch shapes.  (Fresh operator, same reason
    as above.)"""
    A = poisson2d(20, 20)
    B = _batch(A, 5, seed=4)
    solver = Solver(A, "plcg_scan", **KW)
    pool = SolverPool(solver, max_batch=8)
    assert pool.buckets == (1, 2, 4, 8)
    hs = [pool.submit(B[j]) for j in range(5)]
    clear_batch_trace()
    assert pool.flush() == [(5, 8)]
    assert engine.BATCH_TRACE_EVENTS[0][1] == (8, A.n)  # padded shape
    for j, h in enumerate(hs):
        r = h.result()
        assert r.converged and r.info["flush_pad"] == 8
        rj = solve(A, B[j], method="plcg_scan", **KW)
        rel = (np.linalg.norm(np.asarray(r.x) - np.asarray(rj.x))
               / np.linalg.norm(np.asarray(rj.x)))
        assert rel <= 1e-8
    assert pool.occupancy == 5 / 8
    # ragged re-flush hits the 4-bucket: a second distinct shape, not a
    # third -- and a SECOND flush of depth 3 adds no new trace
    for j in range(3):
        pool.submit(B[j])
    assert pool.flush() == [(3, 4)]
    shapes = {e[1] for e in engine.BATCH_TRACE_EVENTS}
    assert shapes == {(8, A.n), (4, A.n)}
    for j in range(3):
        pool.submit(B[j])
    n_events = len(engine.BATCH_TRACE_EVENTS)
    assert pool.flush() == [(3, 4)]
    assert len(engine.BATCH_TRACE_EVENTS) == n_events   # zero retraces


def test_pool_chunks_above_max_batch(poisson):
    A, _ = poisson
    B = _batch(A, 6, seed=5)
    solver = Solver(A, "plcg_scan", **KW)
    pool = SolverPool(solver, max_batch=4)
    hs = [pool.submit(B[j]) for j in range(6)]
    assert pool.flush() == [(4, 4), (2, 2)]
    assert all(h.done for h in hs)
    assert pool.stats["lanes_real"] == 6


def test_pool_rejects_mixed_shapes_and_keeps_handles_resolvable(poisson):
    A, b = poisson
    solver = Solver(A, "plcg_scan", **KW)
    h1 = solver.submit(b)
    h2 = solver.submit(b[: A.n // 2])
    with pytest.raises(ValueError, match="mixed RHS shapes"):
        solver.flush()
    # the failed chunk stays queued (handles are not orphaned); dropping
    # the malformed request lets the good one resolve
    assert solver.pending == 2 and not h1.done
    solver._pending = [p for p in solver._pending if p[2] is not h2]
    assert h1.result().converged
    assert solver.pending == 0


def test_pool_loop_method_falls_back_per_rhs(poisson):
    """Micro-batching needs a batched engine; loop methods still serve
    the queue correctly, one solve per handle."""
    A, _ = poisson
    B = _batch(A, 3, seed=6)
    solver = Solver(A, "cg", tol=1e-10, maxiter=400)
    pool = SolverPool(solver, max_batch=4)
    hs = [pool.submit(B[j]) for j in range(3)]
    pool.flush()
    for j, h in enumerate(hs):
        rj = solve(A, B[j], method="cg", tol=1e-10, maxiter=400)
        assert np.allclose(np.asarray(h.result().x), np.asarray(rj.x))


def test_default_buckets():
    assert _default_buckets(8) == (1, 2, 4, 8)
    assert _default_buckets(6) == (1, 2, 4, 6)
    assert _default_buckets(1) == (1,)


# ------------------------------ mesh path ---------------------------------

def test_prepared_solver_on_mesh_matches_one_shot(poisson, mesh11):
    A, b = poisson
    solver = Solver(A, "plcg_scan", mesh=mesh11, **KW)
    r1 = solver(b.reshape(20, 20))
    r2 = solve(A, b.reshape(20, 20), method="plcg_scan", mesh=mesh11, **KW)
    assert r1.converged
    assert np.array_equal(np.asarray(r1.x), np.asarray(r2.x))
    assert r1.info["psums_per_iter"] == 1
    # repeated calls reuse the strongly-held mesh sweep: no new builds
    builds = solver._mesh_session.builds
    counts = solver.compile_counts()
    solver(b.reshape(20, 20))
    assert solver._mesh_session.builds == builds
    assert solver.compile_counts() == counts            # zero retraces


def test_pool_on_mesh_packs_into_one_sweep(mesh11):
    """Acceptance (mesh variant): >= 4 queued (nx, ny) fields pack into
    one shard_map(vmap) sweep; per-RHS results match one-shot mesh
    solve() bitwise and per-RHS single mesh solves to <= 1e-10."""
    A = poisson2d(20, 20)
    B = _batch(A, 4, seed=7).reshape(4, 20, 20)
    solver = Solver(A, "plcg_scan", mesh=mesh11, **KW)
    pool = SolverPool(solver, max_batch=8)
    hs = [pool.submit(B[j]) for j in range(4)]
    clear_batch_trace()
    assert pool.flush() == [(4, 4)]
    assert [e[0] for e in engine.BATCH_TRACE_EVENTS] == ["plcg@mesh"]
    rb = solve(A, B, method="plcg_scan", mesh=mesh11, **KW)
    for j, h in enumerate(hs):
        r = h.result()
        assert r.converged
        assert np.array_equal(np.asarray(r.x), np.asarray(rb.x)[j])
        rj = solve(A, B[j], method="plcg_scan", mesh=mesh11, **KW)
        rel = (np.linalg.norm(np.asarray(r.x) - np.asarray(rj.x))
               / np.linalg.norm(np.asarray(rj.x)))
        assert rel <= 1e-10


def test_pool_on_4device_mesh(poisson):
    """Acceptance: the pooled path on a REAL (2, 2) decomposition -- live
    halo pairs and a genuinely distributed psum -- matches per-RHS
    one-shot mesh solves to <= 1e-10."""
    if len(jax.devices()) < 4:
        pytest.skip("needs >= 4 host devices (CI serve lane forces 4)")
    A, _ = poisson
    mesh = make_mesh_compat((2, 2), ("data", "model"))
    B = _batch(A, 4, seed=8).reshape(4, 20, 20)
    solver = Solver(A, "plcg_scan", mesh=mesh, **KW)
    pool = SolverPool(solver, max_batch=4)
    hs = [pool.submit(B[j]) for j in range(4)]
    assert pool.flush() == [(4, 4)]
    for j, h in enumerate(hs):
        r = h.result()
        assert r.converged
        rj = solve(A, B[j], method="plcg_scan", mesh=mesh, **KW)
        rel = (np.linalg.norm(np.asarray(r.x) - np.asarray(rj.x))
               / np.linalg.norm(np.asarray(rj.x)))
        assert rel <= 1e-10


# -------------------- solver-cache interaction ----------------------------

def test_live_solver_survives_clear_solver_cache(poisson):
    """Satellite: a live Solver holds its compiled sweeps strongly --
    clear_solver_cache() empties the weak-key caches without touching
    the session, which keeps solving with zero rebuilds/retraces."""
    from repro.core.plcg_scan import _SWEEP_CACHE

    A, b = poisson
    clear_solver_cache()
    gc.collect()
    solver = Solver(A, "plcg_scan", **KW)
    r1 = solver(b)
    assert len(_SWEEP_CACHE) >= 1
    builds = solver.stats["prepared_builds"]
    counts = solver.compile_counts()
    clear_solver_cache()
    assert len(_SWEEP_CACHE) == 0
    r2 = solver(b)
    assert np.array_equal(np.asarray(r1.x), np.asarray(r2.x))
    assert solver.stats["prepared_builds"] == builds    # no rebuild
    assert solver.compile_counts() == counts            # no retrace
    clear_solver_cache()


def test_dropping_solver_releases_operator(poisson):
    """Satellite (extends the PR-2/PR-4 eviction tests): the session pins
    the operator while alive -- dropping the user's own reference leaks
    nothing new -- and dropping the LAST Solver reference releases the
    operator and evicts its weak-cache entries."""
    from repro.core.plcg_scan import _SWEEP_CACHE

    clear_solver_cache()
    gc.collect()
    A = poisson2d(16, 16)
    b = jnp.asarray(np.asarray(A @ np.ones(A.n)))
    wr = weakref.ref(A)
    solver = Solver(A, "plcg_scan", l=2, tol=1e-8, maxiter=100,
                    spectrum=(0.0, 8.0))
    assert solver(b).converged
    assert len(_SWEEP_CACHE) == 1
    del A
    gc.collect()
    assert wr() is not None             # the live session pins the operator
    assert solver(b).converged          # and keeps solving
    del solver
    gc.collect()
    assert wr() is None                 # last reference gone -> released
    assert len(_SWEEP_CACHE) == 0       # weak-cache entry evicted
    clear_solver_cache()
