"""Backend-ladder parity: the four kernel tiers of the scan engine
(``None`` inline jnp / ``"ref"`` jnp oracles / ``"pallas"`` per-kernel /
``"fused"`` single-launch megakernel) must agree to tight f64 tolerance on
the tier-1 Poisson systems, single-RHS and batched, preconditioned and
not -- and the fused tier must actually be ONE Pallas launch per
iteration (structural jaxpr gate; CPU wall time is not probative)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.plcg_scan import plcg_scan
from repro.core.shifts import chebyshev_shifts
from repro.kernels.introspect import count_pallas_calls
from repro.operators import poisson2d

BACKENDS = ["ref", "pallas", "fused"]


@pytest.fixture(scope="module", autouse=True)
def x64():
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", old)


@pytest.fixture(scope="module")
def problem():
    A = poisson2d(20, 20)
    b = jnp.asarray(A @ np.ones(A.n))
    return A, b


def _run(A, b, l, backend, prec=None, iters=100, tol=1e-10, stencil=True):
    interval = (0, 2) if prec is not None else (0, 8)
    return plcg_scan(A.matvec, b, l=l, iters=iters,
                     sigma=tuple(chebyshev_shifts(*interval, l)), tol=tol,
                     prec=prec, backend=backend,
                     stencil_hw=A.stencil2d if stencil else None)


def _rel(a, b):
    return float(jnp.linalg.norm(a - b) / jnp.linalg.norm(b))


@pytest.mark.parametrize("l", [1, 2])
@pytest.mark.parametrize("prec", [None, "jacobi"])
@pytest.mark.parametrize("backend", BACKENDS)
def test_single_rhs_matches_inline_f64(problem, l, prec, backend):
    """Acceptance: every kernel tier reproduces the inline jnp engine to
    <= 1e-12 relative at f64 on the tier-1 Poisson system."""
    A, b = problem
    M = (lambda v: v / 4.0) if prec else None
    base = _run(A, b, l, None, prec=M)
    out = _run(A, b, l, backend, prec=M)
    assert bool(base.converged) and bool(out.converged)
    assert _rel(out.x, base.x) <= 1e-12


@pytest.mark.parametrize("backend", BACKENDS)
def test_deep_pipeline_l4_tier_parity(problem, backend):
    """At l=4 the pipeline hits square-root breakdown (paper Sec. 4:
    attainable accuracy degrades with depth) and post-breakdown roundoff
    is amplified, so the tiers are compared against the 'ref' oracle
    (identical accumulation order) to 1e-12 and against the inline engine
    on the pre-breakdown residual trace."""
    A, b = problem
    l = 4
    base = _run(A, b, l, "ref", iters=40, tol=0.0)
    out = _run(A, b, l, backend, iters=40, tol=0.0)
    assert _rel(out.x, base.x) <= 1e-12
    inline = _run(A, b, l, None, iters=40, tol=0.0)
    ri, ro = np.asarray(inline.resnorms), np.asarray(out.resnorms)
    np.testing.assert_allclose(ro[l:30], ri[l:30], rtol=1e-6)


def test_fused_without_stencil_hint_matches(problem):
    """A generic matvec (no stencil2d structural hint) streams t into the
    megakernel instead of fusing the SPMV -- results are identical."""
    A, b = problem
    with_hint = _run(A, b, 2, "fused", stencil=True)
    without = _run(A, b, 2, "fused", stencil=False)
    assert _rel(without.x, with_hint.x) <= 1e-13


@pytest.mark.parametrize("backend", BACKENDS)
def test_batched_matches_single_rhs(problem, backend):
    """The lane-major (B, n, window) batched path reproduces per-lane
    single-RHS runs across every tier."""
    A, b = problem
    rng = np.random.default_rng(0)
    B = jnp.stack([b, jnp.asarray(A @ rng.standard_normal(A.n))])
    sig = tuple(chebyshev_shifts(0, 8, 2))
    fn = jax.jit(jax.vmap(lambda bb: plcg_scan(
        A.matvec, bb, l=2, iters=100, sigma=sig, tol=1e-10,
        backend=backend, stencil_hw=A.stencil2d)))
    out = fn(B)
    assert np.asarray(out.converged).all()
    for j in range(2):
        single = plcg_scan(A.matvec, B[j], l=2, iters=100, sigma=sig,
                           tol=1e-10, backend=backend,
                           stencil_hw=A.stencil2d)
        assert _rel(out.x[j], single.x) <= 1e-12


def test_solve_front_end_fused_tier(problem):
    """backend='fused' threads through repro.core.solve (which picks up
    the stencil2d hint from the operator) for 1-D and 2-D RHS."""
    from repro.core import solve
    A, b = problem
    r0 = solve(A, b, method="plcg_scan", l=2, tol=1e-10, maxiter=200,
               spectrum=(0.0, 8.0), backend=None)
    r1 = solve(A, b, method="plcg_scan", l=2, tol=1e-10, maxiter=200,
               spectrum=(0.0, 8.0), backend="fused")
    assert r0.converged and r1.converged
    assert _rel(jnp.asarray(r1.x), jnp.asarray(r0.x)) <= 1e-12
    Bb = np.stack([np.asarray(b), np.asarray(b) * 0.5])
    rb = solve(A, Bb, method="plcg_scan", l=2, tol=1e-10, maxiter=200,
               spectrum=(0.0, 8.0), backend="fused")
    assert rb.converged
    assert _rel(jnp.asarray(rb.x[0]), jnp.asarray(r0.x)) <= 1e-12


# ------------------------- bf16 storage parity ----------------------------

def _run_bf16(A, b, l, backend, iters):
    return plcg_scan(A.matvec, b, l=l, iters=iters,
                     sigma=tuple(chebyshev_shifts(0, 8, l)), tol=0.0,
                     backend=backend, stencil_hw=A.stencil2d,
                     precision="bf16")


@pytest.mark.parametrize("l", [1, 2, 3])
def test_bf16_storage_tier_parity(problem, l):
    """Under ``precision="bf16"`` every tier stores the same bf16 windows
    and streams, so the tiers still track each other: 'pallas' reproduces
    'ref' bitwise (same kernels, same accumulation order), and the inline
    and fused tiers differ only by f32-vs-f64 dot accumulation on
    bf16-rounded data -- orders of magnitude below the bf16 storage eps
    at a pre-floor horizon."""
    A, b = problem
    iters = 30
    eps = float(jnp.finfo(jnp.bfloat16).eps)
    ref = _run_bf16(A, b, l, "ref", iters)
    assert _rel(_run_bf16(A, b, l, "pallas", iters).x, ref.x) <= 1e-10
    assert _rel(_run_bf16(A, b, l, None, iters).x, ref.x) <= eps / 2
    assert _rel(_run_bf16(A, b, l, "fused", iters).x, ref.x) <= eps / 2


@pytest.mark.parametrize("backend", [None] + BACKENDS)
def test_bf16_reaches_storage_floor(problem, backend):
    """At l=1 every tier converges to the bf16 attainable-accuracy floor
    (~eps_bf16-scaled true residual) without breakdown."""
    A, b = problem
    out = plcg_scan(A.matvec, b, l=1, iters=120,
                    sigma=tuple(chebyshev_shifts(0, 8, 1)), tol=0.1,
                    backend=backend, stencil_hw=A.stencil2d,
                    precision="bf16")
    assert bool(out.converged) and not bool(out.breakdown)
    true = _rel(jnp.asarray(A @ np.asarray(out.x)), b)
    assert true <= 0.1


# ------------------------- structural launch gates ------------------------

def _launches(A, b, backend, **kw):
    sig = tuple(chebyshev_shifts(0, 8, 2))
    return count_pallas_calls(
        lambda bb: plcg_scan(A.matvec, bb, l=2, iters=8, sigma=sig,
                             backend=backend, **kw), b)


def test_fused_is_one_launch_per_iteration(problem):
    """Acceptance: the fused tier traces to exactly ONE pallas_call in the
    scan body; the per-kernel pallas tier needs one per hot-path kernel."""
    A, b = problem
    n_pallas = _launches(A, b, "pallas")
    n_fused = _launches(A, b, "fused", stencil_hw=A.stencil2d)
    n_fused_nostencil = _launches(A, b, "fused")
    assert n_fused == 1
    assert n_fused_nostencil == 1
    assert n_pallas >= 3
    assert n_fused < n_pallas


def test_bf16_fused_is_still_one_launch(problem):
    """Acceptance: ``precision="bf16"`` must not un-fuse the megakernel --
    the storage casts live inside the one launch (and at the scan
    boundary), never as extra pallas_calls."""
    A, b = problem
    assert _launches(A, b, "fused", stencil_hw=A.stencil2d,
                     precision="bf16") == 1
    assert _launches(A, b, "fused", precision="bf16") == 1


def test_batched_fused_is_still_one_launch(problem):
    """vmap over the fused engine must not replay the kernel per lane."""
    A, b = problem
    sig = tuple(chebyshev_shifts(0, 8, 2))
    B = jnp.stack([b, b * 2.0, b * 3.0])
    n = count_pallas_calls(
        lambda BB: jax.vmap(lambda bb: plcg_scan(
            A.matvec, bb, l=2, iters=8, sigma=sig, backend="fused",
            stencil_hw=A.stencil2d))(BB), B)
    assert n == 1


def test_distributed_injected_dots_bypass_kernels(problem):
    """With injected local dots (the shard_map runtime), every kernel tier
    -- including 'fused' -- is bypassed: zero pallas_call equations."""
    A, b = problem
    sig = tuple(chebyshev_shifts(0, 8, 2))
    for backend in (None, "pallas", "ref", "fused"):
        n = count_pallas_calls(
            lambda bb: plcg_scan(
                A.matvec, bb, l=2, iters=8, sigma=sig, backend=backend,
                stencil_hw=A.stencil2d,
                dot_local=lambda u, v: jnp.sum(u * v),
                reduce_scalars=lambda p: p), b)
        assert n == 0, backend


def test_backend_rejects_unknown(problem):
    A, b = problem
    with pytest.raises(ValueError, match="backend"):
        plcg_scan(A.matvec, b, l=1, iters=4, sigma=(4.0,), backend="cuda")
