"""Preconditioning as a first-class layer (paper Sec. 6, Alg. 4).

Covers the `repro.core.precond` protocol end to end: promotion and the
Identity collapse, the registry capability flags (uniform M=/mesh=
errors), Jacobi/BlockJacobi/Chebyshev numerics, the diag-fused Pallas
megakernel gate (ONE launch per steady-state body with a Jacobi prec),
the mesh execution path (shard-local applies, still exactly ONE stacked
psum per iteration, single-device parity), solver-cache eviction when a
Preconditioner object dies (extending the PR-3 reentrant `_on_death`
fix), and the residual-gap diagnostics of arXiv:1804.02962.

Multi-device coverage: `test_mesh_blockjacobi_parity_on_available_devices`
runs a live (2, 2) decomposition when the main process has >= 4 devices
(the CI preconditioned lane forces 4 via XLA_FLAGS) and skips elsewhere;
every other test runs in-process on a (1, 1) mesh, where collective
semantics are identical.
"""
import gc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BlockJacobi, Chebyshev, Identity, Jacobi,
                        as_preconditioner, methods_supporting,
                        residual_gap, solve)
from repro.core.precond import (Preconditioner, _block_stencil5,
                                chebyshev_inverse_apply)
from repro.core.shifts import chebyshev_shifts
from repro.launch.mesh import make_mesh_compat
from repro.operators import poisson2d
from repro.operators.precond import jacobi


@pytest.fixture(scope="module", autouse=True)
def x64_mod():
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", old)


@pytest.fixture(scope="module")
def poisson():
    A = poisson2d(32, 32)
    b = np.asarray(A @ np.ones(A.n))
    return A, b


@pytest.fixture(scope="module")
def mesh11():
    return make_mesh_compat((1, 1), ("data", "model"))


# ----------------------- protocol & promotion -----------------------------

def test_identity_collapse_and_promotion(poisson):
    """M=None, M=Identity() and a bare identity callable are the same
    solve: the engines collapse the identity into the cheap
    unpreconditioned pipeline (3l+2, not 3l+5, vectors)."""
    A, b = poisson
    assert as_preconditioner(None).is_identity
    assert as_preconditioner(None).runtime() is None
    assert as_preconditioner(Identity()).runtime() is None
    M = as_preconditioner(lambda v: v * 1.0)
    assert isinstance(M, Preconditioner) and not M.is_identity
    kw = dict(method="plcg_scan", l=2, tol=1e-10, maxiter=200,
              spectrum=(0.0, 8.0))
    r0 = solve(A, b, **kw)
    r1 = solve(A, b, M=Identity(), **kw)
    assert r0.iters == r1.iters
    assert np.allclose(np.asarray(r0.x), np.asarray(r1.x), atol=0)
    with pytest.raises(TypeError, match="preconditioner"):
        as_preconditioner(42)


def test_legacy_dataclass_preconditioner_still_dispatches(poisson):
    """The pre-refactor linop.Preconditioner dataclass (still returned by
    operators.block_jacobi_ssor) promotes through as_preconditioner."""
    from repro.core.linop import Preconditioner as LegacyPrec
    A, b = poisson
    legacy = LegacyPrec(apply=lambda v: v / 4.0, name="legacy")
    r = solve(A, b, method="cg", tol=1e-10, maxiter=400, M=legacy)
    assert r.converged


# -------------------------- capability flags ------------------------------

def test_registry_capability_flags():
    assert methods_supporting("M") == ("cg", "dlanczos", "pcg", "plcg",
                                       "plcg_scan")
    assert methods_supporting("mesh") == ("cg", "plcg", "plcg_scan")


def test_uniform_M_rejection_lists_supporting_methods(poisson):
    A, b = poisson
    with pytest.raises(ValueError, match=r"plminres.*does not support "
                                         r"preconditioning"):
        solve(A, b, method="plminres", M=lambda v: v)
    # the message documents the alternatives
    with pytest.raises(ValueError, match="cg, dlanczos, pcg, plcg, "
                                         "plcg_scan"):
        solve(A, b, method="plminres", M=lambda v: v)
    # Identity does NOT trip the flag: it is the unpreconditioned solve
    r = solve(A, b, method="plminres", l=2, tol=1e-8, maxiter=150,
              M=Identity(), spectrum=(0.0, 8.0))
    assert r.info["method"]
    # direct registry invocation (bypassing solve) must not silently
    # drop M either
    from repro.core import get_method
    with pytest.raises(ValueError, match="plminres does not support"):
        get_method("plminres").fn(A, b, M=lambda v: v)


def test_uniform_mesh_rejection_lists_mesh_methods(poisson, mesh11):
    A, b = poisson
    for m in ("pcg", "dlanczos", "plminres"):
        with pytest.raises(ValueError, match="no mesh-aware execution "
                                             "path.*cg, plcg, plcg_scan"):
            solve(A, b.reshape(32, 32), method=m, mesh=mesh11)


def test_opaque_callable_rejected_on_mesh_with_uniform_message(poisson,
                                                               mesh11):
    A, b = poisson
    with pytest.raises(ValueError, match="shard-local.*BlockJacobi"):
        solve(A, b.reshape(32, 32), method="plcg_scan", mesh=mesh11,
              M=lambda v: v / 4.0)
    # a vector diagonal that does NOT match the operator's grid has no
    # shard split either (a matching one is shard-split -- see
    # test_sharded_diagonal_jacobi_* below)
    with pytest.raises(ValueError, match="shard-local"):
        solve(A, b.reshape(32, 32), method="cg", mesh=mesh11,
              M=Jacobi(np.linspace(3.5, 4.5, A.n // 2)))


# ------------------------------ Jacobi ------------------------------------

def test_jacobi_structure_and_defaults(poisson):
    A, b = poisson
    M = jacobi(A)                       # operators facade -> core.Jacobi
    assert isinstance(M, Jacobi)
    assert M.inv_diag == 0.25           # constant Poisson diagonal
    assert np.allclose(np.asarray(M(b)), b / 4.0)
    assert M.precond_spectrum((0.0, 8.0)) == (0.0, 2.0)
    # engine default: sigma comes from the preconditioned interval
    r = solve(A, b, method="plcg_scan", l=2, tol=1e-10, maxiter=300, M=M)
    assert r.converged
    assert max(r.info["sigma"]) < 2.0
    assert r.info["prec"] == M.name
    assert np.linalg.norm(b - np.asarray(A @ np.asarray(r.x))) < 5e-8


# ---------------------------- BlockJacobi ---------------------------------

def test_blockjacobi_is_spd_and_blockwise(poisson):
    A, b = poisson
    M = BlockJacobi((32, 32), blocks=(2, 2), degree=3)
    rng = np.random.default_rng(0)
    u, w = rng.standard_normal(A.n), rng.standard_normal(A.n)
    # symmetry in exact blocks
    assert abs(np.vdot(np.asarray(M(u)), w)
               - np.vdot(u, np.asarray(M(w)))) < 1e-12
    # positive definiteness on samples
    for _ in range(4):
        v = rng.standard_normal(A.n)
        assert float(np.vdot(v, np.asarray(M(v)))) > 0
    # blockwise apply == per-block Chebyshev inverse of the local stencil
    g = u.reshape(32, 32)
    blk = jnp.asarray(g[:16, :16])
    want = chebyshev_inverse_apply(_block_stencil5, blk, M._shifts)
    got = np.asarray(M(u)).reshape(32, 32)[:16, :16]
    np.testing.assert_allclose(got, np.asarray(want), rtol=1e-13)


def test_blockjacobi_block_grid_must_match_mesh(poisson, mesh11):
    A, b = poisson
    M = BlockJacobi((32, 32), blocks=(4, 1), degree=3)
    with pytest.raises(ValueError, match="processor grid"):
        solve(A, b.reshape(32, 32), method="plcg_scan", mesh=mesh11, M=M)


# -------------------- acceptance: preconditioned mesh ---------------------

def test_mesh_blockjacobi_matches_single_device_and_wins(poisson, mesh11):
    """ISSUE acceptance: solve(A, b, mesh=..., M=BlockJacobi(...))
    converges in fewer iterations than unpreconditioned on the Poisson
    benchmark and matches the single-device preconditioned plcg_scan
    result to <= 1e-10 relative (f64)."""
    A, b = poisson
    M = BlockJacobi.for_mesh(A, mesh11, degree=4)
    kw = dict(method="plcg_scan", l=2, tol=1e-10, maxiter=300)
    r_none = solve(A, b.reshape(32, 32), mesh=mesh11,
                   spectrum=(0.0, 8.0), **kw)
    r_mesh = solve(A, b.reshape(32, 32), mesh=mesh11, M=M, **kw)
    r_single = solve(A, b, M=M, **kw)
    assert r_mesh.converged and r_single.converged
    assert r_mesh.iters < r_none.iters          # preconditioning wins
    xm = np.asarray(r_mesh.x).reshape(-1)
    xs = np.asarray(r_single.x)
    assert (np.linalg.norm(xm - xs) <= 1e-10 * np.linalg.norm(xs))
    assert r_mesh.info["psums_per_iter"] == 1
    assert r_mesh.info["prec"] == M.name


def test_mesh_batched_preconditioned_matches_batched_engine(poisson,
                                                            mesh11):
    """(nrhs, nx, ny) + BlockJacobi: RHS vmap outside, shard-local prec
    inside, ONE stacked psum; parity vs the single-device batched
    engine."""
    A, _ = poisson
    M = BlockJacobi.for_mesh(A, mesh11, degree=4)
    rng = np.random.default_rng(1)
    B = np.stack([np.asarray(A @ rng.standard_normal(A.n))
                  for _ in range(3)])
    kw = dict(method="plcg_scan", l=2, tol=1e-10, maxiter=300, M=M)
    ref = solve(A, B, **kw)
    r = solve(A, B.reshape(3, 32, 32), mesh=mesh11, **kw)
    assert r.converged
    xm = np.asarray(r.x).reshape(3, -1)
    for j in range(3):
        xs = np.asarray(ref.x)[j]
        assert np.linalg.norm(xm[j] - xs) <= 1e-10 * np.linalg.norm(xs)
    assert r.info["batched"] == "shard_map+vmap"
    assert r.info["psums_per_iter"] == 1


def test_mesh_blockjacobi_parity_on_available_devices(poisson):
    """CI preconditioned lane: on >= 4 host devices, a REAL (2, 2)
    decomposition with shard-local BlockJacobi -- live halo pairs,
    partial dots, one stacked psum -- matches the single-device
    preconditioned engine to <= 1e-10."""
    if len(jax.devices()) < 4:
        pytest.skip("needs >= 4 host devices (CI prec lane forces 4)")
    A, b = poisson
    mesh = make_mesh_compat((2, 2), ("data", "model"))
    M = BlockJacobi.for_mesh(A, mesh, degree=4)
    kw = dict(method="plcg_scan", l=2, tol=1e-10, maxiter=300, M=M)
    r_mesh = solve(A, b.reshape(32, 32), mesh=mesh, **kw)
    r_single = solve(A, b, **kw)
    assert r_mesh.converged
    xm = np.asarray(r_mesh.x).reshape(-1)
    xs = np.asarray(r_single.x)
    assert np.linalg.norm(xm - xs) <= 1e-10 * np.linalg.norm(xs)


def test_one_psum_per_iteration_with_preconditioner(mesh11):
    """Structural jaxpr gate: the preconditioned pipelined sweep still
    carries exactly ONE psum per scan iteration (BlockJacobi adds zero
    collectives; Chebyshev adds halo ppermutes only), and preconditioned
    mesh CG stays at the baseline's two."""
    from repro.distributed import (DistPoisson, cg_mesh_sweep,
                                   plcg_mesh_sweep)
    from repro.kernels.introspect import count_primitive_in_scan_bodies

    op = DistPoisson(16, 16, mesh11)
    sig = tuple(chebyshev_shifts(0, 1.3, 2))
    b = jnp.ones((16, 16))
    b3 = jnp.ones((3, 16, 16))
    M = BlockJacobi((16, 16), blocks=(1, 1), degree=3)
    fp = plcg_mesh_sweep(op, l=2, iters=30, sigma=sig, tol=1e-8, prec=M)
    assert count_primitive_in_scan_bodies(fp, "psum", b, b * 0, 30) == [1]
    assert count_primitive_in_scan_bodies(fp, "ppermute",
                                          b, b * 0, 30) == [4]
    fb = plcg_mesh_sweep(op, l=2, iters=30, sigma=sig, tol=1e-8, prec=M,
                         batched=True)
    assert count_primitive_in_scan_bodies(fb, "psum",
                                          b3, b3 * 0, 30) == [1]
    C = Chebyshev(op, spectrum=(0.5, 8.0), degree=3)
    fc = plcg_mesh_sweep(op, l=2, iters=30, sigma=sig, tol=1e-8, prec=C)
    assert count_primitive_in_scan_bodies(fc, "psum", b, b * 0, 30) == [1]
    # degree-1 = 2 extra local SPMVs -> 4 ppermutes each, neighbor only
    assert count_primitive_in_scan_bodies(fc, "ppermute",
                                          b, b * 0, 30) == [12]
    J = Jacobi(4.0)
    fq = cg_mesh_sweep(op, iters=30, tol=1e-8, prec=J)
    assert count_primitive_in_scan_bodies(fq, "psum", b, b * 0) == [2]


def test_mesh_chebyshev_and_cg_preconditioned_solve(poisson, mesh11):
    A, b = poisson
    C = Chebyshev(A, spectrum=(0.5, 8.0), degree=3)
    kw = dict(l=2, tol=1e-10, maxiter=300)
    r_none = solve(A, b.reshape(32, 32), method="plcg_scan",
                   spectrum=(0.0, 8.0), mesh=mesh11, **kw)
    r = solve(A, b.reshape(32, 32), method="plcg_scan", mesh=mesh11,
              M=C, **kw)
    assert r.converged and r.iters < r_none.iters
    res = np.linalg.norm(b - np.asarray(A @ np.asarray(r.x).reshape(-1)))
    assert res < 5e-8
    # preconditioned mesh CG (scalar Jacobi is a pure rescale on Poisson:
    # same iterates as unpreconditioned -- the contract is it RUNS and
    # converges with 2 psums)
    rc = solve(A, b.reshape(32, 32), method="cg", tol=1e-10, maxiter=400,
               mesh=mesh11, M=jacobi(A))
    assert rc.converged and rc.info["psums_per_iter"] == 2
    err = np.linalg.norm(np.asarray(rc.x).reshape(-1) - 1.0)
    assert err < 1e-6


def test_sharded_diagonal_jacobi_matches_single_device(poisson, mesh11):
    """ROADMAP/PR-4 follow-up: a FULL (n,) diagonal Jacobi runs on the
    mesh path by shard-splitting the inverse diagonal through the
    operator's processor grid -- parity with the single-device
    preconditioned engine to <= 1e-10, zero added collectives."""
    A, b = poisson
    # genuinely varying SPD diagonal (a constant one would collapse to
    # the scalar shard-local path and prove nothing)
    d = 4.0 + 0.5 * np.sin(np.arange(A.n))
    M = Jacobi(d)
    assert not np.isscalar(M.inv_diag) and np.asarray(M.inv_diag).ndim == 1
    kw = dict(method="plcg_scan", l=2, tol=1e-10, maxiter=300, M=M)
    r_single = solve(A, b, **kw)
    r_mesh = solve(A, b.reshape(32, 32), mesh=mesh11, **kw)
    assert r_mesh.converged
    xm = np.asarray(r_mesh.x).reshape(-1)
    xs = np.asarray(r_single.x)
    # both paths converge independently to tol=1e-10 (the injected mesh
    # dots round differently from the full-vector vdot)
    assert np.linalg.norm(xm - xs) <= 1e-9 * np.linalg.norm(xs)
    assert np.linalg.norm(b - np.asarray(A @ xm)) < 5e-8
    # mesh CG with the sharded diagonal keeps the two-psum baseline
    rc = solve(A, b.reshape(32, 32), method="cg", tol=1e-10, maxiter=400,
               mesh=mesh11, M=M)
    assert rc.converged and rc.info["psums_per_iter"] == 2


def test_sharded_diagonal_jacobi_keeps_one_psum(mesh11):
    """Structural jaxpr gate: the shard-split diagonal apply is an
    elementwise multiply of a dynamic-sliced replicated constant -- no
    collective, so the pipelined sweep stays at exactly ONE psum (and
    the baseline 4 halo ppermutes) per iteration."""
    from repro.distributed import DistPoisson, plcg_mesh_sweep
    from repro.kernels.introspect import count_primitive_in_scan_bodies

    op = DistPoisson(16, 16, mesh11)
    M = Jacobi(4.0 + 0.5 * np.sin(np.arange(256)))
    local = op.prec_local(M)
    assert local is not None            # shard split resolved
    sig = tuple(chebyshev_shifts(0, 2, 2))
    b = jnp.ones((16, 16))
    fp = plcg_mesh_sweep(op, l=2, iters=30, sigma=sig, tol=1e-8, prec=M)
    assert count_primitive_in_scan_bodies(fp, "psum", b, b * 0, 30) == [1]
    assert count_primitive_in_scan_bodies(fp, "ppermute",
                                          b, b * 0, 30) == [4]


def test_sharded_diagonal_jacobi_parity_on_available_devices(poisson):
    """On >= 4 host devices (CI preconditioned lane), the shard-split
    diagonal runs a REAL (2, 2) decomposition: each shard slices a
    different block of the inverse diagonal, and the result still
    matches the single-device preconditioned engine to <= 1e-10."""
    if len(jax.devices()) < 4:
        pytest.skip("needs >= 4 host devices (CI prec lane forces 4)")
    A, b = poisson
    M = Jacobi(4.0 + 0.5 * np.sin(np.arange(A.n)))
    mesh = make_mesh_compat((2, 2), ("data", "model"))
    kw = dict(method="plcg_scan", l=2, tol=1e-10, maxiter=300, M=M)
    r_mesh = solve(A, b.reshape(32, 32), mesh=mesh, **kw)
    r_single = solve(A, b, **kw)
    assert r_mesh.converged
    xm = np.asarray(r_mesh.x).reshape(-1)
    xs = np.asarray(r_single.x)
    assert np.linalg.norm(xm - xs) <= 1e-9 * np.linalg.norm(xs)
    assert np.linalg.norm(b - np.asarray(A @ xm)) < 5e-8


# -------------------- fused megakernel launch gates -----------------------

def test_fused_backend_with_jacobi_is_one_launch(poisson):
    """ISSUE acceptance: backend='fused' with a Jacobi prec stays at ONE
    pallas_call per steady-state body, <= 1e-12 rel parity vs the inline
    engine; a general M with a stencil hint takes the 2-launch split."""
    from repro.core.plcg_scan import plcg_scan
    from repro.kernels.introspect import count_pallas_calls

    A, b = poisson
    bj = jnp.asarray(b)
    M = jacobi(A)
    sig = tuple(chebyshev_shifts(0, 2, 2))

    def run(backend, prec, prec_diag):
        return plcg_scan(A.matvec, bj, l=2, iters=120, sigma=sig,
                         tol=1e-10, prec=prec, prec_diag=prec_diag,
                         backend=backend, stencil_hw=A.stencil2d)

    base = run(None, M, None)
    fused = run("fused", M, M.inv_diag)
    assert bool(base.converged) and bool(fused.converged)
    rel = float(jnp.linalg.norm(fused.x - base.x)
                / jnp.linalg.norm(base.x))
    assert rel <= 1e-12
    n_diag = count_pallas_calls(
        lambda bb: plcg_scan(A.matvec, bb, l=2, iters=8, sigma=sig,
                             prec=M, prec_diag=M.inv_diag,
                             backend="fused", stencil_hw=A.stencil2d), bj)
    assert n_diag == 1
    general = lambda v: v / 4.0  # noqa: E731
    n_general = count_pallas_calls(
        lambda bb: plcg_scan(A.matvec, bb, l=2, iters=8, sigma=sig,
                             prec=general, backend="fused",
                             stencil_hw=A.stencil2d), bj)
    assert n_general == 2


def test_solve_fused_jacobi_through_front_end(poisson):
    """The diag hint threads through solve() -> batched/single sweeps:
    fused+Jacobi matches the inline engine, 1-D and stacked RHS."""
    A, b = poisson
    M = jacobi(A)
    kw = dict(method="plcg_scan", l=2, tol=1e-10, maxiter=200, M=M)
    r0 = solve(A, b, backend=None, **kw)
    r1 = solve(A, b, backend="fused", **kw)
    assert r0.converged and r1.converged
    rel = (np.linalg.norm(np.asarray(r1.x) - np.asarray(r0.x))
           / np.linalg.norm(np.asarray(r0.x)))
    assert rel <= 1e-12
    B = np.stack([b, b * 0.5])
    rb = solve(A, B, backend="fused", **kw)
    assert rb.converged
    relb = (np.linalg.norm(np.asarray(rb.x)[0] - np.asarray(r0.x))
            / np.linalg.norm(np.asarray(r0.x)))
    assert relb <= 1e-12


# ----------------- solver-cache eviction (Preconditioner) -----------------

def test_sweep_cache_evicts_when_preconditioner_dies(poisson):
    """The jitted sweep is keyed weakly on (matvec, prec): dropping the
    Preconditioner object evicts the compiled sweep, exactly like a dead
    operator closure."""
    from repro.core import clear_solver_cache
    from repro.core.plcg_scan import _SWEEP_CACHE, plcg_solve

    A, b = poisson
    clear_solver_cache()
    gc.collect()
    mv = A.matvec
    M = Jacobi(4.0)
    plcg_solve(mv, jnp.asarray(b), l=2, sigma=chebyshev_shifts(0, 2, 2),
               tol=1e-10, maxiter=120, prec=M)
    assert len(_SWEEP_CACHE) == 1
    del M
    gc.collect()
    assert len(_SWEEP_CACHE) == 0
    clear_solver_cache()


def test_mesh_sweep_cache_evicts_when_preconditioner_dies(poisson, mesh11):
    from repro.core import clear_solver_cache
    from repro.distributed import as_dist_operator, plcg_mesh_sweep
    from repro.distributed.plcg_dist import _MESH_SWEEP_CACHE

    A, _ = poisson
    op = as_dist_operator(A, mesh11)
    clear_solver_cache()
    gc.collect()
    M = BlockJacobi((32, 32), blocks=(1, 1), degree=3)
    sig = tuple(chebyshev_shifts(0, 1.3, 2))
    fn = plcg_mesh_sweep(op, l=2, iters=20, sigma=sig, tol=1e-8, prec=M)
    assert plcg_mesh_sweep(op, l=2, iters=20, sigma=sig, tol=1e-8,
                           prec=M) is fn                    # cache hit
    assert len(_MESH_SWEEP_CACHE) == 1
    del fn, M
    gc.collect()
    assert len(_MESH_SWEEP_CACHE) == 0
    clear_solver_cache()


def test_cache_reentrant_death_during_clear_with_preconditioner():
    """PR-3 regression, extended to Preconditioner keys: when clear()
    drops a cached value that holds the LAST strong reference to the
    Preconditioner, the weakref callback fires reentrantly inside
    clear() -- it must defer (not mutate mid-iteration) and still leave
    the cache empty."""
    from repro.core.solver_cache import WeakCallableCache

    cache = WeakCallableCache(maxsize=4)
    M = Jacobi(4.0)
    mv = lambda v: v  # noqa: E731
    cache.get_or_build((mv, M), ("cfg",), lambda: ("sweep", M))
    ref_died = []
    import weakref
    weakref.finalize(M, lambda: ref_died.append(True))
    del M
    gc.collect()
    assert len(cache) == 1          # value still pins the preconditioner
    cache.clear()                   # reentrant _on_death fires here
    gc.collect()
    assert ref_died == [True]
    assert len(cache) == 0
    # the cache stays usable after the reentrant purge
    M2 = Jacobi(2.0)
    cache.get_or_build((mv, M2), ("cfg",), lambda: "v2")
    assert len(cache) == 1


# ----------------------- residual-gap diagnostics -------------------------

def test_residual_gap_report(poisson):
    A, b = poisson
    M = BlockJacobi((32, 32), blocks=(2, 2), degree=4)
    r = solve(A, b, method="plcg_scan", l=2, tol=1e-10, maxiter=300, M=M)
    gap = residual_gap(A, b, r)
    assert set(gap) == {"true_resnorm", "implicit_resnorm", "gap",
                        "rel_gap"}
    assert gap["true_resnorm"] < 1e-7
    # in f64, far from the attainable-accuracy floor, the implicit and
    # true residuals agree to a small relative gap
    assert gap["rel_gap"] < 1e-9
    # batched results need an explicit lane (plus that lane's b)
    B = np.stack([b, b * 2.0])
    rb = solve(A, B, method="plcg_scan", l=2, tol=1e-10, maxiter=300, M=M)
    with pytest.raises(ValueError, match="lane"):
        residual_gap(A, B[1], rb)
    gb = residual_gap(A, B[1], rb, lane=1)
    assert gb["true_resnorm"] < 1e-6 and gb["rel_gap"] < 1e-8
