"""Communication-hiding tests: the ``comm=`` policy ladder on the mesh.

The contract under test (paper Remark 13 + the reduction-pipelining
design of arXiv:1905.06850): the per-iteration scalar reduction may be
split -- ``psum_scatter`` at iteration k, delayed ``all_gather`` at
k+d -- or staged around a ppermute ring, WITHOUT changing the numbers:
the total consumption delay stays exactly l in every mode, so overlap
must match blocking to <= 1e-10 per lane while its scan body contains
ZERO bare psums (one reduce_scatter + one all_gather instead) and the
staging depth d is readable off the scan carry.

Structural jaxpr gates and the front-end/option contract run in-process
on a (1, 1) mesh (the traced program is mesh-size independent up to the
scattered slot width); live multi-device parity runs in subprocesses
with 8 forced host devices (``dist_env``), plus an in-process (2, 2)
parity test that activates under the CI overlap lane
(``--xla_force_host_platform_device_count=8``)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, env: dict) -> dict:
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


# ----------------------- policy normalization errors ----------------------

def test_comm_policy_validation():
    """CommPolicy is the one normalization point: bad modes, misplaced or
    out-of-range depths, and unpromotable values fail loudly there."""
    from repro.core import CommPolicy, as_comm_policy

    assert as_comm_policy(None).is_blocking
    assert as_comm_policy("overlap").mode == "overlap"
    p = CommPolicy(mode="overlap", depth=2)
    assert as_comm_policy(p) is p
    assert p.resolve_depth(5) == 2
    assert CommPolicy(mode="overlap").resolve_depth(5) == 5
    with pytest.raises(ValueError, match="comm mode"):
        CommPolicy(mode="eager")
    with pytest.raises(ValueError, match="depth applies to"):
        CommPolicy(mode="blocking", depth=1)
    with pytest.raises(ValueError, match="depth applies to"):
        CommPolicy(mode="ring", depth=2)
    with pytest.raises(ValueError, match="depth must be >= 1"):
        CommPolicy(mode="overlap", depth=0)
    with pytest.raises(TypeError, match="communication"):
        as_comm_policy(3)
    # hashable: policies key the weak sweep caches
    assert hash(CommPolicy()) == hash(CommPolicy(mode="blocking"))


def test_comm_runtime_capability_errors():
    """build_comm_runtime raises the uniform capability errors: operators
    without the split-phase form reject overlap AND ring with the same
    no-execution-path wording, and too-shallow pipelines reject staging
    that could not complete before consumption."""
    from repro.core.comm import CommPolicy, build_comm_runtime

    class Blocking:                      # minimal protocol: psum only
        pass

    for mode in ("overlap", "ring"):
        with pytest.raises(ValueError, match="no execution path"):
            build_comm_runtime(CommPolicy(mode=mode), Blocking(), l=3)

    class Ring4:                         # a (2,4)-torus worth of hops
        def ring_schedule(self):
            return (("r", ((0, 1), (1, 0)), False),) * 1 + \
                   (("c", ((0, 1), (1, 2), (2, 3), (3, 0)), True),) * 3

    with pytest.raises(ValueError, match="l >= 5"):
        build_comm_runtime(CommPolicy(mode="ring"), Ring4(), l=3)
    rt = build_comm_runtime(CommPolicy(mode="ring"), Ring4(), l=5)
    assert rt.mode == "ring" and len(rt.schedule) == 4

    class Split(Blocking):
        mesh = type("M", (), {"shape": {"data": 2, "model": 2}})()

        def reduce_scalars_start(self, p):
            return p

        def reduce_scalars_finish(self, s, w):
            return s

    with pytest.raises(ValueError, match="1 <= depth <= l"):
        build_comm_runtime(CommPolicy(mode="overlap", depth=4), Split(), l=3)
    rt = build_comm_runtime(CommPolicy(mode="overlap"), Split(), l=3)
    assert rt.depth == 3 and rt.nshards == 4
    assert build_comm_runtime(CommPolicy(), Split(), l=3) is None


def test_front_end_rejects_comm_uniformly(x64):
    """The engine's knob table rejects comm= up front: on methods without
    the capability flag, and off-mesh where no split reduction exists --
    the same error through solve() and Solver."""
    import numpy as np
    from repro.core import Solver, solve
    from repro.launch.mesh import make_mesh_compat
    from repro.operators import poisson2d

    A = poisson2d(8, 8)
    b = np.asarray(A @ np.ones(A.n)).reshape(8, 8)
    mesh = make_mesh_compat((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="no single-device execution path"):
        solve(A, b, method="plcg_scan", comm="overlap")
    with pytest.raises(ValueError, match="does not support communication"):
        solve(A, b, method="cg", mesh=mesh, comm="overlap")
    with pytest.raises(ValueError, match="no single-device execution path"):
        Solver(A, method="plcg_scan", comm="overlap")
    with pytest.raises(ValueError, match="does not support communication"):
        Solver(A, method="cg", mesh=mesh, comm="ring")
    # comm="blocking" is the normalized default: accepted everywhere,
    # including off-mesh (it selects nothing)
    r = solve(A, b.reshape(-1), method="plcg_scan", l=1, tol=1e-8,
              maxiter=100, spectrum=(0.0, 8.0), comm="blocking")
    assert r.converged
    # capability introspection names the comm-capable methods
    from repro.core import methods_supporting
    assert set(methods_supporting("comm")) == {"plcg", "plcg_scan"}


def test_overlap_depth_validated_at_preparation(x64):
    """Depth out of range fails at Solver/prepare time (once), not inside
    the jitted sweep."""
    import numpy as np
    from repro.core import CommPolicy, Solver
    from repro.launch.mesh import make_mesh_compat
    from repro.operators import poisson2d

    A = poisson2d(8, 8)
    mesh = make_mesh_compat((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="1 <= depth <= l"):
        Solver(A, method="plcg_scan", l=2, spectrum=(0.0, 8.0), mesh=mesh,
               comm=CommPolicy(mode="overlap", depth=3))


# -------------------- structural: the split is in the jaxpr ---------------

def test_overlap_scan_body_collective_signature(x64):
    """The traced scan body carries the policy's structural signature:
    blocking = one bare psum; overlap = one reduce_scatter + one
    all_gather and ZERO psums; ring = ppermutes only.  Halo exchange is
    4 ppermutes throughout.  Identical for the batched sweep -- all
    lanes ride the same split reduction."""
    import jax.numpy as jnp
    from repro.core.shifts import chebyshev_shifts
    from repro.distributed import DistPoisson, plcg_mesh_sweep
    from repro.kernels.introspect import count_collectives_in_scan_bodies
    from repro.launch.mesh import make_mesh_compat

    mesh = make_mesh_compat((1, 1), ("data", "model"))
    op = DistPoisson(16, 16, mesh)
    sig = tuple(chebyshev_shifts(0, 8, 3))
    b = jnp.ones((16, 16))
    b3 = jnp.ones((4, 16, 16))

    def counts(comm, batched=False):
        f = plcg_mesh_sweep(op, l=3, iters=30, sigma=sig, tol=1e-8,
                            comm=comm, batched=batched)
        rhs = b3 if batched else b
        return count_collectives_in_scan_bodies(f, rhs, rhs * 0, 30)[0]

    assert counts("blocking") == {"psum": 1, "reduce_scatter": 0,
                                  "all_gather": 0, "ppermute": 4}
    assert counts("overlap") == {"psum": 0, "reduce_scatter": 1,
                                 "all_gather": 1, "ppermute": 4}
    assert counts("overlap", batched=True) == {
        "psum": 0, "reduce_scatter": 1, "all_gather": 1, "ppermute": 4}
    ring = counts("ring")
    assert ring["psum"] == 0 and ring["reduce_scatter"] == 0
    assert ring["all_gather"] == 0           # no all-reduce primitive at all


def test_overlap_staging_depth_in_scan_carry(x64):
    """The in-flight queue lives in the scan carry, so the staging depth
    d is verifiable without running: d scattered slots (plus l-d gathered
    slots when d < l), issued at k and consumed at k+d -- staged exactly
    d apart."""
    import jax.numpy as jnp
    from repro.core import CommPolicy
    from repro.core.shifts import chebyshev_shifts
    from repro.distributed import DistPoisson, plcg_mesh_sweep
    from repro.kernels.introspect import scan_carry_shapes
    from repro.launch.mesh import make_mesh_compat

    mesh = make_mesh_compat((1, 1), ("data", "model"))
    op = DistPoisson(16, 16, mesh)
    l = 3
    W = 2 * l + 1
    sig = tuple(chebyshev_shifts(0, 8, l))
    b = jnp.ones((16, 16))

    def carry(comm):
        f = plcg_mesh_sweep(op, l=l, iters=30, sigma=sig, tol=1e-8,
                            comm=comm)
        return scan_carry_shapes(f, b, b * 0, 30)[0]

    # one shard on (1,1): scattered chunk width C == W
    assert (l, W) in carry("blocking")
    full = carry(CommPolicy(mode="overlap"))             # d = l
    assert (l, W) in full
    shallow = carry(CommPolicy(mode="overlap", depth=1))
    assert (1, W) in shallow                             # 1 slot in flight
    assert (l - 1, W) in shallow                         # rest already full
    ring = carry("ring")
    assert ring.count((l, W)) >= 2                       # acc + circ buffers


# ------------------------ parity: numbers unchanged -----------------------

def test_overlap_matches_blocking_single_shard(x64):
    """On one shard the split reduction is algebraically the identity, so
    overlap (both depths) must be bit-compatible with blocking through
    the full front-end -- and the SolveResult info reports the policy."""
    import numpy as np
    from repro.core import CommPolicy, solve
    from repro.launch.mesh import make_mesh_compat
    from repro.operators import poisson2d

    mesh = make_mesh_compat((1, 1), ("data", "model"))
    nx = ny = 16
    A = poisson2d(nx, ny)
    b = np.asarray(A @ np.ones(nx * ny)).reshape(nx, ny)
    kw = dict(method="plcg_scan", l=2, tol=1e-10, maxiter=200,
              spectrum=(0.0, 8.0), mesh=mesh)
    rb = solve(A, b, **kw)
    assert rb.info["comm"] == "blocking" and rb.info["psums_per_iter"] == 1
    for comm in ("overlap", CommPolicy(mode="overlap", depth=1), "ring"):
        r = solve(A, b, comm=comm, **kw)
        assert r.converged
        assert np.linalg.norm(np.asarray(r.x) - np.asarray(rb.x)) <= 1e-10
        assert r.iters == rb.iters
        assert r.info["psums_per_iter"] == 0
    r = solve(A, b, comm="overlap", **kw)
    assert r.info["comm"] == "overlap" and r.info["overlap_depth"] == 2


def test_prepared_solver_carries_comm_policy(x64):
    """The prepared-session path: Solver(comm=...) normalizes once,
    caches per policy (blocking and overlap sweeps are distinct cache
    entries), and repeated solves reuse the prepared sweep."""
    import numpy as np
    from repro.core import Solver
    from repro.launch.mesh import make_mesh_compat
    from repro.operators import poisson2d

    mesh = make_mesh_compat((1, 1), ("data", "model"))
    nx = ny = 16
    A = poisson2d(nx, ny)
    b = np.asarray(A @ np.ones(nx * ny)).reshape(nx, ny)
    kw = dict(method="plcg_scan", l=2, tol=1e-10, maxiter=200,
              spectrum=(0.0, 8.0), mesh=mesh)
    sb = Solver(A, **kw)
    so = Solver(A, comm="overlap", **kw)
    assert so.comm.mode == "overlap" and sb.comm.is_blocking
    rb, ro = sb.solve(b), so.solve(b)
    assert np.linalg.norm(np.asarray(ro.x) - np.asarray(rb.x)) <= 1e-10
    assert ro.info["comm"] == "overlap" and ro.info["psums_per_iter"] == 0
    r2 = so.solve(b * 2.0)               # same prepared sweep, new RHS
    assert np.linalg.norm(np.asarray(r2.x) - 2 * np.asarray(ro.x)) <= 1e-8


def test_overlap_parity_on_available_devices(x64):
    """In-process multi-device parity: under the CI overlap lane (8
    forced host devices) the full policy ladder runs on a live (2, 2)
    mesh -- real psum_scatter/all_gather edges, real ring hops -- and
    every mode matches blocking to <= 1e-10 per lane.  Skips on
    single-device hosts (the slow subprocess test covers those)."""
    import jax
    import numpy as np

    if len(jax.devices()) < 4:
        pytest.skip("needs >= 4 host devices (CI overlap lane forces 8)")
    from repro.core import CommPolicy, solve
    from repro.launch.mesh import make_mesh_compat
    from repro.operators import poisson2d

    mesh = make_mesh_compat((2, 2), ("data", "model"))
    nx = ny = 32
    A = poisson2d(nx, ny)
    rng = np.random.default_rng(7)
    B = np.stack([np.asarray(A @ rng.standard_normal(A.n))
                  for _ in range(3)]).reshape(3, nx, ny)
    kw = dict(method="plcg_scan", l=3, tol=1e-10, maxiter=250,
              spectrum=(0.0, 8.0), mesh=mesh)
    rb = solve(A, B, **kw)
    xb = np.asarray(rb.x).reshape(3, -1)
    for comm in ("overlap", CommPolicy(mode="overlap", depth=1), "ring"):
        r = solve(A, B, comm=comm, **kw)
        xm = np.asarray(r.x).reshape(3, -1)
        for j in range(3):
            assert (np.linalg.norm(xm[j] - xb[j])
                    <= 1e-10 * np.linalg.norm(xb[j]))
        assert list(r.info["per_rhs_iters"]) == list(rb.info["per_rhs_iters"])


# ----------------- live multi-device payloads (subprocess) ----------------

@pytest.mark.slow
def test_overlap_matches_blocking_on_live_mesh(dist_env):
    """The acceptance gate: on a live (2, 2) mesh (8 forced host devices,
    subprocess) comm='overlap' reproduces comm='blocking' to <= 1e-10 per
    lane -- at full depth, at depth=1, and for the ring -- with the split
    structural signature in the traced body."""
    res = _run(textwrap.dedent("""
        import json, jax
        jax.config.update("jax_enable_x64", True)
        import jax.numpy as jnp, numpy as np
        from repro.core import CommPolicy, solve
        from repro.launch.mesh import make_mesh_compat
        from repro.operators import poisson2d
        mesh = make_mesh_compat((2, 2), ("data", "model"))
        nx = ny = 32
        A = poisson2d(nx, ny)
        b = np.asarray(A @ np.ones(nx * ny)).reshape(nx, ny)
        kw = dict(method="plcg", l=3, tol=1e-10, maxiter=250,
                  spectrum=(0.0, 8.0), mesh=mesh)
        rb = solve(A, b, **kw)
        out = {"conv": bool(rb.converged), "iters": int(rb.iters),
               "psums_blocking": rb.info["psums_per_iter"], "diff": {}}
        for name, comm in [("overlap", "overlap"),
                           ("overlap_d1", CommPolicy(mode="overlap", depth=1)),
                           ("ring", "ring")]:
            r = solve(A, b, comm=comm, **kw)
            out["diff"][name] = float(np.max(np.abs(
                np.asarray(r.x) - np.asarray(rb.x))))
            out.setdefault("iters_" + name, int(r.iters))
        r = solve(A, b, comm="overlap", **kw)
        out["info"] = {"comm": r.info["comm"],
                       "psums": r.info["psums_per_iter"],
                       "depth": r.info["overlap_depth"]}
        print(json.dumps(out))
    """), dist_env)
    assert res["conv"] and res["psums_blocking"] == 1
    for name, d in res["diff"].items():
        assert d <= 1e-10, (name, d)
    assert res["iters_overlap"] == res["iters"]
    assert res["info"] == {"comm": "overlap", "psums": 0, "depth": 3}


@pytest.mark.slow
def test_overlap_per_rhs_masking_across_shards(dist_env):
    """Per-RHS convergence masking survives the split reduction: the
    collectives run unconditionally every iteration (a frozen lane still
    participates in the scatter/gather), only the state commit is
    select-gated -- so a smooth lane stops early and a rough lane keeps
    iterating, exactly as under blocking."""
    res = _run(textwrap.dedent("""
        import json, jax
        jax.config.update("jax_enable_x64", True)
        import jax.numpy as jnp, numpy as np
        from repro.core import solve
        from repro.launch.mesh import make_mesh_compat
        from repro.operators import poisson2d
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        nx = ny = 32
        A = poisson2d(nx, ny)
        smooth = np.asarray(A @ np.ones(A.n))
        rough = np.asarray(
            A @ np.random.default_rng(3).standard_normal(A.n))
        B = np.stack([smooth, rough]).reshape(2, nx, ny)
        kw = dict(method="plcg_scan", l=3, tol=1e-10, maxiter=250,
                  spectrum=(0.0, 8.0), mesh=mesh)
        rb = solve(A, B, **kw)
        ro = solve(A, B, comm="overlap", **kw)
        print(json.dumps({
            "conv": [bool(c) for c in ro.info["per_rhs_converged"]],
            "iters": [int(k) for k in ro.info["per_rhs_iters"]],
            "iters_blocking": [int(k) for k in rb.info["per_rhs_iters"]],
            "trace_lens": [len(t) for t in ro.resnorms]}))
    """), dist_env)
    assert all(res["conv"])
    assert res["iters"][0] < res["iters"][1] - 10   # smooth lane stops early
    assert res["trace_lens"][0] < res["trace_lens"][1]
    assert res["iters"] == res["iters_blocking"]    # masking identical
