"""End-to-end behaviour tests: flash attention VJP, HLO analyzer, and the
full train/serve/solve paths through the public API."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.flash import flash_attention
from repro.models.layers import _direct_sdpa


@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 64)])
def test_flash_attention_matches_reference(causal, window):
    key = jax.random.PRNGKey(0)
    B, S, K, G, hd = 2, 256, 2, 3, 32
    q = jax.random.normal(key, (B, S, K, G, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, K, hd), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, K, hd), jnp.float32)

    o1 = flash_attention(q, k, v, causal, window, 64, 64)
    o2 = _direct_sdpa(q, k, v, causal=causal, window=window, q_offset=0)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)

    def f(fn):
        return lambda *a: (fn(*a) ** 2).sum() + fn(*a).sum()

    gf = jax.grad(f(lambda *a: flash_attention(*a, causal, window, 64, 64)),
                  argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f(lambda *a: _direct_sdpa(*a, causal=causal, window=window,
                                            q_offset=0)),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


def test_hlo_analyzer_counts_loop_trips():
    """cost_analysis counts a scan body once; the analyzer must multiply by
    the known trip count (the roofline depends on this)."""
    from repro.launch.hlo_analysis import analyze
    d, L = 128, 6

    def f(params, x):
        def body(h, p):
            return jnp.tanh(h @ p), None
        h, _ = jax.lax.scan(body, x, params)
        return h.sum()

    co = jax.jit(f).lower(
        jax.ShapeDtypeStruct((L, d, d), jnp.float32),
        jax.ShapeDtypeStruct((d, d), jnp.float32)).compile()
    st = analyze(co.as_text())
    assert abs(st.flops - 2 * d ** 3 * L) / (2 * d ** 3 * L) < 0.05


def test_mesh_construction():
    """make_production_mesh shape contract (uses abstract mesh on 1 CPU)."""
    from repro.launch.mesh import abstract_mesh_compat
    devs = jax.devices()
    if len(devs) < 512:
        # AbstractMesh validates the same shape/axes contract
        m = abstract_mesh_compat((2, 16, 16), ("pod", "data", "model"))
        assert m.shape == {"pod": 2, "data": 16, "model": 16}
        m1 = abstract_mesh_compat((16, 16), ("data", "model"))
        assert m1.size == 256


def test_input_specs_cover_all_cells():
    from repro.configs import ARCHS, get_config
    from repro.launch.shapes import SHAPES, input_specs, shape_applicable
    cells = ok_cells = 0
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES:
            cells += 1
            applicable, why = shape_applicable(cfg, shape)
            if not applicable:
                assert shape == "long_500k" and not cfg.subquadratic
                continue
            specs = input_specs(cfg, shape)
            assert "batch" in specs
            ok_cells += 1
    assert cells == 40
    assert ok_cells == 32          # 8 long_500k cells skipped by design


def test_solver_config_registry():
    from repro.configs import ARCHS, get_config, get_reduced
    assert len(ARCHS) == 10
    for a in ARCHS:
        cfg = get_config(a)
        red = get_reduced(a)
        assert red.d_model < cfg.d_model


def test_end_to_end_train_launcher(tmp_path):
    from repro.launch.train import main
    params = main(["--arch", "mamba2-370m", "--reduced", "--steps", "2",
                   "--batch", "2", "--seq", "32",
                   "--ckpt-dir", str(tmp_path)])
    assert params is not None


def test_end_to_end_serve_launcher():
    from repro.launch.serve import main
    out = main(["--arch", "chatglm3-6b", "--reduced", "--batch", "2",
                "--prompt-len", "8", "--gen", "4"])
    assert out.shape == (2, 4)
