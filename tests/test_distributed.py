"""Mesh execution layer tests: the distributed solvers are reachable ONLY
through the unified front-end ``repro.core.solve(A, b, mesh=...)`` and
must (a) reproduce the single-device batched engine, (b) trace to exactly
ONE fused psum per iteration (TWO for the classic-CG baseline), and
(c) mask per-RHS convergence across shards.

Multi-device payloads run in subprocesses with 8 forced host devices (the
``dist_env`` conftest fixture) so the suite is deterministic on
single-device hosts and in CI; structural jaxpr assertions and the driver
contracts run in-process on a (1, 1) mesh (collective semantics are
identical, unpaired ppermute edges = Dirichlet zeros).  Meshes are built
through the version-portable ``repro.launch.mesh.make_mesh_compat``."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, env: dict) -> dict:
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


# ------------------- acceptance: mesh vs batched engine -------------------

@pytest.mark.slow
def test_mesh_batched_matches_single_device_engine(dist_env):
    """solve(A, B, method="plcg_scan", mesh=mesh) with B (nrhs, nx, ny) on
    a 8-device (4, 2) mesh matches the single-device batched vmap(scan)
    engine to <= 1e-10 relative in f64, with identical per-RHS iteration
    counts."""
    res = _run(textwrap.dedent("""
        import json, jax
        jax.config.update("jax_enable_x64", True)
        import jax.numpy as jnp, numpy as np
        from repro.core import solve
        from repro.launch.mesh import make_mesh_compat
        from repro.operators import poisson2d
        mesh = make_mesh_compat((4, 2), ("data", "model"))
        nx = ny = 32
        A = poisson2d(nx, ny)
        rng = np.random.default_rng(0)
        B = np.stack([np.asarray(A @ rng.standard_normal(A.n))
                      for _ in range(4)])
        kw = dict(method="plcg_scan", l=2, tol=1e-10, maxiter=200,
                  spectrum=(0.0, 8.0))
        ref = solve(A, B, **kw)                         # single device
        r = solve(A, B.reshape(4, nx, ny), mesh=mesh, **kw)
        xm = np.asarray(r.x).reshape(4, -1)
        rel = max(np.linalg.norm(xm[j] - np.asarray(ref.x)[j])
                  / np.linalg.norm(np.asarray(ref.x)[j]) for j in range(4))
        print(json.dumps({
            "rel": float(rel), "conv": bool(r.converged),
            "iters_match": [int(a) == int(b) for a, b in
                            zip(r.info["per_rhs_iters"],
                                ref.info["per_rhs_iters"])],
            "shape": list(np.asarray(r.x).shape),
            "batched": r.info["batched"],
            "psums": r.info["psums_per_iter"]}))
    """), dist_env)
    assert res["conv"] and res["rel"] <= 1e-10
    assert all(res["iters_match"])
    assert res["shape"] == [4, 32, 32]
    assert res["batched"] == "shard_map+vmap" and res["psums"] == 1


@pytest.mark.slow
def test_mesh_single_rhs_matches_reference(dist_env):
    """The single-RHS mesh path (restart driver) reproduces the python
    p(l)-CG reference trace on a (4, 2) mesh."""
    res = _run(textwrap.dedent("""
        import json, jax
        jax.config.update("jax_enable_x64", True)
        import jax.numpy as jnp, numpy as np
        from repro.core import solve
        from repro.core.plcg import plcg
        from repro.launch.mesh import make_mesh_compat
        from repro.operators import poisson2d
        mesh = make_mesh_compat((4, 2), ("data", "model"))
        nx = ny = 32
        A = poisson2d(nx, ny)
        b_np = A @ np.ones(nx*ny)
        r = solve(A, jnp.asarray(b_np.reshape(nx, ny)), method="plcg",
                  l=2, tol=1e-10, maxiter=140, spectrum=(0, 8), mesh=mesh)
        ref = plcg(A, b_np, l=2, tol=1e-10, maxiter=140, spectrum=(0, 8))
        m = min(len(r.resnorms), len(ref.resnorms)) - 1
        ok_trace = bool(np.allclose(r.resnorms[:m], ref.resnorms[:m],
                                    rtol=1e-7))
        res = float(np.linalg.norm(b_np - A @ np.asarray(r.x).reshape(-1)))
        print(json.dumps({"trace": ok_trace, "res": res,
                          "conv": bool(r.converged)}))
    """), dist_env)
    assert res["trace"] and res["conv"] and res["res"] < 1e-7


@pytest.mark.slow
def test_mesh_per_rhs_masking_across_shards(dist_env):
    """Converged lanes freeze through the scan engine's per-lane select
    while live lanes keep iterating -- on shards exactly as on one
    device: the smooth A@1 RHS stops well before a rough random RHS and
    stops emitting residuals."""
    res = _run(textwrap.dedent("""
        import json, jax
        jax.config.update("jax_enable_x64", True)
        import jax.numpy as jnp, numpy as np
        from repro.core import solve
        from repro.launch.mesh import make_mesh_compat
        from repro.operators import poisson2d
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        nx = ny = 32
        A = poisson2d(nx, ny)
        smooth = np.asarray(A @ np.ones(A.n))
        rough = np.asarray(
            A @ np.random.default_rng(3).standard_normal(A.n))
        B = np.stack([smooth, rough]).reshape(2, nx, ny)
        r = solve(A, B, method="plcg_scan", l=2, tol=1e-10, maxiter=200,
                  spectrum=(0.0, 8.0), mesh=mesh)
        it = [int(k) for k in r.info["per_rhs_iters"]]
        print(json.dumps({
            "conv": [bool(c) for c in r.info["per_rhs_converged"]],
            "iters": it,
            "trace_lens": [len(t) for t in r.resnorms]}))
    """), dist_env)
    assert all(res["conv"])
    assert res["iters"][0] < res["iters"][1] - 10   # eigenvector lane stops
    assert res["trace_lens"][0] < res["trace_lens"][1]


@pytest.mark.slow
def test_mesh_cg_baseline_converges(dist_env):
    res = _run(textwrap.dedent("""
        import json, jax
        jax.config.update("jax_enable_x64", True)
        import jax.numpy as jnp, numpy as np
        from repro.core import solve
        from repro.launch.mesh import make_mesh_compat
        from repro.operators import poisson2d
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        nx = ny = 32
        A = poisson2d(nx, ny)
        b_np = A @ np.ones(nx*ny)
        r = solve(A, jnp.asarray(b_np.reshape(nx, ny)), method="cg",
                  tol=1e-10, maxiter=140, mesh=mesh)
        err = float(np.linalg.norm(np.asarray(r.x).reshape(-1) - 1.0))
        print(json.dumps({"err": err, "conv": bool(r.converged),
                          "psums": r.info["psums_per_iter"]}))
    """), dist_env)
    assert res["conv"] and res["err"] < 1e-6 and res["psums"] == 2


# -------------------- structural: one psum per iteration ------------------

def test_one_psum_per_iteration_vs_two_for_cg(x64):
    """Jaxpr introspection (in-process, (1,1) mesh -- the traced program
    is mesh-size independent): the pipelined mesh sweep carries ONE fused
    psum per scan iteration, single-RHS and batched alike; the classic-CG
    baseline carries TWO.  Halo exchange stays 4 ppermutes either way."""
    import jax.numpy as jnp
    from repro.core.shifts import chebyshev_shifts
    from repro.distributed import DistPoisson, cg_mesh_sweep, plcg_mesh_sweep
    from repro.kernels.introspect import count_primitive_in_scan_bodies
    from repro.launch.mesh import make_mesh_compat

    mesh = make_mesh_compat((1, 1), ("data", "model"))
    op = DistPoisson(16, 16, mesh)
    sig = tuple(chebyshev_shifts(0, 8, 2))
    b = jnp.ones((16, 16))
    b3 = jnp.ones((4, 16, 16))

    fp = plcg_mesh_sweep(op, l=2, iters=30, sigma=sig, tol=1e-8)
    assert count_primitive_in_scan_bodies(fp, "psum", b, b * 0, 30) == [1]
    assert count_primitive_in_scan_bodies(fp, "ppermute",
                                          b, b * 0, 30) == [4]
    fb = plcg_mesh_sweep(op, l=2, iters=30, sigma=sig, tol=1e-8,
                         batched=True)
    # the stacked (nrhs, 2l+1) payload rides the SAME single psum
    assert count_primitive_in_scan_bodies(fb, "psum", b3, b3 * 0, 30) == [1]
    assert count_primitive_in_scan_bodies(fb, "ppermute",
                                          b3, b3 * 0, 30) == [4]
    fc = cg_mesh_sweep(op, iters=30, tol=1e-8)
    assert count_primitive_in_scan_bodies(fc, "psum", b, b * 0) == [2]
    fcb = cg_mesh_sweep(op, iters=30, tol=1e-8, batched=True)
    assert count_primitive_in_scan_bodies(fcb, "psum", b3, b3 * 0) == [2]


def test_mesh_parity_on_available_devices(x64):
    """In-process multi-device parity: when the MAIN process has >= 4
    devices (the CI distributed lane forces 4 via XLA_FLAGS), the
    batched mesh engine on a real (2, 2) decomposition -- live ppermute
    halo pairs, partial dots, one psum -- matches the single-device
    batched engine to <= 1e-10 relative.  Skips on single-device hosts
    (the slow subprocess tests cover that case)."""
    import jax
    import numpy as np
    from repro.core import solve
    from repro.launch.mesh import make_mesh_compat
    from repro.operators import poisson2d

    if len(jax.devices()) < 4:
        pytest.skip("needs >= 4 host devices (CI dist lane forces 4)")
    mesh = make_mesh_compat((2, 2), ("data", "model"))
    nx = ny = 32
    A = poisson2d(nx, ny)
    rng = np.random.default_rng(1)
    B = np.stack([np.asarray(A @ rng.standard_normal(A.n))
                  for _ in range(3)])
    kw = dict(method="plcg_scan", l=2, tol=1e-10, maxiter=200,
              spectrum=(0.0, 8.0))
    ref = solve(A, B, **kw)
    r = solve(A, B.reshape(3, nx, ny), mesh=mesh, **kw)
    xm = np.asarray(r.x).reshape(3, -1)
    for j in range(3):
        d = np.linalg.norm(xm[j] - np.asarray(ref.x)[j])
        assert d <= 1e-10 * np.linalg.norm(np.asarray(ref.x)[j])
    assert list(r.info["per_rhs_iters"]) == list(ref.info["per_rhs_iters"])


# ----------------------- front-end contract (in-process) ------------------

def test_mesh_solve_budget_and_info(x64):
    """The folded restart driver enforces a GLOBAL iteration budget across
    sweeps (no max_restarts x maxiter blow-up) and reports the common
    SolveResult contract."""
    import jax.numpy as jnp
    import numpy as np
    from repro.core import solve
    from repro.launch.mesh import make_mesh_compat
    from repro.operators import poisson2d

    from repro.core.shifts import chebyshev_shifts
    from repro.distributed import as_dist_operator, plcg_mesh_sweep

    mesh = make_mesh_compat((1, 1), ("data", "model"))
    nx = ny = 16
    A = poisson2d(nx, ny)
    b = jnp.asarray((A @ np.ones(nx * ny)).reshape(nx, ny))
    # budget-exhaustion path: far too few iterations to converge
    r = solve(A, b, method="plcg_scan", l=2, tol=1e-14, maxiter=10,
              spectrum=(0, 8), mesh=mesh)
    assert not r.converged
    assert r.iters <= 10
    assert r.info["psums_per_iter"] == 1
    # convergent path reports the true iteration count and solution
    r = solve(A, b, method="plcg_scan", l=2, tol=1e-10, maxiter=200,
              spectrum=(0, 8), mesh=mesh)
    assert r.converged and 0 < r.iters <= 200
    assert np.linalg.norm(np.asarray(r.x).reshape(-1) - 1.0) < 1e-6
    # the budget is a traced operand of ONE compiled sweep (restarts
    # never recompile): same callable, different caps, exact k_done
    op = as_dist_operator(A, mesh)
    sig = tuple(chebyshev_shifts(0, 8, 2))
    fn = plcg_mesh_sweep(op, l=2, iters=30, sigma=sig, tol=0.0)
    assert plcg_mesh_sweep(op, l=2, iters=30, sigma=sig, tol=0.0) is fn
    for cap in (5, 9):
        out = fn(b, b * 0, cap)
        assert int(out[4]) + 1 == cap       # k_done + 1 updates committed
        assert not bool(out[2]) and not bool(out[3])  # frozen, not conv/brk


def test_mesh_cg_x0_and_early_stop_contract(x64):
    """dist CG honors x0 and stops early like the pipelined path: an
    exact initial guess converges in 0 iterations, restarting from a
    returned solution performs no further updates, and flat (n,) input
    round-trips."""
    import jax.numpy as jnp
    import numpy as np
    from repro.core import solve
    from repro.launch.mesh import make_mesh_compat
    from repro.operators import poisson2d

    mesh = make_mesh_compat((1, 1), ("data", "model"))
    nx = ny = 16
    A = poisson2d(nx, ny)
    ones = np.ones(nx * ny)
    b = jnp.asarray((A @ ones).reshape(nx, ny))
    exact = solve(A, b, method="cg", tol=1e-10, maxiter=300, mesh=mesh,
                  x0=jnp.asarray(ones.reshape(nx, ny)))
    assert exact.converged and exact.iters == 0
    cold = solve(A, b, method="cg", tol=1e-10, maxiter=300, mesh=mesh)
    warm = solve(A, b, method="cg", tol=1e-10, maxiter=300, mesh=mesh,
                 x0=jnp.asarray(cold.x))
    assert cold.converged and cold.iters > 0
    assert warm.converged and warm.iters == 0       # x0 respected
    assert len(cold.resnorms) == cold.iters + 1     # initial + per update
    # flat right-hand side comes back flat
    r = solve(A, jnp.asarray(A @ ones), method="cg", tol=1e-10,
              maxiter=300, mesh=mesh)
    assert np.asarray(r.x).shape == (nx * ny,)
    assert np.linalg.norm(np.asarray(r.x) - ones) < 1e-6


def test_dist_solvers_only_reachable_through_front_end():
    """No standalone distributed drivers: repro.distributed exports the
    operator protocol and sweep builders only, and the front-end rejects
    methods without a mesh path."""
    import numpy as np
    import repro.distributed as dist
    from repro.core import solve
    from repro.launch.mesh import make_mesh_compat
    from repro.operators import poisson2d

    for gone in ("dist_plcg", "dist_cg", "dist_plcg_solve"):
        assert not hasattr(dist, gone)
    assert dist.mesh_methods() == ("cg", "plcg", "plcg_scan")

    mesh = make_mesh_compat((1, 1), ("data", "model"))
    A = poisson2d(8, 8)
    b = np.asarray(A @ np.ones(A.n)).reshape(8, 8)
    with pytest.raises(ValueError, match="no mesh-aware execution path"):
        solve(A, b, method="pcg", mesh=mesh)
    # a bare M= callable is opaque to the mesh layer (structured
    # shard-local preconditioners work -- see tests/test_precond.py)
    with pytest.raises(ValueError, match="precondition"):
        solve(A, b, method="plcg_scan", mesh=mesh, M=lambda v: v)
    with pytest.raises(ValueError, match="options"):
        solve(A, b, method="plcg_scan", mesh=mesh, record_G=True)
    # max_restarts works single-RHS but is rejected (not silently
    # dropped) by the batched mesh engine, like the vmap(scan) engine
    B2 = np.stack([b, b])
    with pytest.raises(ValueError, match="max_restarts"):
        solve(A, B2, method="plcg_scan", mesh=mesh, max_restarts=0)
    with pytest.raises(TypeError, match="stencil2d"):
        solve(np.eye(64), np.ones(64).reshape(8, 8), method="plcg_scan",
              mesh=mesh)
    # an explicitly requested kernel backend cannot take effect on the
    # injected-dot mesh path: surfaced as a warning, not silently eaten
    with pytest.warns(UserWarning, match="backend"):
        solve(A, b, method="plcg_scan", l=1, tol=1e-4, maxiter=20,
              spectrum=(0.0, 8.0), mesh=mesh, backend="fused")
    # cg on a mesh ignores pipelined-method knobs like the single-device
    # cg adapter (no sigma validation)
    r = solve(A, b, method="cg", l=3, sigma=[0.5], tol=1e-6, maxiter=200,
              mesh=mesh)
    assert r.converged


def test_dist_operator_protocol_and_caching(x64):
    """DistPoisson satisfies the protocol, ppermute pair lists and the
    stencil2d promotion are cached per operator, repeated front-end mesh
    solves reuse ONE compiled sweep, and a DistributedOperator
    dispatches without mesh=."""
    import numpy as np
    from repro.core import clear_batch_trace, solve
    from repro.core import engine
    from repro.distributed import (DistPoisson, DistributedOperator,
                                   as_dist_operator)
    from repro.launch.mesh import make_mesh_compat
    from repro.operators import poisson2d

    mesh = make_mesh_compat((1, 1), ("data", "model"))
    A = poisson2d(16, 16)
    op = as_dist_operator(A, mesh)
    assert isinstance(op, DistPoisson)
    assert isinstance(op, DistributedOperator)
    assert op.global_shape == (16, 16) and op.local_shape == (16, 16)
    # cached properties: same tuple object on repeated access
    assert op._row_perms is op._row_perms
    assert op._col_perms is op._col_perms
    # canonical promotion: same A + mesh -> the SAME operator instance
    assert as_dist_operator(A, mesh) is op
    assert as_dist_operator(op, None) is op
    assert as_dist_operator(op, mesh) is op
    other = make_mesh_compat((1, 1), ("rows", "cols"))
    with pytest.raises(ValueError, match="different mesh"):
        as_dist_operator(op, other)
    # ...so two identical front-end mesh solves compile the sweep ONCE
    B = np.stack([np.asarray(A @ np.ones(A.n))] * 2).reshape(2, 16, 16)
    kw = dict(method="plcg_scan", l=2, tol=1e-8, maxiter=60,
              spectrum=(0.0, 8.0), mesh=mesh)
    clear_batch_trace()
    solve(A, B, **kw)
    solve(A, B, **kw)
    assert len(engine.BATCH_TRACE_EVENTS) == 1
    # operator-first dispatch: solve() picks the mesh off the operator
    b = np.asarray(A @ np.ones(A.n)).reshape(16, 16)
    r = solve(op, b, method="cg", tol=1e-6, maxiter=300)
    assert r.converged


# --------------------- unrelated multi-device suites ----------------------

@pytest.mark.slow
def test_moe_shardmap_matches_local(dist_env):
    res = _run(textwrap.dedent("""
        import json, jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh_compat
        from repro.models import sharding as shd
        from repro.models.layers import moe_layer, _moe_local
        from repro.models.config import ModelConfig, MoEConfig
        cfg = ModelConfig(arch_id="t", family="moe", n_layers=1, d_model=32,
                          n_heads=4, n_kv=2, d_ff=64, vocab=64,
                          moe=MoEConfig(num_experts=8, top_k=2,
                                        d_ff_expert=16,
                                        capacity_factor=16.0))
        key = jax.random.PRNGKey(0)
        p = {"router": jax.random.normal(key, (32, 8), jnp.float32) * 0.3,
             "w_in": jax.random.normal(key, (8, 32, 32), jnp.float32) * 0.2,
             "w_out": jax.random.normal(key, (8, 16, 32), jnp.float32) * 0.2}
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32), jnp.float32)
        ref = _moe_local(cfg, p["router"], p["w_in"], p["w_out"], x, 8, 0)
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        shd.set_mesh(mesh)
        out = jax.jit(lambda pp, xx: moe_layer(cfg, pp, xx))(p, x)
        err = float(jnp.max(jnp.abs(out - ref)))
        print(json.dumps({"err": err}))
    """), dist_env)
    assert res["err"] < 2e-4


@pytest.mark.slow
def test_multidevice_train_step_runs(dist_env):
    """End-to-end sharded train step on an 8-device mesh."""
    res = _run(textwrap.dedent("""
        import json, jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh_compat
        from repro.configs import get_reduced
        from repro.models import init_params, sharding as shd
        from repro.launch.steps import build_train_step
        from repro.training import AdamWConfig, adamw_init
        from repro.training.data import synth_batch
        mesh = make_mesh_compat((4, 2), ("data", "model"))
        shd.set_mesh(mesh)
        cfg = get_reduced("qwen3-moe-235b-a22b")
        params = init_params(cfg, jax.random.PRNGKey(0))
        ocfg = AdamWConfig(lr=1e-3)
        opt = adamw_init(params, ocfg)
        step = jax.jit(build_train_step(cfg, ocfg, remat="none"))
        losses = []
        for s in range(3):
            batch = synth_batch(cfg, s, 8, 32, seed=0)
            params, opt, aux = step(params, opt, batch)
            losses.append(float(aux["loss"]))
        print(json.dumps({"losses": losses}))
    """), dist_env)
    assert all(l == l and l < 20 for l in res["losses"])  # finite
    assert res["losses"][-1] < res["losses"][0]
