"""Multi-device tests (subprocess with 8 forced host devices): the
distributed solver must reproduce the single-device trace, and the MoE
shard_map path must match the local reference.

The subprocess env (8 host devices, src on PYTHONPATH) comes from the
``dist_env`` conftest fixture so the suite is deterministic on
single-device hosts and in CI; meshes are built through the
version-portable ``repro.launch.mesh.make_mesh_compat``."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, env: dict) -> dict:
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_dist_plcg_matches_reference(dist_env):
    res = _run(textwrap.dedent("""
        import json, jax
        jax.config.update("jax_enable_x64", True)
        import jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh_compat
        from repro.distributed import dist_plcg, DistPoisson
        from repro.core.shifts import chebyshev_shifts
        from repro.core.plcg import plcg
        from repro.operators import poisson2d
        mesh = make_mesh_compat((4, 2), ("data", "model"))
        nx = ny = 32
        op = DistPoisson(nx, ny, mesh)
        A = poisson2d(nx, ny)
        b_np = A @ np.ones(nx*ny)
        x, resn, conv, brk, k_done = dist_plcg(
            op, jnp.asarray(b_np.reshape(nx, ny)), l=2, iters=140,
            sigma=chebyshev_shifts(0,8,2), tol=1e-10)
        ref = plcg(A, b_np, l=2, tol=1e-10, maxiter=140, spectrum=(0,8))
        rr = np.array([r for r in np.asarray(resn) if r > 0])
        m = min(len(rr), len(ref.resnorms)) - 1
        ok_trace = bool(np.allclose(rr[:m], ref.resnorms[:m], rtol=1e-7))
        res = float(np.linalg.norm(b_np - A @ np.asarray(x).reshape(-1)))
        print(json.dumps({"trace": ok_trace, "res": res,
                          "conv": bool(conv)}))
    """), dist_env)
    assert res["trace"] and res["conv"] and res["res"] < 1e-7


def test_dist_solve_budget_and_info():
    """dist_plcg_solve enforces a GLOBAL iteration budget across restart
    sweeps (no max_restarts x maxiter blow-up) and reports iterations /
    breakdowns like the single-device driver.  Runs in-process on a (1,1)
    mesh (unpaired ppermute edges = Dirichlet zeros)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.shifts import chebyshev_shifts
    from repro.distributed import DistPoisson, dist_plcg_solve
    from repro.launch.mesh import make_mesh_compat
    from repro.operators import poisson2d

    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        mesh = make_mesh_compat((1, 1), ("data", "model"))
        nx = ny = 16
        op = DistPoisson(nx, ny, mesh)
        A = poisson2d(nx, ny)
        b = jnp.asarray((A @ np.ones(nx * ny)).reshape(nx, ny))
        # budget-exhaustion path: far too few iterations to converge
        x, resn, info = dist_plcg_solve(op, b, l=2,
                                        sigma=chebyshev_shifts(0, 8, 2),
                                        tol=1e-14, maxiter=10)
        assert not info["converged"]
        assert info["iterations"] <= 10
        assert set(info) == {"converged", "restarts", "breakdowns",
                             "iterations"}
        # convergent path reports the true iteration count
        x, resn, info = dist_plcg_solve(op, b, l=2,
                                        sigma=chebyshev_shifts(0, 8, 2),
                                        tol=1e-10, maxiter=200)
        assert info["converged"]
        assert 0 < info["iterations"] <= 200
        err = np.linalg.norm(np.asarray(x).reshape(-1) - 1.0)
        assert err < 1e-6
    finally:
        jax.config.update("jax_enable_x64", old)


@pytest.mark.slow
def test_dist_cg_converges(dist_env):
    res = _run(textwrap.dedent("""
        import json, jax
        jax.config.update("jax_enable_x64", True)
        import jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh_compat
        from repro.distributed import dist_cg, DistPoisson
        from repro.operators import poisson2d
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        nx = ny = 32
        op = DistPoisson(nx, ny, mesh)
        A = poisson2d(nx, ny)
        b_np = A @ np.ones(nx*ny)
        x, resn, conv = dist_cg(op, jnp.asarray(b_np.reshape(nx, ny)),
                                iters=140, tol=1e-10)
        err = float(np.linalg.norm(np.asarray(x).reshape(-1) - 1.0))
        print(json.dumps({"err": err, "conv": bool(conv)}))
    """), dist_env)
    assert res["conv"] and res["err"] < 1e-6


@pytest.mark.slow
def test_moe_shardmap_matches_local(dist_env):
    res = _run(textwrap.dedent("""
        import json, jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh_compat
        from repro.models import sharding as shd
        from repro.models.layers import moe_layer, _moe_local
        from repro.models.config import ModelConfig, MoEConfig
        cfg = ModelConfig(arch_id="t", family="moe", n_layers=1, d_model=32,
                          n_heads=4, n_kv=2, d_ff=64, vocab=64,
                          moe=MoEConfig(num_experts=8, top_k=2,
                                        d_ff_expert=16,
                                        capacity_factor=16.0))
        key = jax.random.PRNGKey(0)
        p = {"router": jax.random.normal(key, (32, 8), jnp.float32) * 0.3,
             "w_in": jax.random.normal(key, (8, 32, 32), jnp.float32) * 0.2,
             "w_out": jax.random.normal(key, (8, 16, 32), jnp.float32) * 0.2}
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32), jnp.float32)
        ref = _moe_local(cfg, p["router"], p["w_in"], p["w_out"], x, 8, 0)
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        shd.set_mesh(mesh)
        out = jax.jit(lambda pp, xx: moe_layer(cfg, pp, xx))(p, x)
        err = float(jnp.max(jnp.abs(out - ref)))
        print(json.dumps({"err": err}))
    """), dist_env)
    assert res["err"] < 2e-4


@pytest.mark.slow
def test_multidevice_train_step_runs(dist_env):
    """End-to-end sharded train step on an 8-device mesh."""
    res = _run(textwrap.dedent("""
        import json, jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh_compat
        from repro.configs import get_reduced
        from repro.models import init_params, sharding as shd
        from repro.launch.steps import build_train_step
        from repro.training import AdamWConfig, adamw_init
        from repro.training.data import synth_batch
        mesh = make_mesh_compat((4, 2), ("data", "model"))
        shd.set_mesh(mesh)
        cfg = get_reduced("qwen3-moe-235b-a22b")
        params = init_params(cfg, jax.random.PRNGKey(0))
        ocfg = AdamWConfig(lr=1e-3)
        opt = adamw_init(params, ocfg)
        step = jax.jit(build_train_step(cfg, ocfg, remat="none"))
        losses = []
        for s in range(3):
            batch = synth_batch(cfg, s, 8, 32, seed=0)
            params, opt, aux = step(params, opt, batch)
            losses.append(float(aux["loss"]))
        print(json.dumps({"losses": losses}))
    """), dist_env)
    assert all(l == l and l < 20 for l in res["losses"])  # finite
    assert res["losses"][-1] < res["losses"][0]
