"""Autotuner tests: calibration caching, decision determinism, clamps.

The contract under test (``repro.core.autotune``): ``l="auto"`` /
``comm="auto"`` solve the paper's per-iteration latency model
``t_iter ~ max(glred / l, spmv)`` over measured (or injected) latencies,
clamped so the storage-precision residual-gap floor
``~ eps_storage * (2l+1)`` never misses the requested ``tol`` -- and a
prepared Solver calibrates exactly ONCE (audited via
``CALIBRATION_EVENTS``), with repeated same-shape solves staying
zero-retrace (``compile_counts``) and same-config sessions zero-
re-measure (the weak-key calibration cache).

Deterministic decision tests pin the latency table with
``override_latencies`` (the injection hook; it bypasses the measurement
cache, so fakes never leak into real calibrations).  Mesh-path tests run
in-process on a (1, 1) mesh; live multi-device behaviour activates under
the CI ``auto`` lane (8 forced host devices) and in a ``dist_env``
subprocess for single-device hosts.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, env: dict) -> dict:
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.fixture
def events():
    """Clean calibration-event log around one test."""
    from repro.core import clear_calibration_events
    from repro.core.autotune import CALIBRATION_EVENTS
    clear_calibration_events()
    yield CALIBRATION_EVENTS
    clear_calibration_events()


# ----------------------------- the clamp ----------------------------------

def test_attainable_floor_grows_with_depth_and_storage_eps():
    import jax.numpy as jnp

    from repro.core.autotune import attainable_floor
    floors = [attainable_floor(l, jnp.float32) for l in (1, 2, 3, 5, 8)]
    assert floors == sorted(floors)                 # monotone in l
    assert (attainable_floor(5, jnp.bfloat16)
            > attainable_floor(5, jnp.float32)
            > attainable_floor(5, jnp.float64))


def test_depth_budget_per_precision_rung():
    import jax.numpy as jnp

    from repro.core import depth_budget
    # f32 storage at tol=1e-6: eps*(2l+1) <= 1e-6 holds through l=3
    assert depth_budget(1e-6, jnp.float32) == 3
    # f64 storage is effectively unbounded at practical tolerances
    assert depth_budget(1e-10, jnp.float64) == 8
    # bf16 storage cannot reach 1e-6 at ANY depth: floor clamps to 1
    assert depth_budget(1e-6, jnp.float32, precision="bf16") == 1
    # tol=0 disables early stopping: no accuracy target, no clamp
    assert depth_budget(0.0, jnp.float32) == 8
    assert depth_budget(0.0, jnp.float32, precision="bf16") == 8
    # the policy's *storage* side is what clamps: bf16x64 still eps(bf16)
    assert depth_budget(1e-6, jnp.float64, precision="bf16x64") == 1


# --------------------- deterministic model decisions ----------------------

def _lat(spmv=100.0, blocking=300.0, **modes):
    glred = {"blocking": blocking}
    glred.update(modes)
    return {"spmv_us": spmv, "glred_us": glred}


def test_decide_solves_latency_model():
    """max(glred/l, spmv) over the ladder: glred=300/spmv=100 breaks even
    at l=3; a reduction-free problem stays at l=1 (shallowest tie)."""
    import jax.numpy as jnp

    from repro.core import decide
    d = decide(_lat(100.0, 300.0), l="auto", comm="blocking", tol=0.0,
               dtype=jnp.float64)
    assert (d.l, d.comm.mode) == (3, "blocking")
    assert d.score_us == pytest.approx(100.0)
    # glred negligible: every depth scores spmv, ties break shallow
    assert decide(_lat(100.0, 1.0), l="auto", comm="blocking", tol=0.0,
                  dtype=jnp.float64).l == 1
    # glred enormous: deepest admissible pipeline wins
    assert decide(_lat(100.0, 10000.0), l="auto", comm="blocking", tol=0.0,
                  dtype=jnp.float64).l == 8


def test_decide_comm_auto_prefers_measured_cheapest():
    import jax.numpy as jnp

    from repro.core import decide
    lat = _lat(100.0, 800.0, overlap=300.0)
    d = decide(lat, l="auto", comm="auto", tol=0.0, dtype=jnp.float64)
    # overlap's cheaper reduction hides at l=3 (300/3=100); blocking
    # would need l=8 and ties at the same score -- deeper, so it loses
    assert (d.l, d.comm.mode) == (3, "overlap")
    assert d.depth == 3                     # overlap staging depth = l
    # ring needs l >= hops+1: with 5 hops only l=8 qualifies, and its
    # cheap hops beat blocking's 800/8 there
    lat = dict(_lat(10.0, 800.0, ring=100.0), ring_hops=5)
    d = decide(lat, l="auto", comm="auto", tol=0.0, dtype=jnp.float64)
    assert (d.l, d.comm.mode) == (8, "ring")


def test_decide_pinned_knobs_restrict_the_search():
    import jax.numpy as jnp

    from repro.core import CommPolicy, decide
    # pinned l: only the comm axis is searched
    d = decide(_lat(100.0, 800.0, overlap=200.0), l=2, comm="auto",
               tol=0.0, dtype=jnp.float64)
    assert d.l == 2 and d.comm.mode == "overlap"
    # pinned comm policy object passes through verbatim (explicit depth)
    pol = CommPolicy(mode="overlap", depth=2)
    d = decide(_lat(100.0, 300.0, overlap=300.0), l="auto", comm=pol,
               tol=0.0, dtype=jnp.float64)
    assert d.comm is pol
    assert d.l >= 2                         # staging depth needs l >= 2
    # infeasible pin: ring over 5 hops with pinned shallow l
    with pytest.raises(ValueError, match="no admissible"):
        decide(dict(_lat(), ring_hops=5), l=2, comm="ring",
               tol=0.0, dtype=jnp.float64)


def test_decide_clamps_to_precision_budget():
    """A glred-dominated table wants l=8, but bf16 storage at 2e-2 only
    affords the floor through l=1 -- the clamp wins over the model."""
    import jax.numpy as jnp

    from repro.core import decide
    from repro.core.autotune import attainable_floor
    lat = _lat(100.0, 10000.0)
    tol = 2.5e-2
    d = decide(lat, l="auto", comm="blocking", tol=tol, dtype=jnp.float32,
               precision="bf16")
    assert d.l == 1 and d.budget == 1
    assert attainable_floor(d.l, jnp.bfloat16) <= tol
    # same table unclamped picks the deep pipeline
    assert decide(lat, l="auto", comm="blocking", tol=tol,
                  dtype=jnp.float64).l == 8


def test_decide_warns_when_tol_below_depth1_floor():
    import jax.numpy as jnp

    from repro.core import decide
    with pytest.warns(UserWarning, match="depth-1 precision floor"):
        d = decide(_lat(), l="auto", comm="blocking", tol=1e-6,
                   dtype=jnp.float32, precision="bf16")
    assert d.l == 1                         # nothing shallower exists


# ------------------------- front-end validation ---------------------------

def test_prepare_depth_front_end_validation():
    from repro.core import engine
    spec = engine.get_method("plcg_scan")
    assert engine._prepare_depth(spec, "auto") == "auto"
    assert engine._prepare_depth(spec, 3) == 3
    with pytest.raises(ValueError, match="l must be >= 1"):
        engine._prepare_depth(spec, 0)
    # methods without a pipeline depth reject the sentinel up front
    with pytest.raises(ValueError, match="no pipeline depth"):
        engine._prepare_depth(engine.get_method("cg"), "auto")


def test_comm_auto_off_mesh_degrades_to_blocking(x64):
    """comm='auto' means "fastest available schedule": off-mesh only the
    blocking reduction exists, so auto resolves to it silently (explicit
    comm='overlap' off-mesh still raises)."""
    from repro.core import Solver, engine
    from repro.operators import poisson2d
    spec = engine.get_method("plcg_scan")
    assert engine._prepare_comm(spec, "auto", on_mesh=False).is_blocking
    assert engine._prepare_comm(spec, "auto", on_mesh=True) == "auto"
    s = Solver(poisson2d(8, 8), method="plcg_scan", l=2, comm="auto")
    assert s.comm.is_blocking and s.auto is None


def test_auto_requires_operator_at_construction():
    from repro.core import Solver
    with pytest.raises(ValueError, match="pass n="):
        Solver(lambda v: 2.0 * v, method="plcg_scan", l="auto")


def test_override_table_validated():
    from repro.core import override_latencies
    with pytest.raises(ValueError, match="missing"):
        with override_latencies({"spmv_us": 1.0}):
            pass


# ------------------ prepared sessions: the measure-once gate --------------

def test_solver_auto_injected_deterministic_and_reported(x64, events):
    """The CI-reproducible path: a fake latency table pins the decision
    (glred=300/spmv=100 -> l=3), the session reports it in
    SolveResult.info['auto'], and repeated same-shape solves neither
    re-measure nor retrace."""
    from repro.core import Solver, override_latencies
    from repro.operators import poisson2d

    A = poisson2d(16, 16)
    b = np.asarray(A @ np.ones(A.n))
    with override_latencies(_lat(100.0, 300.0)):
        s = Solver(A, method="plcg_scan", l="auto", tol=1e-8, maxiter=200)
    assert s.l == 3 and s.auto.source == "injected"
    assert len(events) == 1                 # calibrated ONCE, at prepare
    r1 = s.solve(b)
    r2 = s.solve(b)
    assert len(events) == 1                 # solves never re-calibrate
    assert r1.converged and r2.converged
    info = r1.info["auto"]
    assert info["l"] == 3 and info["comm"] == "blocking"
    assert info["source"] == "injected"
    assert info["latencies"]["glred_us"]["blocking"] == 300.0
    counts = s.compile_counts()
    s.solve(b)
    assert s.compile_counts() == counts     # zero retraces, same shape
    # a different table changes the choice -- the decision is data-driven
    with override_latencies(_lat(100.0, 10000.0)):
        assert Solver(A, method="plcg_scan", l="auto", tol=1e-8,
                      maxiter=200).l == 8
    assert len(events) == 2


def test_solver_auto_measured_once_per_operator_config(x64, events):
    """Without injection the session measures REAL latencies -- exactly
    once: a second same-config session hits the weak-key calibration
    cache (zero new events) and reaches the same decision."""
    from repro.core import Solver
    from repro.operators import poisson2d

    A = poisson2d(8, 8)
    b = np.asarray(A @ np.ones(A.n))
    s1 = Solver(A, method="plcg_scan", l="auto", tol=1e-6, maxiter=200)
    assert len(events) == 1 and events[0][0] == "measured"
    assert s1.auto.source == "measured"
    lat = s1.auto.latencies
    assert lat["spmv_us"] > 0
    assert set(lat["iter_us"]) == {1, 2, 3, 5, 8}
    s2 = Solver(A, method="plcg_scan", l="auto", tol=1e-6, maxiter=200)
    assert len(events) == 1                 # cache hit: zero re-measure
    assert s2.l == s1.l
    assert s1.solve(b).converged


def test_one_shot_solve_accepts_auto(x64, events):
    from repro.core import override_latencies, solve
    from repro.operators import poisson2d

    A = poisson2d(16, 16)
    b = np.asarray(A @ np.ones(A.n))
    with override_latencies(_lat(100.0, 300.0)):
        r = solve(A, b, method="plcg_scan", l="auto", tol=1e-8, maxiter=200)
    assert r.converged and r.info["auto"]["l"] == 3
    assert r.info["l"] == 3                 # the engine ran the choice


# ----------------------- mesh path (in-process, (1,1)) --------------------

def test_mesh_auto_resolved_at_preparation(x64, events):
    """On a mesh the sentinels resolve inside prepare_on_mesh: the
    prepared session carries the concrete (l, comm), validated against
    the operator exactly like pinned values, and reports the decision."""
    from repro.core import Solver, override_latencies
    from repro.launch.mesh import make_mesh_compat
    from repro.operators import poisson2d

    mesh = make_mesh_compat((1, 1), ("data", "model"))
    A = poisson2d(16, 16)
    b = np.asarray(A @ np.ones(A.n)).reshape(16, 16)
    with override_latencies(_lat(100.0, 300.0, overlap=100.0)):
        s = Solver(A, method="plcg_scan", mesh=mesh, l="auto", comm="auto",
                   tol=1e-8, maxiter=200)
    assert len(events) == 1
    assert (s.l, s.comm.mode) == (1, "overlap")     # 100/1 ties spmv=100
    assert s._mesh_session.l == s.l
    assert s._mesh_session.comm is s.comm
    r = s.solve(b)
    assert r.converged
    assert r.info["auto"]["comm"] == "overlap"
    assert r.info["comm"] == "overlap"


def test_mesh_prepared_solver_rejects_unresolved_sentinels(x64):
    from repro.core import engine
    from repro.distributed.plcg_dist import PreparedMeshSolver
    from repro.launch.mesh import make_mesh_compat
    from repro.operators import poisson2d

    mesh = make_mesh_compat((1, 1), ("data", "model"))
    spec = engine.get_method("plcg_scan")
    with pytest.raises(ValueError, match="resolved before"):
        PreparedMeshSolver(spec, poisson2d(8, 8), mesh, M=None, l="auto",
                           sigma=None, spectrum=None)


def test_mesh_auto_never_exceeds_precision_budget(x64, events):
    """The acceptance clamp: a deep-favoring injected table under bf16
    storage must still respect depth_budget -- auto never picks a depth
    whose precision floor misses tol."""
    import jax.numpy as jnp

    from repro.core import Solver, depth_budget, override_latencies
    from repro.core.autotune import attainable_floor
    from repro.launch.mesh import make_mesh_compat
    from repro.operators import poisson2d

    mesh = make_mesh_compat((1, 1), ("data", "model"))
    A = poisson2d(16, 16)
    tol = 2.5e-2
    with override_latencies(_lat(100.0, 10000.0)):
        s = Solver(A, method="plcg_scan", mesh=mesh, l="auto",
                   precision="bf16", tol=tol, maxiter=200)
    budget = depth_budget(tol, jnp.float64, precision="bf16")
    assert s.l <= budget == 1
    assert attainable_floor(s.l, jnp.bfloat16) <= tol
    assert s.auto.budget == budget


def test_mesh_measured_collective_signature_unchanged(x64, events):
    """Measured calibration on the (1, 1) mesh: one shard means only the
    blocking reduction is measurable, auto picks it, and the prepared
    sweep's scan body keeps the ONE-psum signature."""
    from repro.core import Solver
    from repro.kernels.introspect import count_collectives_in_scan_bodies
    from repro.launch.mesh import make_mesh_compat
    from repro.operators import poisson2d

    mesh = make_mesh_compat((1, 1), ("data", "model"))
    A = poisson2d(16, 16)
    b = np.asarray(A @ np.ones(A.n)).reshape(16, 16)
    s = Solver(A, method="plcg_scan", mesh=mesh, l="auto", comm="auto",
               tol=1e-6, maxiter=200)
    assert len(events) == 1 and events[0][0] == "measured"
    assert s.comm.is_blocking               # nshards == 1: only schedule
    assert set(s.auto.latencies["glred_us"]) == {"blocking"}
    r = s.solve(b)
    assert r.converged
    fn = s._mesh_session._get_sweep("plcg", 1e-6)(
        iters=40, batched=False)
    cc = count_collectives_in_scan_bodies(fn, b, b * 0, 20)[0]
    assert cc["psum"] == 1 and cc["reduce_scatter"] == 0


# ------------------- live multi-device (CI auto lane) ---------------------

def test_auto_live_mesh_in_process(x64, events):
    """Under the CI auto lane (8 forced host devices): measured
    calibration on a live (2, 4) mesh sees all three reduction modes,
    decides within the budget, solves correctly, and the chosen policy's
    collective signature is structurally intact.  Skips on single-device
    hosts (the subprocess test below covers those)."""
    import jax

    if len(jax.devices()) < 8:
        pytest.skip("needs >= 8 host devices (CI auto lane forces 8)")
    from repro.core import Solver
    from repro.kernels.introspect import count_collectives_in_scan_bodies
    from repro.launch.mesh import make_mesh_compat
    from repro.operators import poisson2d

    mesh = make_mesh_compat((2, 4), ("data", "model"))
    nx = ny = 64
    A = poisson2d(nx, ny)
    b = np.asarray(A @ np.ones(A.n)).reshape(nx, ny)
    s = Solver(A, method="plcg_scan", mesh=mesh, l="auto", comm="auto",
               tol=1e-6, maxiter=400)
    assert len(events) == 1 and events[0][0] == "measured"
    lat = s.auto.latencies
    # a real multi-shard mesh measures every mode; the (2, 4) torus
    # rings over (2-1) + (4-1) = 4 neighbor hops
    assert set(lat["glred_us"]) == {"blocking", "overlap", "ring"}
    assert lat["ring_hops"] == 4 and lat["nshards"] == 8
    assert 1 <= s.l <= s.auto.budget
    r = s.solve(b)
    assert r.converged and r.info["auto"]["source"] == "measured"
    # chosen policy's structural signature: exactly one reduction path
    fn = s._mesh_session._get_sweep("plcg", 1e-6)(iters=40, batched=False)
    cc = count_collectives_in_scan_bodies(fn, b, b * 0, 20)[0]
    if s.comm.mode == "blocking":
        assert cc["psum"] == 1 and cc["reduce_scatter"] == 0
    elif s.comm.mode == "overlap":
        assert (cc["psum"], cc["reduce_scatter"], cc["all_gather"]) \
            == (0, 1, 1)
    else:                                   # ring: ppermutes only
        assert cc["psum"] == 0 and cc["reduce_scatter"] == 0
    # same-config session: zero re-measure through the weak-key cache
    s2 = Solver(A, method="plcg_scan", mesh=mesh, l="auto", comm="auto",
                tol=1e-6, maxiter=400)
    assert len(events) == 1 and s2.l == s.l
    counts = s.compile_counts()
    s.solve(b)
    assert s.compile_counts() == counts     # zero retraces


@pytest.mark.slow
def test_auto_live_mesh_subprocess(dist_env):
    """Single-device-host coverage of the live path: the same (2, 4)
    measured calibration in a subprocess with 8 forced host devices."""
    res = _run(textwrap.dedent("""
        import json, jax
        jax.config.update("jax_enable_x64", True)
        import numpy as np
        from repro.core import Solver
        from repro.core.autotune import CALIBRATION_EVENTS
        from repro.launch.mesh import make_mesh_compat
        from repro.operators import poisson2d
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        nx = ny = 64
        A = poisson2d(nx, ny)
        b = np.asarray(A @ np.ones(A.n)).reshape(nx, ny)
        s = Solver(A, method="plcg_scan", mesh=mesh, l="auto",
                   comm="auto", tol=1e-6, maxiter=400)
        r = s.solve(b)
        s2 = Solver(A, method="plcg_scan", mesh=mesh, l="auto",
                    comm="auto", tol=1e-6, maxiter=400)
        print(json.dumps({
            "events": len(CALIBRATION_EVENTS),
            "modes": sorted(s.auto.latencies["glred_us"]),
            "l": s.l, "budget": s.auto.budget, "l2": s2.l,
            "comm": s.comm.mode, "conv": bool(r.converged),
            "auto_info": r.info["auto"]["l"]}))
    """), dist_env)
    assert res["events"] == 1               # calibrated once, cached
    assert res["modes"] == ["blocking", "overlap", "ring"]
    assert 1 <= res["l"] <= res["budget"]
    assert res["l2"] == res["l"]
    assert res["conv"] and res["auto_info"] == res["l"]
