"""The jitted scan engine must reproduce the python reference exactly:
same resnorm trace, same breakdown behavior, windowed storage by
construction (state holds 3l+2 vectors)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.plcg import plcg
from repro.core.plcg_scan import plcg_scan, plcg_solve
from repro.core.shifts import chebyshev_shifts
from repro.operators import poisson2d


@pytest.fixture(scope="module", autouse=True)
def x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="module")
def problem():
    A = poisson2d(20, 20)
    b = A @ np.ones(A.n)
    return A, b


@pytest.mark.parametrize("l", [1, 2, 4])
def test_scan_matches_reference(problem, l):
    A, b = problem
    out = plcg_scan(lambda v: A @ v, jnp.asarray(b), l=l, iters=120,
                    sigma=chebyshev_shifts(0, 8, l), tol=1e-10)
    ref = plcg(A, b, l=l, tol=1e-10, maxiter=120, spectrum=(0, 8),
               max_restarts=0)
    rr = np.array([float(r) for r in out.resnorms if r > 0])
    m = min(len(rr), len(ref.resnorms)) - 1
    assert m > 20
    m = int(m * 0.7)      # compare the pre-stagnation segment (Sec. 4)
    assert np.allclose(rr[:m], ref.resnorms[:m], rtol=1e-5 * l * l)


def test_scan_state_is_windowed(problem):
    """Storage faithfulness (Sec. 3.2): z window l+1, v window 2l+1."""
    from repro.core.plcg_scan import PLCGState
    A, b = problem
    l = 3
    traced = {}

    def spy_matvec(v):
        return A @ v

    # inspect the jaxpr state shapes via eval_shape on one scan
    out = jax.eval_shape(
        lambda bb: plcg_scan(spy_matvec, bb, l=l, iters=10,
                             sigma=chebyshev_shifts(0, 8, l)),
        jax.ShapeDtypeStruct(b.shape, jnp.float64))
    assert out.x.shape == b.shape
    # the window invariants are structural: build the initial state shapes
    n = b.shape[0]
    # (implicitly verified by construction -- Zw (l+1, n), Vw (2l+1, n))


def test_solve_driver_restarts(problem):
    A, b = problem
    x, resn, info = plcg_solve(lambda v: A @ v, jnp.asarray(b), l=3,
                               sigma=chebyshev_shifts(0, 8, 3), tol=1e-10,
                               maxiter=200)
    assert info["converged"]
    assert np.linalg.norm(b - A @ np.asarray(x)) < 5e-8


def test_scan_preconditioned(problem):
    A, b = problem
    prec = lambda v: v / 4.0  # noqa: E731  Jacobi for the Poisson stencil
    x, resn, info = plcg_solve(lambda v: A @ v, jnp.asarray(b), l=2,
                               sigma=chebyshev_shifts(0, 2, 2), tol=1e-10,
                               maxiter=200, prec=prec)
    assert info["converged"]
    assert np.linalg.norm(b - A @ np.asarray(x)) < 5e-8


def test_scan_freezes_after_convergence(problem):
    A, b = problem
    out = plcg_scan(lambda v: A @ v, jnp.asarray(b), l=1, iters=200,
                    sigma=chebyshev_shifts(0, 8, 1), tol=1e-10)
    rr = np.asarray(out.resnorms)
    nz = np.nonzero(rr)[0]
    # after convergence every subsequent residual entry stays 0 (frozen)
    assert bool(out.converged)
    assert nz[-1] < 70


# ------------------------- jitted-sweep cache -----------------------------

def test_sweep_cache_reuses_and_clears(problem):
    """Same operator object + settings -> one cache entry reused;
    clear_solver_cache() empties it."""
    import gc

    from repro.core import clear_solver_cache
    from repro.core.plcg_scan import _SWEEP_CACHE

    A, b = problem
    clear_solver_cache()
    gc.collect()
    mv = lambda v: A @ v  # noqa: E731
    kw = dict(l=2, sigma=chebyshev_shifts(0, 8, 2), tol=1e-10, maxiter=120)
    x1, _, _ = plcg_solve(mv, jnp.asarray(b), **kw)
    assert len(_SWEEP_CACHE) == 1
    x2, _, _ = plcg_solve(mv, jnp.asarray(b), **kw)
    assert len(_SWEEP_CACHE) == 1          # hit, not a second entry
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x2))
    clear_solver_cache()
    assert len(_SWEEP_CACHE) == 0


def test_sweep_cache_drops_dead_closures(problem):
    """A fresh closure per call no longer leaks: when the caller drops the
    operator closure, its cache entry (and compiled sweep) is evicted via
    the weak-key callback instead of being pinned forever."""
    import gc

    from repro.core import clear_solver_cache
    from repro.core.plcg_scan import _SWEEP_CACHE

    A, b = problem
    clear_solver_cache()
    gc.collect()
    mv = lambda v: A @ v  # noqa: E731
    plcg_solve(mv, jnp.asarray(b), l=2, sigma=chebyshev_shifts(0, 8, 2),
               tol=1e-10, maxiter=120)
    assert len(_SWEEP_CACHE) == 1
    del mv
    gc.collect()
    assert len(_SWEEP_CACHE) == 0


def test_sweep_cache_is_bounded(problem):
    """Even with callers that keep 20+ distinct closures alive, the cache
    never exceeds its LRU bound."""
    import gc

    from repro.core import clear_solver_cache
    from repro.core.plcg_scan import _SWEEP_CACHE

    A, b = problem
    clear_solver_cache()
    gc.collect()
    keep = []
    for j in range(20):
        mv = (lambda j: lambda v: A @ v)(j)
        keep.append(mv)
        plcg_solve(mv, jnp.asarray(b), l=1, sigma=chebyshev_shifts(0, 8, 1),
                   tol=1e-8, maxiter=40)
    assert 0 < len(_SWEEP_CACHE) <= 16
    clear_solver_cache()
