"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step with shape/NaN checks, plus serve consistency (train == prefill ==
decode logits)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_reduced
from repro.models import (decode_step, forward, init_params, loss_fn,
                          prefill)

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _batch(cfg, seq=S):
    if cfg.family == "encdec":
        return {"frames": jax.random.normal(KEY, (B, seq, cfg.d_model),
                                            jnp.float32),
                "tokens": jax.random.randint(KEY, (B, seq), 0, cfg.vocab)}
    if cfg.embeds_input:
        return {"embeds": jax.random.normal(KEY, (B, seq, cfg.d_model),
                                            jnp.float32),
                "labels": jax.random.randint(KEY, (B, seq), 0, cfg.vocab)}
    return {"tokens": jax.random.randint(KEY, (B, seq), 0, cfg.vocab)}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_reduced(arch)
    params = init_params(cfg, KEY)
    batch = _batch(cfg)
    logits, _ = jax.jit(lambda p, b: forward(cfg, p, b, mode="train"))(
        params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch)))(params)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_serve_consistency(arch):
    """decode logits after prefill == full forward at the same position."""
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(1))
    batch = _batch(cfg)

    def split(b):
        pre = {k: (v[:, : S - 1] if v.ndim >= 2 and v.shape[1] == S else v)
               for k, v in b.items()}
        tok = {k: (v[:, S - 1:] if v.ndim >= 2 and v.shape[1] == S else v)
               for k, v in b.items()}
        if cfg.family == "encdec":
            pre["frames"] = b["frames"]
            tok["frames"] = b["frames"]
        return pre, tok

    pre, tok = split(batch)
    if cfg.embeds_input and "labels" in pre:
        pre.pop("labels"), tok.pop("labels")
    full, _ = jax.jit(lambda p, b: forward(cfg, p, b, mode="train"))(
        params, batch)
    _, caches = jax.jit(lambda p, b: prefill(cfg, p, b, max_len=S))(
        params, pre)
    dec, _ = jax.jit(lambda p, t, c: decode_step(cfg, p, t, c,
                                                 jnp.int32(S - 1)))(
        params, tok, caches)
    err = float(jnp.max(jnp.abs(dec[:, 0] - full[:, -1])))
    scale = float(jnp.max(jnp.abs(full[:, -1]))) + 1e-6
    assert err < 2e-3 * max(scale, 1.0), (arch, err, scale)


@pytest.mark.parametrize("arch", ["mamba2-370m", "recurrentgemma-9b"])
def test_subquadratic_flag(arch):
    assert get_config(arch).subquadratic


def test_param_counts_plausible():
    """Analytic parameter counts should be in the advertised ballpark."""
    expect = {
        "mamba2-370m": (0.3e9, 0.6e9),
        "qwen2-vl-2b": (1.2e9, 2.5e9),
        "qwen3-moe-235b-a22b": (180e9, 280e9),
        "arctic-480b": (380e9, 560e9),
        "mistral-large-123b": (100e9, 140e9),
        "chatglm3-6b": (5e9, 8e9),
        "qwen1.5-32b": (26e9, 40e9),
        "qwen3-14b": (12e9, 18e9),
        "recurrentgemma-9b": (7e9, 12e9),
        "whisper-large-v3": (1.2e9, 2.2e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).num_params()
        assert lo <= n <= hi, (arch, n)


def test_moe_active_params_smaller():
    cfg = get_config("qwen3-moe-235b-a22b")
    assert cfg.num_active_params() < 0.25 * cfg.num_params()
