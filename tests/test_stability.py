"""Stability-at-depth autopilot: in-scan per-lane restarts, residual
replacement, and NaN-safe breakdown recovery.

The acceptance bar of the in-scan restart machinery (``restart=`` /
``residual_replacement=`` on ``plcg_scan``):

* a batched solve where ONE lane hits square-root breakdown recovers
  in-trace (restart counter >= 1, converged) while every OTHER lane is
  **bitwise identical** to the no-breakdown run -- on the single-device
  vmap path and on a live (2, 2) mesh -- through ONE compiled sweep
  (zero retraces);
* the per-iteration collective signature of all three ``comm=`` policies
  is unchanged by recovery (same counts; the stability payload rides the
  existing reduction, one slot wider);
* a NaN-poisoned lane is contained: it parks as a breakdown without
  polluting its siblings or spinning the iteration budget;
* periodic true-residual replacement (``r = b - A x``) closes the
  deep-pipeline residual gap back to the shallow-pipeline level;
* the global ``k_budget`` is an invariant: restarts re-seed the Krylov
  window but never grant extra committed updates.

Breakdown forcing: ``monomial_shifts`` (sigma_i = 0) destabilise the
deep basis within a few dozen iterations on the Poisson operator, while
an eigenvector right-hand side converges in ~2 committed updates --
before any breakdown can develop.  That pair gives one breaking and one
clean lane under a SHARED shift schedule.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import Solver, SolverPool, residual_gap, solve  # noqa: E402
from repro.core import engine  # noqa: E402
from repro.core.shifts import chebyshev_shifts, monomial_shifts  # noqa: E402
from repro.operators import poisson2d  # noqa: E402


def _eig_rhs(A, m=16):
    """RHS aligned with the lowest Poisson eigenvector: the Krylov space
    of b is one-dimensional, so p(l)-CG converges in ~2 committed
    updates -- before monomial-shift instability can trigger a
    breakdown.  The clean sibling lane of every containment test."""
    i = np.arange(1, m + 1)
    v = np.outer(np.sin(np.pi * i / (m + 1)),
                 np.sin(np.pi * i / (m + 1))).reshape(-1)
    v /= np.linalg.norm(v)
    return np.asarray(A @ v)


def _rough_rhs(A, seed=0):
    """RHS exciting the full spectrum: needs enough iterations that
    monomial shifts reliably hit square-root breakdown first."""
    rng = np.random.default_rng(seed)
    return np.asarray(A @ rng.standard_normal(A.n))


# ------------------- NaN/Inf-safe breakdown detection ---------------------

def test_nan_rhs_parks_as_breakdown(x64):
    """A non-finite system must terminate as a breakdown on both the
    python reference and the scan engine -- not spin to maxiter on NaN
    comparisons (every NaN comparison is False, so an unguarded
    ``arg <= 0`` breakdown test never fires)."""
    A = poisson2d(8, 8)
    b = np.asarray(A @ np.ones(A.n))
    b_nan = b.copy()
    b_nan[3] = np.nan
    for method, kw in (("plcg", {}), ("plcg_scan", {})):
        r = solve(A, b_nan, method=method, l=2, spectrum=(0.0, 8.0),
                  tol=1e-8, maxiter=200, **kw)
        assert not r.converged
        assert r.breakdowns >= 1
        assert r.iters < 200          # parked early, not budget-spun


# --------------- per-lane independence: the acceptance bar ----------------

def test_per_lane_restart_independence_vmap(x64):
    """One lane breaks down and recovers in-scan; the sibling lane is
    BITWISE identical to the no-breakdown run; both runs share ONE
    compiled sweep (a single trace event -- zero retraces)."""
    A = poisson2d(16, 16)
    b_eig, b_rough = _eig_rhs(A), _rough_rhs(A)
    sv = Solver(A, method="plcg_scan", l=3, sigma=monomial_shifts(3),
                tol=1e-6, maxiter=300, restart=4)
    engine.clear_batch_trace()
    r_clean = sv.solve(jnp.stack([jnp.asarray(b_eig), jnp.asarray(b_eig)]))
    r_mixed = sv.solve(jnp.stack([jnp.asarray(b_eig), jnp.asarray(b_rough)]))
    assert len(engine.BATCH_TRACE_EVENTS) == 1   # one trace, two solves

    assert list(r_clean.info["per_rhs_restarts"]) == [0, 0]
    rst = list(r_mixed.info["per_rhs_restarts"])
    assert rst[0] == 0 and rst[1] >= 1           # lane 1 broke and restarted
    assert all(r_mixed.info["per_rhs_converged"])

    x_clean = np.asarray(r_clean.x)
    x_mixed = np.asarray(r_mixed.x)
    assert np.array_equal(x_clean[0], x_mixed[0])    # bitwise containment
    assert (r_mixed.info["per_rhs_iters"][0]
            == r_clean.info["per_rhs_iters"][0])

    # the recovered lane actually solved its system
    res = np.linalg.norm(b_rough - np.asarray(A @ x_mixed[1]))
    assert res <= 1e-6 * np.linalg.norm(b_rough)


def test_nan_lane_containment(x64):
    """A NaN-poisoned lane parks as an (unrecoverable) breakdown after
    an attempted re-seed; its sibling lane stays bitwise identical --
    per-lane masking keeps the poison out of the shared reduction's
    committed updates."""
    A = poisson2d(16, 16)
    b_smooth = np.asarray(A @ np.ones(A.n))
    b_nan = _rough_rhs(A)
    b_nan[5] = np.nan
    sv = Solver(A, method="plcg_scan", l=3, spectrum=(0.0, 8.0),
                tol=1e-8, maxiter=200, restart=2)
    r_clean = sv.solve(jnp.stack([jnp.asarray(b_smooth),
                                  jnp.asarray(b_smooth)]))
    r_mixed = sv.solve(jnp.stack([jnp.asarray(b_smooth),
                                  jnp.asarray(b_nan)]))
    conv = list(r_mixed.info["per_rhs_converged"])
    assert conv[0] and not conv[1]
    assert list(r_mixed.info["per_rhs_breakdown"])[1]
    assert np.array_equal(np.asarray(r_clean.x)[0], np.asarray(r_mixed.x)[0])
    assert np.all(np.isfinite(np.asarray(r_mixed.x)[0]))


def test_mesh_per_lane_restart_independence(x64):
    """Containment on a live (2, 2) mesh: lane 0 is BITWISE invariant to
    what happens in lane 1 -- swapping lane 1's RHS for one that breaks
    down and recovers in-scan leaves lane 0's solution, restart count
    and iteration count untouched (the restart state is shard-replicated
    from the globally-reduced scalars, and recovery adds no collectives
    for poison to ride on).  The strict 0-restart-sibling variant lives
    in the vmap test above; an eigenvector lane is a happy-breakdown
    knife edge whose outcome flips with the mesh reduction order, so the
    mesh pair uses two full-spectrum RHS.  Skips below 4 devices; the CI
    stability lane forces 8."""
    if len(jax.devices()) < 4:
        pytest.skip("needs >= 4 host devices (CI stability lane forces 8)")
    from repro.launch.mesh import make_mesh_compat

    mesh = make_mesh_compat((2, 2), ("data", "model"))
    A = poisson2d(16, 16)
    ba = _rough_rhs(A, seed=0).reshape(16, 16)
    bb = _rough_rhs(A, seed=1).reshape(16, 16)
    kw = dict(method="plcg_scan", l=3, sigma=monomial_shifts(3), tol=1e-6,
              maxiter=300, mesh=mesh, restart=4)
    r_clean = solve(A, jnp.stack([jnp.asarray(ba), jnp.asarray(ba)]), **kw)
    r_mixed = solve(A, jnp.stack([jnp.asarray(ba), jnp.asarray(bb)]), **kw)
    rst_c = list(r_clean.info["per_rhs_restarts"])
    rst_m = list(r_mixed.info["per_rhs_restarts"])
    assert rst_m[0] == rst_c[0] and rst_m[1] >= 1    # lane 1 broke, recovered
    assert all(r_mixed.info["per_rhs_converged"])
    assert np.array_equal(np.asarray(r_clean.x)[0], np.asarray(r_mixed.x)[0])
    assert (r_mixed.info["per_rhs_iters"][0]
            == r_clean.info["per_rhs_iters"][0])
    res = np.linalg.norm(np.asarray(bb).reshape(-1)
                         - np.asarray(A @ np.asarray(r_mixed.x)[1].reshape(-1)))
    assert res <= 1e-5 * np.linalg.norm(np.asarray(bb))


# --------------- one restart semantics: in-scan vs host driver ------------

def test_inscan_matches_host_driver_parity(x64):
    """In-scan recovery and the legacy host restart loop are ONE
    semantics: with ritz_refresh off (so both share the shift-free
    re-init rule) the in-scan path matches the host-driver path to
    <= 1e-10 on x with identical restart and iteration counts -- on
    this problem both fire exactly one near-convergence restart, so
    the *triggered* trajectories are compared, not just the idle
    machinery."""
    A = poisson2d(16, 16)
    b = np.asarray(A @ np.ones(A.n))
    kw = dict(method="plcg_scan", l=3, spectrum=(0.0, 8.0), tol=1e-10,
              maxiter=300)
    r_host = solve(A, b, restart=None, max_restarts=3, **kw)
    r_scan = solve(A, b, restart=3, ritz_refresh=False, **kw)
    assert r_host.converged and r_scan.converged
    assert r_host.restarts == r_scan.restarts
    assert r_host.iters == r_scan.iters
    assert (np.linalg.norm(np.asarray(r_host.x) - np.asarray(r_scan.x))
            <= 1e-10 * np.linalg.norm(np.asarray(r_host.x)))


def test_restart_and_max_restarts_mutually_exclusive(x64):
    """ONE restart semantics: the in-scan knob and the deprecated host
    loop cannot be combined, and the knob table rejects restart knobs
    uniformly for methods without support."""
    A = poisson2d(8, 8)
    b = np.asarray(A @ np.ones(A.n))
    with pytest.raises(ValueError, match="mutually exclusive"):
        solve(A, b, method="plcg_scan", l=2, spectrum=(0.0, 8.0),
              restart=2, max_restarts=1, maxiter=50)
    for bad in (dict(restart=2), dict(residual_replacement=10)):
        with pytest.raises(ValueError, match="plcg_scan"):
            solve(A, b, method="cg", maxiter=50, **bad)
    with pytest.raises(ValueError, match="period >= 1"):
        solve(A, b, method="plcg_scan", l=2, spectrum=(0.0, 8.0),
              residual_replacement=0, maxiter=50)
    assert "plcg_scan" in engine.methods_supporting("restart")


# ------------------------ global budget invariant -------------------------

@pytest.mark.filterwarnings("ignore:tol=.*below")
def test_restarts_never_extend_committed_budget(x64):
    """Restarts re-seed the window but the committed-update budget is
    global: total iterations never exceed maxiter even while lanes
    restart (the extra scan bodies are pipeline re-fill, not updates)."""
    A = poisson2d(16, 16)
    b_rough = _rough_rhs(A)
    r = solve(A, b_rough, method="plcg_scan", l=3,
              sigma=monomial_shifts(3), tol=1e-14, maxiter=30, restart=5)
    assert r.iters <= 30
    assert len(np.asarray(r.resnorms)) <= 31      # r0 + at most maxiter
    rb = solve(A, jnp.stack([jnp.asarray(b_rough), jnp.asarray(_eig_rhs(A))]),
               method="plcg_scan", l=3, sigma=monomial_shifts(3),
               tol=1e-14, maxiter=30, restart=5)
    assert max(int(k) for k in rb.info["per_rhs_iters"]) <= 30


# --------------------- residual replacement accuracy ----------------------

def test_residual_replacement_closes_deep_pipeline_gap(x64):
    """Deep pipelines drift: the recurrence residual decouples from the
    true residual b - Ax as l grows (paper Sec. 4).  Periodic
    replacement re-syncs them -- the l=6 replaced run must (a) at least
    halve the l=6 unreplaced relative gap and (b) come back down to the
    shallow l=1 gap level."""
    A = poisson2d(32, 32)
    b = np.asarray(A @ np.ones(A.n))
    kw = dict(method="plcg_scan", spectrum=(0.0, 8.0), tol=1e-14,
              maxiter=3000)
    g1 = residual_gap(A, b, solve(A, b, l=1, **kw))
    r_deep = solve(A, b, l=6, **kw)
    r_repl = solve(A, b, l=6, residual_replacement=20, restart=None, **kw)
    assert r_deep.converged and r_repl.converged
    assert r_repl.replacements >= 1
    g_deep = residual_gap(A, b, r_deep)
    g_repl = residual_gap(A, b, r_repl)
    assert g_repl["rel_gap"] <= 0.5 * g_deep["rel_gap"]
    assert g_repl["rel_gap"] <= g1["rel_gap"]


def test_residual_replacement_auto_arms_restart(x64):
    """residual_replacement= alone puts the sweep in stability mode, so
    restart="auto" resolves to a real cap (recovery is then free); the
    default solve keeps restart=None (the fused fast path is untouched)."""
    A = poisson2d(16, 16)
    b = np.asarray(A @ np.ones(A.n))
    r = solve(A, b, method="plcg_scan", l=4, spectrum=(0.0, 8.0),
              tol=1e-12, maxiter=600, residual_replacement=25)
    assert r.info["restart"] == 5 and r.replacements >= 1
    rd = solve(A, b, method="plcg_scan", l=2, spectrum=(0.0, 8.0),
               tol=1e-10, maxiter=300)
    assert rd.info.get("restart") is None


# ---------------------- backend parity under recovery ---------------------

def test_backend_parity_with_restarts(x64):
    """All execution tiers agree through a breakdown + in-scan recovery:
    the reference scan, the Pallas kernel tier and the fused-stencil
    tier produce the same recovered solution (<= 1e-8) with the same
    restart count."""
    A = poisson2d(16, 16)
    b_rough = _rough_rhs(A)
    kw = dict(method="plcg_scan", l=3, sigma=monomial_shifts(3), tol=1e-6,
              maxiter=300, restart=4)
    bnorm = np.linalg.norm(b_rough)
    ref = solve(A, b_rough, backend=None, **kw)
    assert ref.converged and ref.restarts >= 1
    for backend in ("pallas", "fused"):
        r = solve(A, b_rough, backend=backend, **kw)
        assert r.converged and r.restarts == ref.restarts
        assert r.iters == ref.iters
        # restart trigger points are roundoff-sensitive, so post-recovery
        # trajectories agree to ~tol, not to machine precision: gate each
        # tier on its own true residual plus a coarse cross-tier match
        res = np.linalg.norm(b_rough - np.asarray(A @ np.asarray(r.x)))
        assert res <= 5e-6 * bnorm
        assert (np.linalg.norm(np.asarray(r.x) - np.asarray(ref.x))
                <= 1e-4 * np.linalg.norm(np.asarray(ref.x)))


# -------------- collective signature: structural invariance ---------------

def test_collective_signature_unchanged_by_stability(x64):
    """Recovery adds ZERO collectives: per scan body every comm= policy
    has the same collective counts with and without restart= -- the
    stability payload rides the existing reduction, exactly one slot
    wider ((2l+2,) vs (2l+1,) on the blocking psum)."""
    from repro.distributed import DistPoisson, plcg_mesh_sweep
    from repro.kernels.introspect import (
        collective_payload_shapes_in_scan_bodies,
        count_collectives_in_scan_bodies)
    from repro.launch.mesh import make_mesh_compat

    mesh = make_mesh_compat((1, 1), ("data", "model"))
    op = DistPoisson(16, 16, mesh)
    l = 3
    sig = tuple(chebyshev_shifts(0, 8, l))
    b = jnp.ones((16, 16))

    def sweep(comm, restart):
        return plcg_mesh_sweep(op, l=l, iters=30, sigma=sig, tol=1e-8,
                               comm=comm, restart=restart)

    for comm in ("blocking", "overlap", "ring"):
        base = count_collectives_in_scan_bodies(
            sweep(comm, None), b, b * 0, 30)[0]
        stab = count_collectives_in_scan_bodies(
            sweep(comm, 2), b, b * 0, 30)[0]
        assert stab == base, comm

    def psum_shapes(restart):
        pairs = collective_payload_shapes_in_scan_bodies(
            sweep("blocking", restart), b, b * 0, 30)[0]
        return [s for p, s in pairs if p == "psum"]

    assert psum_shapes(None) == [(2 * l + 1,)]
    assert psum_shapes(2) == [(2 * l + 2,)]      # one extra slot, one psum


# ----------------------- pooled dispatch recovery -------------------------

def test_pool_lanes_restart_independently(x64):
    """SolverPool flushes carry per-lane restart counts back onto each
    handle's SolveResult: a breaking submission recovers without
    touching the clean one."""
    A = poisson2d(16, 16)
    sv = Solver(A, method="plcg_scan", l=3, sigma=monomial_shifts(3),
                tol=1e-6, maxiter=300, restart=4)
    pool = SolverPool(sv, max_batch=4)
    h_clean = pool.submit(jnp.asarray(_eig_rhs(A)))
    h_break = pool.submit(jnp.asarray(_rough_rhs(A)))
    pool.flush()
    r_clean, r_break = h_clean.result(), h_break.result()
    assert r_clean.converged and r_clean.restarts == 0
    assert r_break.converged and r_break.restarts >= 1
