"""Training substrate: optimizers, checkpoint/restart, compression,
Newton-pCG."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import init_params, loss_fn
from repro.training import (AdamWConfig, CheckpointManager, NewtonPCGConfig,
                            adamw_init, adamw_update, compress_grads,
                            compress_init, decompress_grads, newton_pcg_step)
from repro.training.data import synth_batch
from repro.training.monitor import StragglerMonitor


def _tiny_params(key, shapes=((64, 128), (128,), (8, 16, 32))):
    ks = jax.random.split(key, len(shapes))
    return {f"p{i}": jax.random.normal(k, s, jnp.float32)
            for i, (k, s) in enumerate(zip(ks, shapes))}


def test_adamw_decreases_quadratic():
    params = {"w": jnp.ones((32,)) * 3.0}
    ocfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    state = adamw_init(params, ocfg)
    loss = lambda p: jnp.sum(p["w"] ** 2)  # noqa: E731
    first = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state = adamw_update(params, g, state, ocfg)
    # Adam oscillates near the optimum at fixed lr; require a 50x reduction
    assert float(loss(params)) < first / 50.0


def test_adamw8bit_tracks_fp32():
    key = jax.random.PRNGKey(0)
    params = _tiny_params(key)
    g = jax.tree.map(lambda p: jnp.ones_like(p) * 0.1, params)
    o32 = AdamWConfig(lr=1e-2)
    o8 = AdamWConfig(lr=1e-2, eightbit=True)
    s32, s8 = adamw_init(params, o32), adamw_init(params, o8)
    p32, p8 = params, params
    for _ in range(10):
        p32, s32 = adamw_update(p32, g, s32, o32)
        p8, s8 = adamw_update(p8, g, s8, o8)
    for k in params:
        np.testing.assert_allclose(np.asarray(p8[k]), np.asarray(p32[k]),
                                   atol=5e-3)


def test_grad_compression_error_feedback():
    """Error feedback makes the *accumulated* compressed gradient unbiased:
    sum of dequantized payloads + final residual == sum of true grads."""
    key = jax.random.PRNGKey(1)
    params = _tiny_params(key)
    res = compress_init(params)
    total_true = jax.tree.map(jnp.zeros_like, params)
    total_sent = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    for i in range(5):
        g = jax.tree.map(
            lambda p, kk=i: jax.random.normal(jax.random.PRNGKey(kk),
                                              p.shape, jnp.float32), params)
        payload, res = compress_grads(g, res)
        deq = decompress_grads(payload, params)
        total_true = jax.tree.map(lambda a, b: a + b, total_true, g)
        total_sent = jax.tree.map(lambda a, b: a + b, total_sent, deq)
    for k in params:
        gap = np.asarray(total_true[k] - total_sent[k] - res[k])
        assert np.max(np.abs(gap)) < 1e-4


def test_checkpoint_roundtrip_and_resume(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    key = jax.random.PRNGKey(2)
    tree = {"params": _tiny_params(key), "opt": {"count": jnp.int32(7)}}
    mgr.save(10, tree, extra={"note": "a"})
    mgr.save(20, tree)
    mgr.save(30, tree)
    assert mgr.steps() == [20, 30]          # keep-2 GC
    step, restored, extra = mgr.restore()
    assert step == 30
    for k in tree["params"]:
        np.testing.assert_array_equal(np.asarray(restored["params"][k]),
                                      np.asarray(tree["params"][k]))
    assert int(restored["opt"]["count"]) == 7


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = {"w": jnp.arange(100.0)}
    mgr.save_async(5, tree)
    mgr.wait()
    step, restored, _ = mgr.restore()
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(100.0))


def test_training_resume_bitexact(tmp_path):
    """Fault tolerance: train 4 steps straight == train 2, crash, resume 2."""
    cfg = get_reduced("chatglm3-6b")
    from repro.launch.steps import build_train_step
    ocfg = AdamWConfig(lr=1e-3)
    step_fn = jax.jit(build_train_step(cfg, ocfg, remat="none"))

    def run(params, opt, s0, s1):
        for s in range(s0, s1):
            batch = synth_batch(cfg, s, 2, 16, seed=3)
            params, opt, _ = step_fn(params, opt, batch)
        return params, opt

    p0 = init_params(cfg, jax.random.PRNGKey(0))
    o0 = adamw_init(p0, ocfg)
    pa, oa = run(p0, o0, 0, 4)

    pb, ob = run(p0, o0, 0, 2)
    mgr = CheckpointManager(tmp_path)
    mgr.save(2, {"params": pb, "opt": ob})
    _, tree, _ = mgr.restore()
    pc, oc = run(tree["params"], tree["opt"], 2, 4)
    for a, c in zip(jax.tree.leaves(pa), jax.tree.leaves(pc)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32), atol=1e-6)


def test_newton_pcg_reduces_loss():
    cfg = get_reduced("qwen3-14b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    ncfg = NewtonPCGConfig(l=2, cg_iters=6, lr=0.5)
    lf = lambda p, b: loss_fn(cfg, p, b)  # noqa: E731
    step = jax.jit(lambda p, b: newton_pcg_step(lf, p, b, ncfg))
    batch = synth_batch(cfg, 0, 2, 32, seed=0)
    l0 = float(loss_fn(cfg, params, batch))
    for i in range(3):
        params, stats = step(params, batch)
    l1 = float(loss_fn(cfg, params, batch))
    assert l1 < l0


def test_straggler_monitor():
    mon = StragglerMonitor(k_sigma=3.0, warmup=3)
    for i in range(10):
        assert not mon.record(i, 1.0 + 0.01 * (i % 2))
    assert mon.record(10, 10.0)
    assert mon.flagged == 1
