"""Training substrate: optimizers, checkpoint/restart, compression,
Newton-pCG, and the Newton-CG trainer subsystem (GGN operators +
prepared deep-pipelined inner solves)."""
import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import init_params, loss_fn
from repro.training import (AdamWConfig, CheckpointManager, GGNOperator,
                            NewtonPCGConfig, NewtonPCGTrainer, adamw_init,
                            adamw_update, compress_grads, compress_init,
                            decompress_grads, estimate_ggn_lmax,
                            newton_pcg_step)
from repro.training.data import synth_batch
from repro.training.monitor import StragglerMonitor


def _tiny_params(key, shapes=((64, 128), (128,), (8, 16, 32))):
    ks = jax.random.split(key, len(shapes))
    return {f"p{i}": jax.random.normal(k, s, jnp.float32)
            for i, (k, s) in enumerate(zip(ks, shapes))}


def test_adamw_decreases_quadratic():
    params = {"w": jnp.ones((32,)) * 3.0}
    ocfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    state = adamw_init(params, ocfg)
    loss = lambda p: jnp.sum(p["w"] ** 2)  # noqa: E731
    first = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state = adamw_update(params, g, state, ocfg)
    # Adam oscillates near the optimum at fixed lr; require a 50x reduction
    assert float(loss(params)) < first / 50.0


def test_adamw8bit_tracks_fp32():
    key = jax.random.PRNGKey(0)
    params = _tiny_params(key)
    g = jax.tree.map(lambda p: jnp.ones_like(p) * 0.1, params)
    o32 = AdamWConfig(lr=1e-2)
    o8 = AdamWConfig(lr=1e-2, eightbit=True)
    s32, s8 = adamw_init(params, o32), adamw_init(params, o8)
    p32, p8 = params, params
    for _ in range(10):
        p32, s32 = adamw_update(p32, g, s32, o32)
        p8, s8 = adamw_update(p8, g, s8, o8)
    for k in params:
        np.testing.assert_allclose(np.asarray(p8[k]), np.asarray(p32[k]),
                                   atol=5e-3)


def test_grad_compression_error_feedback():
    """Error feedback makes the *accumulated* compressed gradient unbiased:
    sum of dequantized payloads + final residual == sum of true grads."""
    key = jax.random.PRNGKey(1)
    params = _tiny_params(key)
    res = compress_init(params)
    total_true = jax.tree.map(jnp.zeros_like, params)
    total_sent = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    for i in range(5):
        g = jax.tree.map(
            lambda p, kk=i: jax.random.normal(jax.random.PRNGKey(kk),
                                              p.shape, jnp.float32), params)
        payload, res = compress_grads(g, res)
        deq = decompress_grads(payload, params)
        total_true = jax.tree.map(lambda a, b: a + b, total_true, g)
        total_sent = jax.tree.map(lambda a, b: a + b, total_sent, deq)
    for k in params:
        gap = np.asarray(total_true[k] - total_sent[k] - res[k])
        assert np.max(np.abs(gap)) < 1e-4


def test_checkpoint_roundtrip_and_resume(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    key = jax.random.PRNGKey(2)
    tree = {"params": _tiny_params(key), "opt": {"count": jnp.int32(7)}}
    mgr.save(10, tree, extra={"note": "a"})
    mgr.save(20, tree)
    mgr.save(30, tree)
    assert mgr.steps() == [20, 30]          # keep-2 GC
    step, restored, extra = mgr.restore()
    assert step == 30
    for k in tree["params"]:
        np.testing.assert_array_equal(np.asarray(restored["params"][k]),
                                      np.asarray(tree["params"][k]))
    assert int(restored["opt"]["count"]) == 7


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = {"w": jnp.arange(100.0)}
    mgr.save_async(5, tree)
    mgr.wait()
    step, restored, _ = mgr.restore()
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(100.0))


def test_training_resume_bitexact(tmp_path):
    """Fault tolerance: train 4 steps straight == train 2, crash, resume 2."""
    cfg = get_reduced("chatglm3-6b")
    from repro.launch.steps import build_train_step
    ocfg = AdamWConfig(lr=1e-3)
    step_fn = jax.jit(build_train_step(cfg, ocfg, remat="none"))

    def run(params, opt, s0, s1):
        for s in range(s0, s1):
            batch = synth_batch(cfg, s, 2, 16, seed=3)
            params, opt, _ = step_fn(params, opt, batch)
        return params, opt

    p0 = init_params(cfg, jax.random.PRNGKey(0))
    o0 = adamw_init(p0, ocfg)
    pa, oa = run(p0, o0, 0, 4)

    pb, ob = run(p0, o0, 0, 2)
    mgr = CheckpointManager(tmp_path)
    mgr.save(2, {"params": pb, "opt": ob})
    _, tree, _ = mgr.restore()
    pc, oc = run(tree["params"], tree["opt"], 2, 4)
    for a, c in zip(jax.tree.leaves(pa), jax.tree.leaves(pc)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32), atol=1e-6)


def test_newton_pcg_reduces_loss():
    cfg = get_reduced("qwen3-14b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    ncfg = NewtonPCGConfig(l=2, cg_iters=6, lr=0.5)
    lf = lambda p, b: loss_fn(cfg, p, b)  # noqa: E731
    step = jax.jit(lambda p, b: newton_pcg_step(lf, p, b, ncfg))
    batch = synth_batch(cfg, 0, 2, 32, seed=0)
    l0 = float(loss_fn(cfg, params, batch))
    for i in range(3):
        params, stats = step(params, batch)
    l1 = float(loss_fn(cfg, params, batch))
    assert l1 < l0


def test_straggler_monitor():
    mon = StragglerMonitor(k_sigma=3.0, warmup=3)
    for i in range(10):
        assert not mon.record(i, 1.0 + 0.01 * (i % 2))
    assert mon.record(10, 10.0)
    assert mon.flagged == 1


# ---------------------------------------------------------------------------
# Newton-CG training subsystem: GGN operators + NewtonPCGTrainer
# ---------------------------------------------------------------------------

def _ls_problem(dtype, seed=5, n_feat=24, n_out=6, m=32):
    """A linear least-squares training problem: loss_fn(params, batch),
    initial params, and a per-step synthetic batch generator."""
    rng = np.random.default_rng(seed)
    params = {
        "w": jnp.asarray(rng.standard_normal((n_feat, n_out)) * 0.3, dtype),
        "b": jnp.zeros((n_out,), dtype),
    }

    def lf(p, batch):
        x, y = batch
        pred = x @ p["w"] + p["b"]
        return 0.5 * jnp.mean((pred - y) ** 2)

    def batch_at(step):
        r = np.random.default_rng(100 + step)
        return (jnp.asarray(r.standard_normal((m, n_feat)), dtype),
                jnp.asarray(r.standard_normal((m, n_out)), dtype))

    return lf, params, batch_at


def test_ggn_operator_matvec_and_bind():
    """GGNOperator.matvec is the damped Hessian product at the CURRENT
    context, and bind() swaps in fresh parameters without a new closure."""
    from jax.flatten_util import ravel_pytree

    # cubic loss: hvp depends on the linearization point (H = 2 diag(w))
    def lf(p, batch):
        return jnp.sum(p["w"] ** 3) / 3.0

    params = {"w": jnp.arange(1.0, 9.0)}
    op = GGNOperator(lf, params, batch=None, damping=0.5)
    v = jnp.ones(8)
    p_flat, _ = ravel_pytree(params)
    np.testing.assert_allclose(np.asarray(op.matvec(v)),
                               np.asarray(2.0 * p_flat + 0.5), rtol=1e-6)
    mv_old = op.matvec_ctx                 # the closure is stable...
    op.bind(3.0 * p_flat, None)            # ...only the context moves
    assert op.matvec_ctx is mv_old
    np.testing.assert_allclose(np.asarray(op.matvec(v)),
                               np.asarray(6.0 * p_flat + 0.5), rtol=1e-6)


def test_estimate_ggn_lmax_quadratic():
    """The power-iteration bound tracks the true top eigenvalue of a
    known quadratic (replacing the old hardcoded 10.0)."""
    from jax.flatten_util import ravel_pytree

    q = jnp.asarray(np.linspace(0.5, 4.0, 16), jnp.float32)

    def lf(p, batch):
        return 0.5 * jnp.sum(q * p["w"] ** 2)

    params = {"w": jnp.ones(16, jnp.float32)}
    p_flat, unravel = ravel_pytree(params)
    est = estimate_ggn_lmax(lf, unravel, p_flat, None, damping=1e-2,
                            power_iters=40)
    # exact top eigenvalue of (diag(q) + damping I) is 4.01; the estimate
    # carries the conventional 1.05 safety factor
    assert abs(est - 1.05 * 4.01) / 4.01 < 0.05


def test_trainer_matches_legacy_newton_step(x64):
    """Engine-backed trainer step == direct newton_pcg_step to <= 1e-10 on
    the Newton direction (same pinned spectrum, same depth/tol/budget)."""
    from jax.flatten_util import ravel_pytree

    lf, params, batch_at = _ls_problem(jnp.float64)
    batch = batch_at(0)
    # pin the power-iteration spectral bound so both paths build identical
    # Chebyshev shifts (a bad bound breaks the auxiliary recurrences down,
    # and then the two paths legitimately diverge: the direct step freezes
    # at the breakdown iterate while the engine restarts and converges)
    p_flat, unravel = ravel_pytree(params)
    lmax = estimate_ggn_lmax(lf, unravel, p_flat, batch, damping=0.1,
                             power_iters=30)
    cfg = NewtonPCGConfig(l=2, cg_iters=40, damping=0.1, lr=1.0,
                          cg_tol=1e-8, lmax_estimate=float(lmax))
    p_legacy, _ = newton_pcg_step(lf, params, batch, cfg)
    tr = NewtonPCGTrainer(lf, cfg)
    p_engine, stats = tr.step(params, batch)
    assert stats["cg_converged"] and not stats["cg_breakdown"]
    for k in params:
        np.testing.assert_allclose(np.asarray(p_engine[k]),
                                   np.asarray(p_legacy[k]),
                                   rtol=0.0, atol=1e-10)


def test_trainer_zero_retrace_across_rebinds():
    """Outer steps 2..N rebind fresh (params, batch) into the step-1
    compiled sweep: compile_counts() stays at 1 everywhere, while the
    rebound data actually steers the solve (directions differ)."""
    lf, params, batch_at = _ls_problem(jnp.float32)
    cfg = NewtonPCGConfig(l=2, cg_iters=8, damping=0.1, lr=0.5)
    tr = NewtonPCGTrainer(lf, cfg)
    from jax.flatten_util import ravel_pytree
    deltas = []
    prev = params
    for i in range(4):
        params, stats = tr.step(params, batch_at(i))
        pa, _ = ravel_pytree(prev)
        pb, _ = ravel_pytree(params)
        deltas.append(np.asarray(pb - pa))
        prev = params
        if i == 0:
            first = dict(tr.compile_counts())
            assert first and all(v == 1 for v in first.values())
    assert dict(tr.compile_counts()) == first
    # rebinds took effect: per-step Newton directions are not the same
    assert not np.allclose(deltas[0], deltas[1])


def test_trainer_reduces_loss_and_grad_norm():
    """5 outer steps on the reduced model config: loss and gradient norm
    both decrease (the subsystem form of test_newton_pcg_reduces_loss)."""
    cfg = get_reduced("qwen3-14b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    ncfg = NewtonPCGConfig(l=2, cg_iters=6, lr=0.5)
    lf = lambda p, b: loss_fn(cfg, p, b)  # noqa: E731
    tr = NewtonPCGTrainer(lf, ncfg, power_iters=4)
    batch = synth_batch(cfg, 0, 2, 32, seed=0)
    hist = []
    for i in range(5):
        params, stats = tr.step(params, batch)
        hist.append((float(stats["loss"]), float(stats["grad_norm"])))
    assert hist[-1][0] < hist[0][0]
    assert hist[-1][1] < hist[0][1]


def test_trainer_l_auto_injected_latencies():
    """l='auto' calibrates the depth from the latency table: with one
    reduction costing 3 HVPs, the chosen depth hides 3 per reduction."""
    from repro.core.autotune import override_latencies

    lf, params, batch_at = _ls_problem(jnp.float32)
    cfg = NewtonPCGConfig(l="auto", cg_iters=8, damping=0.1)
    tr = NewtonPCGTrainer(lf, cfg)
    with override_latencies({"spmv_us": 100.0,
                             "glred_us": {"blocking": 300.0}}):
        params, stats = tr.step(params, batch_at(0))
    assert tr.solver.l == 3
    assert stats["auto"] is not None and stats["auto"]["l"] == 3
    assert np.isfinite(stats["loss"])


@pytest.mark.parametrize("knobs", [
    dict(precision="bf16"),
    dict(restart=3, residual_replacement=5),
])
def test_trainer_engine_knobs(knobs):
    """Solver-engine knobs pass through the trainer: bf16 window storage
    and in-scan restart/residual replacement run and stay zero-retrace."""
    lf, params, batch_at = _ls_problem(jnp.float32)
    cfg = NewtonPCGConfig(l=2, cg_iters=8, damping=0.1, lr=0.5)
    tr = NewtonPCGTrainer(lf, cfg, **knobs)
    for i in range(2):
        params, stats = tr.step(params, batch_at(i))
        assert np.isfinite(stats["loss"])
    counts = tr.compile_counts()
    assert counts and all(v == 1 for v in counts.values())


def test_trainer_reports_to_monitor(tmp_path):
    """Per-step solver evidence reaches the monitor and rides the next
    heartbeat."""
    hb = tmp_path / "heartbeat.json"
    mon = StragglerMonitor(heartbeat_path=str(hb))
    lf, params, batch_at = _ls_problem(jnp.float32)
    cfg = NewtonPCGConfig(l=2, cg_iters=8, damping=0.1)
    tr = NewtonPCGTrainer(lf, cfg, monitor=mon)
    for i in range(2):
        params, stats = tr.step(params, batch_at(i))
        mon.record(i, stats["step_s"])
    assert len(mon.solves) == 2
    assert {"step", "iters", "converged", "restarts",
            "replacements"} <= set(mon.solves[0])
    beat = json.loads(hb.read_text())
    assert beat["solve"]["step"] == 1
    assert beat["solve"]["iters"] >= 1


# ----------------------- live-mesh subprocess tests -----------------------

def _run(code: str, env: dict) -> dict:
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


_MESH_PRELUDE = r"""
import json
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from repro.training import NewtonPCGConfig, NewtonPCGTrainer

def ls_problem(dtype, seed=5, n_feat=24, n_out=6, m=32):
    rng = np.random.default_rng(seed)
    params = {
        "w": jnp.asarray(rng.standard_normal((n_feat, n_out)) * 0.3, dtype),
        "b": jnp.zeros((n_out,), dtype),
    }
    def lf(p, batch):
        x, y = batch
        return 0.5 * jnp.mean((x @ p["w"] + p["b"] - y) ** 2)
    def batch_at(step):
        r = np.random.default_rng(100 + step)
        return (jnp.asarray(r.standard_normal((m, n_feat)), dtype),
                jnp.asarray(r.standard_normal((m, n_out)), dtype))
    return lf, params, batch_at

mesh = Mesh(np.array(jax.devices()[:4]).reshape(2, 2), ("data", "model"))

# one shared, well-placed spectral bound: both the mesh and the
# single-device reference trainer must build IDENTICAL Chebyshev shifts
from jax.flatten_util import ravel_pytree
from repro.training import estimate_ggn_lmax
_lf, _p, _b = ls_problem(jnp.float32)
_pf, _unr = ravel_pytree(_p)
LMAX = float(estimate_ggn_lmax(_lf, _unr, _pf, _b(0), damping=0.1,
                               power_iters=20))
"""


def test_trainer_mesh_live_step(dist_env):
    """Live (2, 2)-mesh outer steps: exactly ONE stacked psum per inner
    p(l)-CG iteration (structural jaxpr gate on the prepared sweep),
    mesh == single-device Newton directions, and zero retraces across
    rebinding outer steps."""
    code = _MESH_PRELUDE + r"""
from repro.kernels.introspect import count_primitive_in_scan_bodies

cfg = NewtonPCGConfig(l=3, cg_iters=8, damping=0.1, lr=0.5,
                      lmax_estimate=LMAX)

lf, params, batch_at = ls_problem(jnp.float32)
tr = NewtonPCGTrainer(lf, cfg, mesh=mesh)
lf1, p1, _ = ls_problem(jnp.float32)
single = NewtonPCGTrainer(lf1, cfg)

losses, gaps = [], []
for i in range(3):
    p_in = params
    params, stats = tr.step(params, batch_at(i))
    # one-step parity from the SAME state (f32 trajectories would
    # otherwise drift apart across steps); the single twin still
    # exercises its own rebind path every step
    p1, s1 = single.step(p_in, batch_at(i))
    losses.append(float(stats["loss"]))
    ref = np.concatenate([np.asarray(p1[k]).ravel() for k in sorted(p1)])
    got = np.concatenate([np.asarray(params[k]).ravel()
                          for k in sorted(params)])
    gaps.append(float(np.max(np.abs(got - ref))))

counts = list(tr.compile_counts().values())

op = tr.op
raw = next(iter(tr.solver._mesh_session._sweeps.values()))
b = jnp.zeros((op.n_pad,), jnp.float32)
psums = count_primitive_in_scan_bodies(raw, "psum", op.context, b,
                                       jnp.zeros_like(b), cfg.cg_iters)

print(json.dumps({"losses": losses, "gaps": gaps, "counts": counts,
                  "psums": psums, "iters": int(stats["cg_iters"])}))
"""
    out = _run(code, dist_env)
    assert out["counts"] and all(c == 1 for c in out["counts"])
    assert out["psums"] == [1]
    assert max(out["gaps"]) < 1e-5
    assert out["losses"][-1] < out["losses"][0]


def test_trainer_mesh_knob_matrix(dist_env):
    """The full engine knob matrix through the trainer on a live mesh:
    comm=overlap/ring, precision=bf16, and l='auto'+comm='auto' with an
    injected latency table (one reduction = 3 HVPs -> depth 3).  Every
    configuration must agree with blocking f32 on the first Newton
    direction and stay zero-retrace over rebinding steps."""
    code = _MESH_PRELUDE + r"""
from repro.core.autotune import override_latencies

cfg = NewtonPCGConfig(l=3, cg_iters=8, damping=0.1, lr=0.5,
                      lmax_estimate=LMAX)

def run(tcfg, steps=2, **kw):
    lf, params, batch_at = ls_problem(jnp.float32)
    tr = NewtonPCGTrainer(lf, tcfg, mesh=mesh, **kw)
    for i in range(steps):
        params, stats = tr.step(params, batch_at(i))
    flat = np.concatenate([np.asarray(params[k]).ravel()
                           for k in sorted(params)])
    return tr, flat, stats

_, ref, _ = run(cfg)
out = {}
for name, kw in [("overlap", dict(comm="overlap")),
                 ("ring", dict(comm="ring")),
                 ("bf16", dict(precision="bf16"))]:
    tr, flat, stats = run(cfg, **kw)
    out[name] = {"gap": float(np.max(np.abs(flat - ref))),
                 "counts": list(tr.compile_counts().values()),
                 "finite": bool(np.isfinite(stats["loss"]))}

acfg = NewtonPCGConfig(l="auto", cg_iters=8, damping=0.1, lr=0.5,
                       lmax_estimate=LMAX)
with override_latencies({"spmv_us": 100.0,
                         "glred_us": {"blocking": 300.0,
                                      "overlap": 240.0,
                                      "ring": 420.0}}):
    tr, flat, stats = run(acfg, comm="auto")
out["auto"] = {"l": tr.solver.l, "comm": stats["auto"]["comm"],
               "info_l": stats["auto"]["l"],
               "counts": list(tr.compile_counts().values())}
print(json.dumps(out))
"""
    out = _run(code, dist_env)
    for name in ("overlap", "ring"):
        assert out[name]["gap"] < 1e-5, (name, out[name])
    for name in ("overlap", "ring", "bf16"):
        assert out[name]["finite"]
        assert all(c == 1 for c in out[name]["counts"]), (name, out[name])
    # one reduction costs ~3 HVPs -> the calibrated depth hides 3, and the
    # cheapest policy at that depth wins
    assert out["auto"]["l"] == 3 and out["auto"]["info_l"] == 3
    assert out["auto"]["comm"] in ("blocking", "overlap", "ring")
    assert all(c == 1 for c in out["auto"]["counts"])
