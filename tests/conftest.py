import os

# smoke tests and benches must see ONE device (the dry-run sets its own
# device count in a separate process)
os.environ.setdefault("XLA_FLAGS", "")

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)
