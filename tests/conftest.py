import os

# smoke tests and benches must see ONE device (the dry-run sets its own
# device count in a separate process)
os.environ.setdefault("XLA_FLAGS", "")

import numpy as np  # noqa: E402
import pytest  # noqa: E402

#: XLA flag forcing 8 host devices -- the distributed suite runs its
#: payloads in subprocesses with this env so multi-device behaviour is
#: deterministic on single-device hosts (laptops, CI runners) without
#: perturbing the single-device main process.
DIST_XLA_FLAGS = "--xla_force_host_platform_device_count=8"


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def x64():
    """Enable f64 for one test, restoring the previous setting after."""
    import jax
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", old)


@pytest.fixture(scope="session")
def dist_env():
    """Environment for the multi-device subprocess tests: 8 forced host
    devices + src on PYTHONPATH."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = DIST_XLA_FLAGS
    env["PYTHONPATH"] = os.path.join(repo, "src")
    return env
