"""Mixed-precision benchmarks: what bf16 window storage saves and costs.

The p(l)-CG footprint is dominated by the 3l+3 window vectors
(``Vw (n, 2l+1)``, ``Zw (n, l+1)``, ``Zhw (n, 3)``) and the fused
iteration streams all of them through HBM, so the ``precision=`` policy
(``repro.core.precision``) targets exactly that traffic: windows + SPMV
stream in a low-precision *storage* dtype, every scalar recurrence, dot
payload, collective buffer and convergence test in the f32/f64 *compute*
dtype.  Three row groups:

* ``mp/traffic_{f32,bf16}_l{1,3,5}`` -- bytes each fused iteration moves
  through the window-dominated path, measured by summing the ``nbytes``
  of the actual per-iteration operand buffers at each storage dtype (the
  value column is bytes/iter, not us).  ``run.py`` derives
  ``mp/traffic_saving`` = f32/bf16 at l=5 from these rows -- the
  headline HBM-traffic reduction (2x by itemsize on every window path).
* ``mp/iter_l{l}_{backend}`` -- us/iter of a fixed-budget sweep at f32
  vs bf16 storage per kernel backend.  CPU interpret-mode wall time is
  NOT probative of TPU HBM throughput (bf16 is emulated in software
  here); the traffic rows are the probative ones, these only pin the
  graphs down end to end.
* ``mp/gap_{bf16,f32,f64}[_rr]`` -- the attainable-accuracy ladder at
  depth l=5: ``residual_gap()`` (arXiv:1804.02962) per storage dtype,
  with and without ``residual_replacement=`` (arXiv:1706.05988) --  the
  committed numbers for the storage-precision/stability trade-off.
  bf16 storage stalls at ~eps_bf16-scaled floors; replacement claws part
  of the drift back but cannot beat the storage rounding of the window
  recurrences themselves.
"""
from __future__ import annotations

import warnings

import numpy as np

from benchmarks._util import timeit_us as _timeit

#: pipeline depths of the traffic/timing sweeps (the paper's deep range)
DEPTHS = (1, 3, 5)


def _window_bytes_per_iter(n: int, l: int, sdt) -> int:
    """Bytes one fused iteration moves on the window-dominated path,
    summed from real buffers: read Vw+Zw+SPMV stream, write both shifted
    windows back (the megakernel's read-modify-write of the whole
    lane-major state)."""
    import jax.numpy as jnp
    Vw = jnp.zeros((n, 2 * l + 1), sdt)
    Zw = jnp.zeros((n, l + 1), sdt)
    t = jnp.zeros((n,), sdt)
    return 2 * (Vw.nbytes + Zw.nbytes) + t.nbytes


def mp_traffic():
    """Measured bytes/iter of the window path per storage dtype and l.

    The value column is bytes (not us): summed ``nbytes`` of the actual
    jax buffers the fused body streams per iteration, so the itemsize
    comes from the real storage dtype, not an assumed constant."""
    import jax.numpy as jnp
    n = 1 << 16
    rows = []
    for l in DEPTHS:
        per = {}
        for tag, sdt in (("f32", jnp.float32), ("bf16", jnp.bfloat16)):
            per[tag] = _window_bytes_per_iter(n, l, sdt)
            rows.append((f"mp/traffic_{tag}_l{l}", float(per[tag]),
                         f"value=bytes_per_iter;n={n};window_cols={3*l+2};"
                         f"itemsize={jnp.dtype(sdt).itemsize}"))
        rows[-1] = (rows[-1][0], rows[-1][1],
                    rows[-1][2] + f";saving={per['f32']/per['bf16']:.2f}x")
    return rows


def mp_iter_times():
    """us/iter at f32 vs bf16 storage per backend (CPU-indicative only;
    Pallas runs interpret=True here and bf16 is software-emulated, so the
    probative column is the traffic model, not this wall time)."""
    import jax
    import jax.numpy as jnp

    from repro.core.plcg_scan import plcg_jit
    from repro.core.shifts import chebyshev_shifts
    from repro.operators import poisson2d
    h = w = 32
    A = poisson2d(h, w)
    b = jnp.asarray(A @ np.ones(A.n), jnp.float32)
    iters = 24
    rows = []
    for l in DEPTHS:
        sig = tuple(chebyshev_shifts(0.0, 8.0, l))
        for backend in ("fused", "pallas"):
            us = {}
            for tag, pol in (("f32", None), ("bf16", "bf16")):
                fn = lambda pol=pol: plcg_jit(
                    A.matvec, b, l=l, iters=iters, sigma=sig, tol=0.0,
                    backend=backend, stencil_hw=(h, w), precision=pol)
                jax.block_until_ready(fn().x)
                us[tag] = _timeit(fn, reps=2) / iters
            rows.append((f"mp/iter_l{l}_{backend}", us["bf16"],
                         f"us_per_iter_bf16={us['bf16']:.0f};"
                         f"us_per_iter_f32={us['f32']:.0f};"
                         "cpu_interpret_indicative"))
    return rows


def mp_gap_ladder():
    """Attainable accuracy vs storage dtype at l=5, +/- residual
    replacement: the committed trade-off ladder (value column: solve wall
    time; probative fields: rel_gap / true_res per storage rung)."""
    import jax

    from repro.core import residual_gap, solve
    from repro.operators import poisson2d
    nx = ny = 32
    A = poisson2d(nx, ny)
    b = np.asarray(A @ np.ones(A.n))
    x64 = bool(jax.config.jax_enable_x64)
    base = dict(method="plcg_scan", l=5, spectrum=(0.0, 8.0), tol=1e-6,
                maxiter=300)
    rows = []
    for storage in ("bf16", "f32", "f64"):
        for rr in (None, 20):
            tag = f"mp/gap_{storage}" + ("_rr" if rr else "")
            kw = dict(base, precision=storage)
            if rr is not None:
                # shift-free re-seed: the robust f32-scalar configuration
                # (see stab_bench.stab_gap_ladder)
                kw.update(residual_replacement=rr, ritz_refresh=False)
            with warnings.catch_warnings():
                # f64 storage without jax_enable_x64 truncates to f32
                # with a per-trace UserWarning; the x64 flag in the row
                # already records the truncation
                warnings.simplefilter("ignore", UserWarning)
                r = solve(A, b, **kw)
                us = _timeit(lambda kw=kw: solve(A, b, **kw), reps=1)
            gap = residual_gap(A, b, r)
            rows.append((tag, us,
                         f"iters={r.iters};conv={r.converged};"
                         f"restarts={r.restarts};repl={r.replacements};"
                         f"rel_gap={gap['rel_gap']:.1e};"
                         f"true_res={gap['true_resnorm']:.1e};"
                         f"x64={x64}"))
    return rows


ALL = [mp_traffic, mp_iter_times, mp_gap_ladder]
SMOKE = [mp_traffic, mp_gap_ladder]
