"""Unified-front-end benchmarks: per-method dispatch cost through
``repro.core.solve`` and the batched multi-RHS vmap(scan) engine vs a loop
of single-RHS solves.  Rows follow the ``name,us_per_call,derived``
contract of ``benchmarks.run``."""
from __future__ import annotations

import numpy as np

from benchmarks._util import timeit_us as _timeit


def engine_dispatch():
    """One solve() per registered method on the small Poisson problem."""
    from repro.core import methods, solve
    from repro.operators import poisson2d
    A = poisson2d(32, 32)
    b = A @ np.ones(A.n)
    rows = []
    for m in methods():
        r = solve(A, b, method=m, l=2, tol=1e-4, maxiter=300,
                  spectrum=(0.0, 8.0))
        us = _timeit(lambda m=m: solve(A, b, method=m, l=2, tol=1e-4,
                                       maxiter=300, spectrum=(0.0, 8.0)),
                     reps=1)
        rows.append((f"engine/{m}", us,
                     f"iters={r.iters};conv={r.converged}"))
    return rows


def engine_batched():
    """Batched (8, n) multi-RHS vmap(scan) vs 8 single-RHS scan solves."""
    from repro.core import solve
    from repro.operators import poisson2d
    A = poisson2d(32, 32)
    rng = np.random.default_rng(0)
    B = np.stack([np.asarray(A @ rng.standard_normal(A.n))
                  for _ in range(8)])
    kw = dict(l=2, tol=1e-4, maxiter=200, spectrum=(0.0, 8.0))
    rows = []
    t_batch = _timeit(lambda: solve(A, B, method="plcg_scan", **kw), reps=1)
    t_loop = _timeit(
        lambda: [solve(A, B[j], method="plcg_scan", **kw)
                 for j in range(B.shape[0])], reps=1)
    r = solve(A, B, method="plcg_scan", **kw)
    conv = int(np.asarray(r.info["per_rhs_converged"]).sum())
    rows.append(("engine/batched_8rhs", t_batch,
                 f"loop_us={t_loop:.0f};speedup={t_loop / t_batch:.2f}x;"
                 f"converged={conv}/8"))
    return rows


def engine_backends():
    """Scan engine across the kernel-backend ladder on one problem.

    ``fused`` runs the single-launch megakernel (interpret mode on CPU, so
    its wall time here is NOT indicative of TPU -- the structural
    launch-count columns of ``kern/fused_body_*`` are the probative
    metric)."""
    from repro.core import solve
    from repro.operators import poisson2d
    A = poisson2d(32, 32)
    b = A @ np.ones(A.n)
    rows = []
    for backend, kernels in ((None, "inline"), ("ref", "K4,K5"),
                             ("fused", "K1+K4+K5,1-launch")):
        tag = backend or "inline"
        us = _timeit(lambda be=backend: solve(
            A, b, method="plcg_scan", l=2, tol=1e-4, maxiter=200,
            spectrum=(0.0, 8.0), backend=be), reps=1)
        rows.append((f"engine/scan_backend_{tag}", us, f"kernels={kernels}"))
    return rows


ALL = [engine_dispatch, engine_batched, engine_backends]
SMOKE = [engine_dispatch, engine_batched]
