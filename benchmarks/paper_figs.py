"""Benchmarks reproducing each paper table/figure (CPU-sized problems).

Every function returns a list of (name, us_per_call, derived) CSV rows,
printed by benchmarks.run.  The mapping to the paper:

  fig1_convergence     -- Fig. 1: convergence identity + breakdown behavior
                          with optimal vs sub-optimal Chebyshev shifts
  table1_cost_model    -- Table 1: GLRED/SPMV counts, FLOPS(x n), MEMORY
                          (vectors) per iteration, validated structurally
  fig3_scaling_model   -- Figs. 3/4: strong-scaling speedup model
                          max(GLRED/l, SPMV) with measured SPMV time and a
                          v5e ICI latency model; derives max speedup (2l+1)x
  fig6_accuracy        -- Fig. 6 / Table 2: attainable accuracy vs l
  fig9_gaps            -- Fig. 9: basis-gap and residual-gap norms
  fig10_ginv           -- Fig. 10: ||G_j^{-1}||_max growth vs l and shifts
  table2_suite         -- Table 2: SPD suite attainable accuracy
"""
from __future__ import annotations

import numpy as np

from benchmarks._util import timeit_us
from repro.core.cg import classic_cg
from repro.core.pcg import ghysels_pcg
from repro.core.plcg import plcg
from repro.core.shifts import chebyshev_shifts
from repro.operators import poisson2d, random_spd_dense
from repro.operators.spd import TABLE2_SUITE, spd_with_spectrum


def _timeit(fn, reps=3):
    return timeit_us(fn, reps=reps)


def fig1_convergence():
    rows = []
    A = poisson2d(64, 64)
    b = A @ np.ones(A.n)
    ref = classic_cg(A, b, tol=1e-8, maxiter=800)
    rows.append(("fig1/cg", _timeit(lambda: classic_cg(A, b, tol=1e-8, maxiter=800)),
                 f"iters={ref.iters}"))
    for l, interval in [(1, (0, 8)), (2, (0, 8)), (3, (0, 8)),
                        (2, (0, 8 * 1.005)), (3, (0, 8 * 1.005))]:
        tag = "opt" if interval[1] == 8 else "subopt"
        r = plcg(A, b, l=l, tol=1e-8, maxiter=800, spectrum=interval)
        rows.append((f"fig1/p{l}cg_{tag}",
                     _timeit(lambda: plcg(A, b, l=l, tol=1e-8, maxiter=800,
                                          spectrum=interval), reps=1),
                     f"iters={r.iters};breakdowns={r.breakdowns};conv={r.converged}"))
    return rows


def table1_cost_model():
    """Structural validation of Table 1 against the scan engine's state."""
    rows = []
    for l in (1, 2, 3, 5):
        # MEMORY: Zw (l+1) + Vw (2l+1) + p = 3l+3 vectors excl. x, b
        vectors = (l + 1) + (2 * l + 1) + 1
        # FLOPS (x n): v-rec 4l+1; z-rec 5; dots 2(l+1); p-rec 3; x-upd 2
        flops = (4 * l + 1) + 5 + 2 * (l + 1) + 3 + 2
        rows.append((f"table1/p{l}cg", 0.0,
                     f"glred=1;spmv=1;flops_xn={flops}~paper {6*l+10};"
                     f"vectors={vectors}=paper 3l+3"))
    rows.append(("table1/cg", 0.0, "glred=2;spmv=1;flops_xn=10;vectors=3"))
    rows.append(("table1/pcg_ghysels", 0.0, "glred=1;spmv=1;flops_xn=16;vectors=6"))
    return rows


def fig3_scaling_model():
    """Speedup over classic CG vs node count: time/iter models from Table 1
    with a measured local SPMV and a log-tree reduction latency."""
    rows = []
    A = poisson2d(256, 256)
    x = np.ones(A.n)
    t_spmv_total = _timeit(lambda: A @ x, reps=10) / 1e6      # seconds, 65k pts
    alpha = 5e-6       # per-hop reduction latency (s) -- InfiniBand-class
    n_grid = 1000 * 1000
    for nodes in (1, 4, 16, 64, 256, 1024):
        t_spmv = t_spmv_total * (n_grid / A.n) / nodes
        t_glred = alpha * np.log2(max(nodes, 2))
        t_cg = 2 * t_glred + t_spmv
        for l in (1, 2, 3):
            t_pl = max(t_glred / l, t_spmv)
            rows.append((f"fig3/N{nodes}_l{l}", 0.0,
                         f"speedup={t_cg / t_pl:.2f};model=max(glred/l,spmv)"))
    rows.append(("fig3/max_speedup_l3", 0.0,
                 f"theoretical={(2*3+1)};paper=(2l+1)x"))
    return rows


def fig6_accuracy():
    rows = []
    A = poisson2d(100, 100)
    b = A @ (np.ones(A.n) / 100.0)
    r = classic_cg(A, b, tol=0.0, maxiter=350, trace_true_residual=True)
    rows.append(("fig6/cg", 0.0, f"floor={min(r.true_resnorms):.3e}"))
    r = ghysels_pcg(A, b, tol=0.0, maxiter=350, trace_true_residual=True)
    rows.append(("fig6/pcg_ghysels", 0.0, f"floor={min(r.true_resnorms):.3e}"))
    for l in (1, 2, 3):
        r = plcg(A, b, l=l, tol=0.0, maxiter=350, spectrum=(0, 8),
                 trace_gaps=True, max_restarts=0)
        tr = r.true_resnorms or [np.inf]
        rows.append((f"fig6/p{l}cg", 0.0,
                     f"floor={min(tr):.3e};breakdowns={r.breakdowns}"))
    return rows


def fig9_gaps():
    rows = []
    A = poisson2d(60, 60)
    b = A @ (np.ones(A.n) / 60.0)
    for l in (1, 2, 3):
        r = plcg(A, b, l=l, tol=0.0, maxiter=250, spectrum=(0, 8),
                 trace_gaps=True, max_restarts=0)
        tr = r.info["traces"][0]
        bg = tr.basis_gap_norms or [np.nan]
        rg = tr.residual_gap_norms or [np.nan]
        rows.append((f"fig9/p{l}cg", 0.0,
                     f"basis_gap_final={bg[-1]:.3e};"
                     f"residual_gap_final={rg[-1]:.3e}"))
    return rows


def fig10_ginv():
    rows = []
    A = poisson2d(40, 40)
    b = A @ (np.ones(A.n) / 40.0)
    for l, interval in [(1, (0, 8)), (2, (0, 8)), (3, (0, 8)),
                        (2, (0, 8 * 1.005))]:
        tag = "opt" if interval[1] == 8 else "subopt"
        r = plcg(A, b, l=l, tol=0.0, maxiter=120, spectrum=interval,
                 record_G=True, max_restarts=0)
        G = r.info["traces"][0].G
        k = min(100, r.iters)
        norms = []
        for j in (20, 50, k):
            # det() underflows to exactly 0 long before G[:j,:j] is
            # numerically singular (it left these rows empty in committed
            # BENCH JSONs); the pseudoinverse is defined either way and
            # equals inv() on the invertible leading blocks
            norms.append(np.max(np.abs(np.linalg.pinv(G[:j, :j]))))
        rows.append((f"fig10/p{l}cg_{tag}", 0.0,
                     "Ginv_max@[20,50,end]=" +
                     ",".join(f"{v:.2e}" for v in norms)))
    return rows


def table2_suite():
    rows = []
    from repro.core.linop import dense_operator
    for name, n, cond, kind, seed in TABLE2_SUITE:
        if kind == "uniform":
            eigs = np.linspace(1.0 / cond, 1.0, n)
        elif kind == "geometric":
            eigs = np.geomspace(1.0 / cond, 1.0, n)
        else:
            eigs = np.concatenate([[1.0 / cond], np.linspace(0.9, 1.1, n - 1)])
        A = dense_operator(spd_with_spectrum(eigs, seed=seed))
        b = A @ (np.ones(n) / np.sqrt(n))
        iters = min(6 * n, 1200)
        accs = []
        r = classic_cg(A, b, tol=0.0, maxiter=iters, trace_true_residual=True)
        accs.append(("cg", min(r.true_resnorms)))
        r = ghysels_pcg(A, b, tol=0.0, maxiter=iters, trace_true_residual=True)
        accs.append(("pcg", min(r.true_resnorms)))
        for l in (1, 2, 3):
            r = plcg(A, b, l=l, tol=0.0, maxiter=iters,
                     spectrum=(float(eigs.min()) * 0.9, float(eigs.max()) * 1.1),
                     trace_gaps=True, max_restarts=0)
            tr = r.true_resnorms or [np.inf]
            accs.append((f"p{l}", min(tr)))
        rows.append((f"table2/{name}", 0.0,
                     ";".join(f"{k}={v:.2e}" for k, v in accs)))
    return rows


def shift_ablation():
    """Remark 3 / Fig. 1 right quantified: basis-shift choice vs stability.

    Chebyshev-on-exact-interval vs perturbed interval vs monomial basis:
    iterations to 1e-8, breakdown counts, and accuracy floor."""
    rows = []
    from repro.core.shifts import monomial_shifts
    A = poisson2d(64, 64)
    b = A @ np.ones(A.n)
    cases = [("cheb_exact", dict(spectrum=(0.0, 8.0))),
             ("cheb_pert", dict(spectrum=(0.0, 8.0 * 1.05))),
             ("cheb_narrow", dict(spectrum=(0.5, 7.5))),
             ("monomial", dict(sigma=monomial_shifts(3)))]
    for name, kw in cases:
        r = plcg(A, b, l=3, tol=1e-8, maxiter=600, max_restarts=4, **kw)
        rows.append((f"shifts/{name}", 0.0,
                     f"iters={r.iters};breakdowns={r.breakdowns};"
                     f"conv={r.converged}"))
    return rows


def minres_indefinite():
    """Remark 6: pipelined MINRES handles symmetric *indefinite* systems
    that break (D-Lanczos-based) p(l)-CG."""
    rows = []
    from repro.core.linop import dense_operator
    from repro.core.plminres import plminres
    rng = np.random.default_rng(0)
    n = 120
    Q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    eigs = np.concatenate([-np.linspace(0.5, 1.0, n // 4),
                           np.linspace(0.2, 1.0, n - n // 4)])
    A = dense_operator((Q * eigs) @ Q.T)
    b = A @ np.ones(n)
    for l in (1, 2):
        r = plminres(A, b, l=l, m=n, spectrum=(float(eigs.min()),
                                               float(eigs.max())))
        res = np.linalg.norm(b - A @ r.x)
        rows.append((f"minres/p{l}", 0.0, f"final_res={res:.2e}"))
    return rows


ALL = [fig1_convergence, table1_cost_model, fig3_scaling_model,
       fig6_accuracy, fig9_gaps, fig10_ginv, table2_suite,
       shift_ablation, minres_indefinite]
