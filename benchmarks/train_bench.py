"""Newton-CG training benchmarks: the deep-pipelined HVP inner loop as
the optimizer of an end-to-end training step.

Same subprocess pattern as ``dist_bench``: the payload runs on a FORCED
4-device host platform so the (2, 2) mesh trainer's collectives are a
real schedule, and the structural rows are counted in the traced sweep
(wall-clock on a forced CPU mesh is NOT a perf claim -- the structural
columns are the probative metric, exactly like ``dist/``).  Rows:

* ``train/newton_step_us_4dev`` -- mean end-to-end outer-step time of
  the prepared mesh ``NewtonPCGTrainer`` on the reduced LM config
  (post-warmup, so zero-retrace serving is what is measured; the
  derived column carries the compile count per sweep, which must be 1);
* ``train/inner_solve_us_4dev`` -- the inner ``(GGN+lambda I)d=-g``
  solve alone; derived carries ``psums_per_iter`` counted in the traced
  sweep body -- the paper's ONE stacked reduction per p(l)-CG
  iteration, now with HVPs as the overlapped SPMV;
* ``train/hvp_vs_glred_us_4dev`` -- the autotuner's measured HVP
  latency (value) against its per-mode reduction latencies (derived),
  i.e. the actual inputs ``l="auto"`` solved ``max(glred/l, hvp)``
  over, plus the chosen ``(l, comm)``.
"""
from __future__ import annotations

from benchmarks.dist_bench import _rows_forced

_TRAIN_PAYLOAD = r"""
import json, time
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from repro.configs import get_reduced
from repro.kernels.introspect import count_primitive_in_scan_bodies
from repro.models import init_params, loss_fn
from repro.training import NewtonPCGConfig, NewtonPCGTrainer
from repro.training.data import synth_batch

mesh = Mesh(np.array(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
cfg = get_reduced("qwen3-14b")
lf = lambda p, b: loss_fn(cfg, p, b)
rows = []

ncfg = NewtonPCGConfig(l=2, cg_iters=8, lr=0.5)
tr = NewtonPCGTrainer(lf, ncfg, mesh=mesh)
params = init_params(cfg, jax.random.PRNGKey(0))
params, stats = tr.step(params, synth_batch(cfg, 0, 2, 64, seed=0))

steps = 2
t0 = time.perf_counter()
for i in range(1, 1 + steps):
    params, stats = tr.step(params, synth_batch(cfg, i, 2, 64, seed=0))
step_us = (time.perf_counter() - t0) / steps * 1e6
compiles = max(tr.compile_counts().values())
rows.append(["train/newton_step_us_4dev", step_us,
             f"l={ncfg.l};cg_iters={ncfg.cg_iters};"
             f"inner_iters={stats['cg_iters']};"
             f"loss={float(stats['loss']):.3f};compiles_per_sweep={compiles};"
             f"zero_retrace={compiles == 1}"])

op = tr.op
from jax.flatten_util import ravel_pytree
p_flat = tr._replicate(ravel_pytree(params)[0])
batch = synth_batch(cfg, 9, 2, 64, seed=0)
loss, g = tr._val_grad(p_flat, batch)
op.bind(p_flat, batch)
bb = tr._replicate(op.pad(-g))
jax.block_until_ready(tr.solver.solve(bb).x)
t0 = time.perf_counter()
r = tr.solver.solve(bb)
jax.block_until_ready(r.x)
solve_us = (time.perf_counter() - t0) * 1e6
raw = next(iter(tr.solver._mesh_session._sweeps.values()))
b0 = jnp.zeros((op.n_pad,), jnp.float32)
# the HVP itself scans over the LM's layers, so the traced program nests
# scan bodies -- the gate is the TOTAL bare-psum count across them
psums = sum(count_primitive_in_scan_bodies(
    raw, "psum", op.context, b0, jnp.zeros_like(b0), ncfg.cg_iters))
rows.append(["train/inner_solve_us_4dev", solve_us,
             f"psums_per_iter={psums};gate=1;n={op.n};"
             f"inner_iters={int(r.iters)};"
             f"hvps_hidden_per_reduction={ncfg.l}"])

acfg = NewtonPCGConfig(l="auto", cg_iters=8, lr=0.5)
tra = NewtonPCGTrainer(lf, acfg, mesh=mesh, comm="auto")
p2 = init_params(cfg, jax.random.PRNGKey(1))
p2, astats = tra.step(p2, synth_batch(cfg, 0, 2, 64, seed=1))
info = astats["auto"]
lat = info["latencies"]
glred = ";".join(f"glred_{m}_us={v:.0f}"
                 for m, v in sorted(lat["glred_us"].items()))
rows.append(["train/hvp_vs_glred_us_4dev", lat["spmv_us"],
             f"hvp_us={lat['spmv_us']:.0f};{glred};chosen_l={info['l']};"
             f"comm={info['comm']};source={info['source']}"])
print(json.dumps(rows))
"""


def train_rows():
    """train/ row family: end-to-end Newton step time, the inner solve's
    collective signature, and the measured HVP-vs-reduction latencies,
    all on a forced 4-device (2, 2) mesh."""
    return _rows_forced(_TRAIN_PAYLOAD, 4)


ALL = [train_rows]
SMOKE = [train_rows]
