"""Autotuner benchmarks: the calibrated ``l="auto"`` pick vs a fixed-l
ladder, plus the one-time calibration overhead.

Same subprocess pattern as ``dist_bench``: the payload runs on a FORCED
8-device host platform so the (2, 4) mesh -- and therefore the per-mode
reduction measurements the autotuner takes -- are real schedule
differences, not a single-device no-op.  Rows:

* ``auto/fixed_l{1,2,3,5}_8dev`` -- timed prepared-solver solves at each
  pinned depth, identical tol/maxiter/mesh, the ladder the auto pick is
  judged against;
* ``auto/chosen_8dev`` -- the calibrated session's solve; the derived
  column reports the chosen ``(l, comm)`` and ``within_best`` = best
  fixed-l wall-clock / chosen wall-clock (1.00 means auto matched the
  best pinned depth; the acceptance target is >= 0.90, REPORTED here,
  never asserted -- CPU wall-clock is not a perf gate, see ci.yml);
* ``auto/calibration_us`` -- construction time of the ``Solver(l="auto",
  comm="auto")`` session, i.e. what one-time calibration costs; the
  derived column carries the measured SPMV / per-mode reduction
  latencies the decision was solved from.
"""
from __future__ import annotations

from benchmarks.dist_bench import _rows_forced

_AUTO_PAYLOAD = r"""
import json, time
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np
from repro.core import Solver
from repro.launch.mesh import make_mesh_compat
from repro.operators import poisson2d

mesh = make_mesh_compat((2, 4), ("data", "model"))
nx = ny = 64
A = poisson2d(nx, ny)
b = jnp.asarray(np.asarray(A @ np.ones(A.n)).reshape(nx, ny))
tol, maxiter = 1e-6, 400
rows = []

def timeit(fn, *a, reps=2):
    jax.block_until_ready(fn(*a).x)        # warmup absorbs compile
    t0 = time.perf_counter()
    out = None
    for _ in range(reps):
        out = fn(*a)
    jax.block_until_ready(out.x)
    return (time.perf_counter() - t0) / reps * 1e6

best_us, best_l = None, None
for l in (1, 2, 3, 5):
    s = Solver(A, method="plcg_scan", mesh=mesh, l=l, tol=tol,
               maxiter=maxiter)
    us = timeit(s.solve, b)
    r = s.solve(b)
    if best_us is None or us < best_us:
        best_us, best_l = us, l
    rows.append([f"auto/fixed_l{l}_8dev", us,
                 f"l={l};iters={r.iters};conv={r.converged};tol={tol}"])

t0 = time.perf_counter()
s = Solver(A, method="plcg_scan", mesh=mesh, l="auto", comm="auto",
           tol=tol, maxiter=maxiter)
calib_us = (time.perf_counter() - t0) * 1e6
us = timeit(s.solve, b)
r = s.solve(b)
info = r.info["auto"]
rows.append(["auto/chosen_8dev", us,
             f"l={info['l']};comm={info['comm']};budget={info['budget']};"
             f"within_best={best_us / us:.2f};best_fixed_l={best_l};"
             f"iters={r.iters};conv={r.converged}"])
lat = info["latencies"]
glred = ";".join(f"glred_{m}_us={v:.0f}"
                 for m, v in sorted(lat["glred_us"].items()))
rows.append(["auto/calibration_us", calib_us,
             f"spmv_us={lat['spmv_us']:.0f};{glred};"
             f"source={info['source']};one_time_per_session"])
print(json.dumps(rows))
"""


def auto_rows():
    """auto/ row family: fixed-l ladder, the calibrated pick and the
    calibration overhead, all on a forced 8-device (2, 4) mesh."""
    return _rows_forced(_AUTO_PAYLOAD, 8)


ALL = [auto_rows]
SMOKE = [auto_rows]
