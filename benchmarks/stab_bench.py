"""Stability-at-depth benchmarks: what the in-scan autopilot costs and
what it buys.

Three questions, one row group each:

* ``stab/gap_l*`` -- attainable accuracy vs pipeline depth: the
  residual-gap diagnostic (arXiv:1804.02962) with and without periodic
  true-residual replacement.  The probative column is ``rel_gap``
  (recurrence residual vs true ``b - Ax`` decoupling, paper Sec. 4);
  replacement should pull the deep-``l`` gap back to the ``l=1`` level.
* ``stab/armed_overhead`` -- what arming ``restart=`` costs when no
  breakdown ever fires: the stability payload widens the per-iteration
  reduction by one slot and un-fuses the stencil megakernel, so this is
  the price of always-on recovery (and why ``restart="auto"`` stays off
  on the default fast path).  The un-fused split is STRUCTURAL, not an
  optimization gap: the fused megakernel's contract is "SPMV of window
  slot 0, consumed in-kernel", but an armed sweep must (a) switch the
  SPMV input per lane to the current iterate ``x`` on re-seeding
  iterations (``spmv_in = where(reseed_now, x, Zw[:, 0])`` -- a
  non-window vector the kernel never sees) and (b) get the raw SPMV
  result ``t_hat`` back OUT of the iteration body, because the re-seed
  residual ``rhat = b - t_hat`` and the replacement residual are
  assembled host-side of the kernel in compute precision.  Keeping the
  stencil in-kernel would mean widening the megakernel signature with an
  extra ``(n,)`` input, a per-lane select and a second output stream --
  at which point the "fused" kernel IS the 2-launch split it was
  avoiding.  So stab mode always takes Pallas-stencil-SPMV + megakernel
  (2 launches) even when ``prec is None``; see the dispatch comment in
  ``plcg_scan.py`` (``fuse_stencil = ... and not stab``).
* ``stab/frozen_lanes`` -- budget utilisation of a batched solve where
  some lanes hit square-root breakdown: without recovery the broken
  lanes freeze and their remaining update budget is dead weight; with
  in-scan restarts the same compiled sweep spends it on re-seeded
  iterations and converges.

``us_per_call`` is CPU wall time and only indicative.
"""
from __future__ import annotations

import numpy as np

from benchmarks._util import timeit_us as _timeit


def stab_gap_ladder():
    """rel_gap and true-residual floor vs l, with/without residual
    replacement (period 20), at the float32 attainable-accuracy floor
    (tol below it, fixed budget).

    The rr rows run ``ritz_refresh=False``: benchmarks execute in the
    default float32, where the committed tridiagonal scalars are too
    noisy for the eigvalsh shift refresh -- the shift-free re-seed is
    the robust float32 configuration (float64 prefers the default
    refresh, see tests/test_stability.py)."""
    from repro.core import residual_gap, solve
    from repro.operators import poisson2d
    nx = ny = 32
    A = poisson2d(nx, ny)
    b = np.asarray(A @ np.ones(A.n))
    base = dict(method="plcg_scan", spectrum=(0.0, 8.0), tol=1e-6,
                maxiter=300)
    rows = []
    for l in (1, 3, 6):
        for rr in (None, 20):
            if l == 1 and rr is not None:
                continue            # nothing to re-sync at depth 1
            tag = f"stab/gap_l{l}" + ("_rr" if rr else "")
            kw = dict(base, l=l)
            if rr is not None:
                kw.update(residual_replacement=rr, ritz_refresh=False)
            r = solve(A, b, **kw)
            us = _timeit(lambda kw=kw: solve(A, b, **kw), reps=1)
            gap = residual_gap(A, b, r)
            rows.append((tag, us,
                         f"iters={r.iters};conv={r.converged};"
                         f"restarts={r.restarts};repl={r.replacements};"
                         f"rel_gap={gap['rel_gap']:.1e};"
                         f"true_res={gap['true_resnorm']:.1e}"))
    return rows


def stab_armed_overhead():
    """us/iter with restart= armed but never fired vs the default fast
    path (same problem, same tol): the steady-state cost of carrying the
    recovery micro-state machine and the one-slot-wider reduction."""
    from repro.core import solve
    from repro.operators import poisson2d
    A = poisson2d(32, 32)
    b = np.asarray(A @ np.ones(A.n))
    kw = dict(method="plcg_scan", l=3, spectrum=(0.0, 8.0), tol=1e-4,
              maxiter=400)
    r_off = solve(A, b, **kw)
    us_off = _timeit(lambda: solve(A, b, **kw), reps=3)
    r_on = solve(A, b, restart=4, **kw)
    us_on = _timeit(lambda: solve(A, b, restart=4, **kw), reps=3)
    per_off = us_off / max(r_off.iters, 1)
    per_on = us_on / max(r_on.iters, 1)
    return [("stab/armed_overhead", us_on,
             f"us_per_iter_armed={per_on:.0f};us_per_iter_off={per_off:.0f};"
             f"overhead_x={per_on / per_off:.2f};"
             f"restarts_fired={r_on.restarts}")]


def stab_frozen_lanes():
    """Batched budget utilisation: 4 lanes under breakdown-forcing
    monomial shifts, without vs with in-scan recovery.  Reports the
    converged-lane fraction and the committed-update fraction of the
    budget (frozen lanes strand the remainder)."""
    import jax.numpy as jnp

    from repro.core import solve
    from repro.core.shifts import monomial_shifts
    from repro.operators import poisson2d
    A = poisson2d(16, 16)
    rng = np.random.default_rng(0)
    B = jnp.stack([jnp.asarray(A @ rng.standard_normal(A.n))
                   for _ in range(4)])
    maxiter = 300
    kw = dict(method="plcg_scan", l=3, sigma=monomial_shifts(3), tol=2e-4,
              maxiter=maxiter)
    rows = []
    for tag, stab_kw in (("stab/frozen_lanes_before", {}),
                         ("stab/frozen_lanes_after", {"restart": 4})):
        r = solve(A, B, **kw, **stab_kw)
        us = _timeit(lambda skw=stab_kw: solve(A, B, **kw, **skw), reps=1)
        conv = np.asarray(r.info["per_rhs_converged"])
        iters = np.asarray(r.info["per_rhs_iters"], dtype=float)
        # a frozen (broken, unconverged) lane strands maxiter - k updates
        stranded = float(np.where(conv, 0.0, maxiter - iters).sum())
        rows.append((tag, us,
                     f"conv_lanes={int(conv.sum())}/4;"
                     f"restarts={r.restarts};"
                     f"stranded_budget_pct="
                     f"{100.0 * stranded / (4 * maxiter):.0f}"))
    return rows


ALL = [stab_gap_ladder, stab_armed_overhead, stab_frozen_lanes]
SMOKE = [stab_armed_overhead, stab_frozen_lanes]
