"""Preconditioning benchmarks: the ladder none -> Jacobi -> BlockJacobi
(-> Chebyshev) through the unified front-end, plus the structural
launch-count gate of the diag-fused megakernel.

The probative columns are ``iters`` (iterations to tolerance -- the
quantity preconditioning buys, paper Sec. 6) and the residual-gap
diagnostic (attainable accuracy, arXiv:1804.02962); ``us_per_call`` is
CPU wall time and only indicative.  ``prec/fused_jacobi`` additionally
records the per-iteration Pallas launch count: a diagonal preconditioner
must NOT break the fused backend's single launch.
"""
from __future__ import annotations

import numpy as np

from benchmarks._util import timeit_us as _timeit


def prec_ladder():
    """iterations-to-tol + us/iter for none vs Jacobi vs BlockJacobi vs
    Chebyshev on the tier-1 Poisson problem (single process; BlockJacobi
    runs its (2, 2) block grid exactly as the mesh path would)."""
    from repro.core import BlockJacobi, Chebyshev, residual_gap, solve
    from repro.operators import jacobi, poisson2d
    nx = ny = 32
    A = poisson2d(nx, ny)
    b = np.asarray(A @ np.ones(A.n))
    precs = [
        ("none", None),
        ("jacobi", jacobi(A)),
        ("blockjacobi_2x2", BlockJacobi((nx, ny), blocks=(2, 2), degree=4)),
        ("chebyshev_d3", Chebyshev(A, spectrum=(0.5, 8.0), degree=3)),
    ]
    rows = []
    for tag, M in precs:
        kw = dict(method="plcg_scan", l=2, tol=1e-6, maxiter=400, M=M)
        if M is None:
            kw["spectrum"] = (0.0, 8.0)
        r = solve(A, b, **kw)
        us = _timeit(lambda kw=kw: solve(A, b, **kw), reps=1)
        gap = residual_gap(A, b, r)
        rows.append((f"prec/{tag}", us,
                     f"iters={r.iters};conv={r.converged};"
                     f"us_per_iter={us / max(r.iters, 1):.0f};"
                     f"rel_gap={gap['rel_gap']:.1e}"))
    return rows


def prec_fused_launches():
    """Structural: backend='fused' with a Jacobi (diag) preconditioner
    stays at ONE pallas_call per steady-state body; a general (opaque)
    callable with a stencil hint takes the 2-launch split."""
    from repro.core.plcg_scan import plcg_scan
    from repro.core.shifts import chebyshev_shifts
    from repro.kernels.introspect import count_pallas_calls
    from repro.operators import jacobi, poisson2d
    import jax.numpy as jnp
    A = poisson2d(32, 32)
    b = jnp.asarray(np.asarray(A @ np.ones(A.n)))
    M = jacobi(A)
    sig = tuple(chebyshev_shifts(0, 2, 2))

    def launches(prec_diag, prec):
        return count_pallas_calls(
            lambda bb: plcg_scan(A.matvec, bb, l=2, iters=8, sigma=sig,
                                 prec=prec, prec_diag=prec_diag,
                                 backend="fused",
                                 stencil_hw=A.stencil2d), b)

    n_diag = launches(M.inv_diag, M)
    n_gen = launches(None, lambda v: v / 4.0)
    us = _timeit(lambda: plcg_scan(A.matvec, b, l=2, iters=40, sigma=sig,
                                   prec=M, prec_diag=M.inv_diag,
                                   backend="fused",
                                   stencil_hw=A.stencil2d), reps=1)
    return [("prec/fused_jacobi", us,
             f"launches_diag={n_diag};launches_general={n_gen}")]


ALL = [prec_ladder, prec_fused_launches]
SMOKE = [prec_ladder, prec_fused_launches]
