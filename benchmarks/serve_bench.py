"""Serving-layer benchmarks: the one-shot -> prepared -> pooled ladder.

The probative columns are structural, not wall-clock (CPU timings are
noisy and not probative of TPU dispatch): the per-call front-end setup
a one-shot ``solve()`` repays on every call (``serve/setup`` times the
whole validate/normalize/default/build pipeline in isolation), the
retrace count of a prepared session across repeated same-shape calls
(MUST be zero after the first call --
``kernels.introspect.jit_cache_size``), and the flush occupancy /
batched-sweep call count of ``SolverPool`` micro-batching
(``engine.BATCH_TRACE_EVENTS``).  ``serve/overhead_ratio`` (one-shot us
per call / prepared us per call, computed by ``run.py``) is the serving
win ``BENCH_<rev>.json`` tracks across PRs.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks._util import timeit_us as _timeit


def _problem(nx=16):
    from repro.operators import poisson2d
    A = poisson2d(nx, nx)
    b = np.asarray(A @ np.ones(A.n))
    return A, b


#: Small + f32-convergent on purpose: the serving workload is MANY SMALL
#: solves, where the per-call Python front-end is a visible fraction.
KW = dict(l=2, tol=1e-4, maxiter=100, spectrum=(0.0, 8.0))
REPS = 30


def serve_overhead():
    """Per-call cost of N identical small solves, one-shot solve() vs a
    prepared Solver(A)(b), plus the isolated session-setup cost and the
    prepared session's retrace count (zero after the first call is the
    acceptance gate)."""
    import jax

    from repro.core import Solver, solve

    A, b = _problem()

    def oneshot():
        return solve(A, b, method="plcg_scan", **KW).x

    solver = Solver(A, "plcg_scan", **KW)

    def prepared():
        return solver(b).x

    us_setup = _timeit(lambda: Solver(A, "plcg_scan", **KW), reps=REPS)
    jax.block_until_ready(oneshot())
    us_oneshot = _timeit(oneshot, reps=REPS)
    jax.block_until_ready(prepared())
    us_prepared = _timeit(prepared, reps=REPS)
    # retraces across the timed calls: every prepared sweep that ran must
    # sit at exactly ONE compilation
    sizes = [c for c in solver.compile_counts().values() if c > 0]
    ratio = us_oneshot / max(us_prepared, 1e-9)
    return [
        ("serve/setup", us_setup,
         "validate+normalize+default+build, amortized to 0 by a session"),
        ("serve/oneshot", us_oneshot, f"reps={REPS}"),
        ("serve/prepared", us_prepared,
         f"ratio_vs_oneshot={ratio:.2f};"
         f"compiles={max(sizes) if sizes else 0};zero_retraces="
         f"{all(c == 1 for c in sizes)}"),
    ]


def serve_pool():
    """Micro-batched dispatch: 8 queued RHS through SolverPool = ONE
    batched sweep call (counted via BATCH_TRACE_EVENTS), vs 8 sequential
    prepared calls; occupancy + per-lane parity of a ragged (5-deep)
    padded flush."""
    from repro.core import Solver, SolverPool, clear_batch_trace, solve
    from repro.core import engine

    A, b = _problem()
    rng = np.random.default_rng(0)
    B = np.stack([np.asarray(A @ rng.standard_normal(A.n))
                  for _ in range(8)])
    solver = Solver(A, "plcg_scan", **KW)
    pool = SolverPool(solver, max_batch=8)

    def pooled():
        hs = [pool.submit(B[j]) for j in range(8)]
        pool.flush()
        return [h.result().x for h in hs]

    # warmup + sweep-call count in one pass
    clear_batch_trace()
    pooled()
    sweep_calls_first = len(engine.BATCH_TRACE_EVENTS)
    pooled()
    retraces_after = len(engine.BATCH_TRACE_EVENTS) - sweep_calls_first
    t0 = time.perf_counter()
    for _ in range(5):
        out = pooled()
    us_pool = (time.perf_counter() - t0) / 5 * 1e6
    del out
    us_seq = _timeit(lambda: [solver(B[j]).x for j in range(8)], reps=3)
    # ragged flush: 5 requests pad to the 8-bucket
    hs = [pool.submit(B[j]) for j in range(5)]
    (real, padded), = pool.flush()
    del hs
    # per-lane parity vs the one-shot front-end (structural sanity)
    h = pool.submit(B[0])
    pool.flush()
    r0 = solve(A, B[0], method="plcg_scan", **KW)
    rel = (np.linalg.norm(np.asarray(h.result().x) - np.asarray(r0.x))
           / np.linalg.norm(np.asarray(r0.x)))
    return [
        ("serve/pool_flush8", us_pool / 8,
         f"us_per_rhs;sweep_calls_first_flush={sweep_calls_first};"
         f"retraces_after={retraces_after};"
         f"speedup_vs_sequential={us_seq / max(us_pool, 1e-9):.2f}"),
        ("serve/pool_ragged5", 0.0,
         f"real={real};padded={padded};occupancy={real / padded:.3f};"
         f"lane_rel_err={rel:.1e}"),
    ]


ALL = [serve_overhead, serve_pool]
SMOKE = [serve_overhead, serve_pool]
