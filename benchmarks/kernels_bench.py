"""Kernel micro-benchmarks: wall time of the oracle math (the CPU stand-in
for the TPU kernels) + derived HBM-traffic model for the fused kernels +
the structural launch-count comparison of the fused-iteration megakernel
vs the per-kernel Pallas tier (jaxpr equation counts -- CPU wall time is
not probative of TPU launch overhead)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks._util import timeit_us
from repro.kernels import ref


def _timeit(fn, reps=10):
    return timeit_us(fn, reps=reps)


def kernel_times():
    rows = []
    key = jax.random.PRNGKey(0)
    H = W = 512
    x = jax.random.normal(key, (H, W), jnp.float32)
    hn = jnp.zeros(W)
    hw = jnp.zeros(H)
    st = jax.jit(lambda: ref.stencil2d_ref(x, hn, hn, hw, hw))
    rows.append(("kern/stencil2d_512", _timeit(st),
                 f"bytes={(H*W*2+2*W+2*H)*4};flops={5*H*W}"))
    for l in (1, 3, 5):
        m, n = 2 * l + 1, 1 << 18
        Wm = jax.random.normal(key, (n, m), jnp.float32)   # lane-major
        z = jax.random.normal(key, (n,), jnp.float32)
        md = jax.jit(lambda Wm=Wm, z=z: ref.multidot_ref(Wm, z))
        naive_bytes = 2 * m * n * 4
        fused_bytes = (m + 1) * n * 4
        rows.append((f"kern/multidot_l{l}", _timeit(md),
                     f"fused_traffic={fused_bytes};naive={naive_bytes};"
                     f"saving={naive_bytes/fused_bytes:.2f}x"))
        g = jax.random.normal(jax.random.PRNGKey(3), (m,), jnp.float32)
        wa = jax.jit(lambda Wm=Wm, z=z, g=g: ref.window_axpy_ref(Wm, z, g, 1.1))
        rows.append((f"kern/window_axpy_l{l}", _timeit(wa),
                     f"fused_traffic={(m+2)*n*4};"
                     f"naive={(2*m+1)*n*4}"))
    return rows


def fused_body_times():
    """The fused-iteration megakernel: oracle wall time + HBM-traffic
    model + per-iteration Pallas launch counts of the ``fused`` vs the
    ``pallas`` backend tier of the scan engine (counted in the traced
    jaxpr via ``repro.kernels.introspect``)."""
    from repro.core.plcg_scan import plcg_scan
    from repro.core.shifts import chebyshev_shifts
    from repro.kernels.introspect import count_pallas_calls
    key = jax.random.PRNGKey(0)
    rows = []
    for l in (1, 2):
        m, n = 2 * l + 1, 1 << 16
        Vw = jax.random.normal(key, (n, m), jnp.float32)
        Zw = jax.random.normal(jax.random.PRNGKey(1), (n, l + 1), jnp.float32)
        t = jax.random.normal(jax.random.PRNGKey(2), (n,), jnp.float32)
        g = jax.random.normal(jax.random.PRNGKey(3), (2 * l,), jnp.float32)
        one = jnp.float32(1.0)
        fb = jax.jit(lambda Vw=Vw, Zw=Zw, t=t, g=g: ref.fused_body_ref(
            Vw, Zw, None, t, None, l=l, steady=jnp.bool_(True), s_warm=one,
            gam=one, dlt=one, dsub=one, gcc=one, g=g))
        # one fused launch reads Vw+Zw+t once and writes Vw2+Zw2:
        fused_words = (6 * l + 7) * n
        # pallas tier: waxpy (2l+2) + 2 multidots (l+2 + l+1) + z-AXPY
        # stream (4) + SPMV touch (2), each its own launch + round-trip:
        tier_words = (10 * l + 9) * n
        h = w = 1 << 5
        nn = h * w
        from repro.operators import poisson2d
        A = poisson2d(h, w)
        b = jnp.asarray(A @ jnp.ones(nn, jnp.float32))
        sig = tuple(chebyshev_shifts(0, 8, l))
        launches = {
            be: count_pallas_calls(
                lambda bb, be=be: plcg_scan(
                    A.matvec, bb, l=l, iters=4, sigma=sig, backend=be,
                    stencil_hw=(h, w)), b)
            for be in ("pallas", "fused")
        }
        rows.append((
            f"kern/fused_body_l{l}", _timeit(fb),
            f"fused_traffic={fused_words*4};pallas_tier={tier_words*4};"
            f"saving={tier_words/fused_words:.2f}x;"
            f"launches_fused={launches['fused']};"
            f"launches_pallas={launches['pallas']}"))
    return rows


ALL = [kernel_times, fused_body_times]
