"""Kernel micro-benchmarks: wall time of the oracle math (the CPU stand-in
for the TPU kernels) + derived HBM-traffic model for the fused kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks._util import timeit_us
from repro.kernels import ref


def _timeit(fn, reps=10):
    return timeit_us(fn, reps=reps)


def kernel_times():
    rows = []
    key = jax.random.PRNGKey(0)
    H = W = 512
    x = jax.random.normal(key, (H, W), jnp.float32)
    hn = jnp.zeros(W)
    hw = jnp.zeros(H)
    st = jax.jit(lambda: ref.stencil2d_ref(x, hn, hn, hw, hw))
    rows.append(("kern/stencil2d_512", _timeit(st),
                 f"bytes={(H*W*2+2*W+2*H)*4};flops={5*H*W}"))
    for l in (1, 3, 5):
        m, n = 2 * l + 1, 1 << 18
        Wm = jax.random.normal(key, (m, n), jnp.float32)
        z = jax.random.normal(key, (n,), jnp.float32)
        md = jax.jit(lambda Wm=Wm, z=z: ref.multidot_ref(Wm, z))
        naive_bytes = 2 * m * n * 4
        fused_bytes = (m + 1) * n * 4
        rows.append((f"kern/multidot_l{l}", _timeit(md),
                     f"fused_traffic={fused_bytes};naive={naive_bytes};"
                     f"saving={naive_bytes/fused_bytes:.2f}x"))
        g = jax.random.normal(key, (m,), jnp.float32)
        wa = jax.jit(lambda Wm=Wm, z=z, g=g: ref.window_axpy_ref(Wm, z, g, 1.1))
        rows.append((f"kern/window_axpy_l{l}", _timeit(wa),
                     f"fused_traffic={(m+2)*n*4};"
                     f"naive={(2*m+1)*n*4}"))
    return rows


ALL = [kernel_times]
