"""Benchmark harness: one function per paper table/figure + kernel timings
+ the dry-run roofline aggregation.  Prints ``name,us_per_call,derived``
CSV rows (the contract consumed by EXPERIMENTS.md)."""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import kernels_bench, paper_figs, roofline
    groups = list(paper_figs.ALL) + list(kernels_bench.ALL) + list(roofline.ALL)
    print("name,us_per_call,derived")
    failures = 0
    for fn in groups:
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001
            print(f"{getattr(fn, '__name__', 'roofline')},0,"
                  f"ERROR:{type(e).__name__}:{str(e)[:120]}")
            failures += 1
            continue
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        sys.stderr.write(f"[{getattr(fn, '__name__', 'roofline')}: "
                         f"{time.time()-t0:.1f}s]\n")
    if failures:
        sys.stderr.write(f"{failures} benchmark group(s) failed\n")


if __name__ == "__main__":
    main()
