"""Benchmark harness: one function per paper table/figure + kernel timings
+ the unified-front-end groups + the dry-run roofline aggregation.  Prints
``name,us_per_call,derived`` CSV rows (the contract consumed by
EXPERIMENTS.md).

``--smoke`` runs a fast subset (front-end dispatch, batched engine, kernel
micro-times, the structural Table-1 rows) for the CI benchmark-smoke job:
the rows must *print*, no timing is asserted.

``--json [PATH]`` additionally writes the machine-readable trajectory
file ``{name: us_per_call}`` (plus a ``derived`` map) consumed by the
perf gate: commit one ``BENCH_<rev>.json`` per landed revision so
regressions are diffable across the PR sequence.  Without an explicit
PATH the file is auto-named ``BENCH_<rev>.json`` from
``git rev-parse --short HEAD``, so the provenance can no longer drift
from the checked-out revision.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import sys
import time

# make `python benchmarks/run.py` work from anywhere (not only
# `python -m benchmarks.run` from the repo root)
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI subset; asserts nothing about timings")
    ap.add_argument("--json", metavar="PATH", nargs="?", default=None,
                    const="auto",
                    help="also write {name: us_per_call} (+derived) JSON; "
                         "without PATH, auto-names BENCH_<rev>.json from "
                         "`git rev-parse --short HEAD`")
    args = ap.parse_args(argv)
    if args.json == "auto":
        try:
            rev = subprocess.run(
                ["git", "rev-parse", "--short", "HEAD"],
                cwd=pathlib.Path(__file__).resolve().parent.parent,
                capture_output=True, text=True, check=True).stdout.strip()
        except (OSError, subprocess.CalledProcessError) as e:
            rev = "local"
            sys.stderr.write(f"[--json: git rev-parse unavailable ({e}); "
                             "falling back to BENCH_local.json]\n")
        args.json = f"BENCH_{rev}.json"

    from benchmarks import (auto_bench, dist_bench, engine_bench,
                            kernels_bench, mp_bench, paper_figs, prec_bench,
                            roofline, serve_bench, stab_bench, train_bench)
    if args.smoke:
        groups = (list(engine_bench.SMOKE) + list(kernels_bench.ALL)
                  + [paper_figs.table1_cost_model] + list(dist_bench.SMOKE)
                  + list(prec_bench.SMOKE) + list(serve_bench.SMOKE)
                  + list(stab_bench.SMOKE) + list(mp_bench.SMOKE)
                  + list(auto_bench.SMOKE) + list(train_bench.SMOKE))
    else:
        groups = (list(paper_figs.ALL) + list(kernels_bench.ALL)
                  + list(engine_bench.ALL) + list(dist_bench.ALL)
                  + list(prec_bench.ALL) + list(serve_bench.ALL)
                  + list(stab_bench.ALL) + list(mp_bench.ALL)
                  + list(auto_bench.ALL) + list(train_bench.ALL)
                  + list(roofline.ALL))
    print("name,us_per_call,derived")
    failures = 0
    all_rows: list[tuple] = []
    for fn in groups:
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001
            print(f"{getattr(fn, '__name__', 'roofline')},0,"
                  f"ERROR:{type(e).__name__}:{str(e)[:120]}")
            failures += 1
            continue
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        all_rows.extend(rows)
        sys.stderr.write(f"[{getattr(fn, '__name__', 'roofline')}: "
                         f"{time.time()-t0:.1f}s]\n")
    # the serving win tracked across PRs: per-call front-end overhead of
    # one-shot solve() over a prepared Solver (a derived row so
    # BENCH_<rev>.json diffs it like any other metric)
    us = {name: v for name, v, _ in all_rows}
    if us.get("serve/prepared"):
        ratio = us["serve/oneshot"] / us["serve/prepared"]
        row = ("serve/overhead_ratio", ratio,
               "oneshot_us_per_call/prepared_us_per_call")
        print(f"{row[0]},{row[1]:.2f},{row[2]}")
        all_rows.append(row)
    # the communication-hiding win tracked across PRs: blocking-psum sweep
    # wall-clock over the split psum_scatter/all_gather sweep on the forced
    # 8-device mesh (>1 means the in-flight reduction paid for itself)
    if us.get("dist/overlap_overlap_8dev"):
        ratio = us["dist/overlap_blocking_8dev"] / us["dist/overlap_overlap_8dev"]
        row = ("dist/overlap_hiding_ratio", ratio,
               f"ratio={ratio:.2f};blocking_us/overlap_us on forced "
               "8-device mesh")
        print(f"{row[0]},{row[1]:.2f},{row[2]}")
        all_rows.append(row)
    # the mixed-precision win tracked across PRs: HBM bytes/iter of the
    # f32 window path over the bf16 one at the deepest benchmarked l
    # (measured from real buffer nbytes in mp_bench.mp_traffic)
    if us.get("mp/traffic_bf16_l5"):
        ratio = us["mp/traffic_f32_l5"] / us["mp/traffic_bf16_l5"]
        row = ("mp/traffic_saving", ratio,
               "f32_bytes_per_iter/bf16_bytes_per_iter at l=5")
        print(f"{row[0]},{row[1]:.2f},{row[2]}")
        all_rows.append(row)
    if args.json:
        payload = {
            "us_per_call": {name: round(us, 1) for name, us, _ in all_rows},
            "derived": {name: derived for name, us, derived in all_rows},
        }
        pathlib.Path(args.json).write_text(json.dumps(payload, indent=1,
                                                      sort_keys=True))
        sys.stderr.write(f"[wrote {len(all_rows)} rows to {args.json}]\n")
    if failures:
        sys.stderr.write(f"{failures} benchmark group(s) failed\n")
        sys.exit(1)


if __name__ == "__main__":
    main()
