"""Shared benchmark helpers."""
from __future__ import annotations

import time

import jax


def timeit_us(fn, reps: int = 3) -> float:
    """Mean wall time of ``fn()`` in microseconds.

    One untimed warmup call absorbs jit compilation; the last timed
    result is blocked on so async jax dispatch is included in the
    measurement (non-jax results pass through untouched).
    """
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    out = None
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6
