"""Roofline aggregation: turn the dry-run JSONs into the EXPERIMENTS.md
SRoofline table (per arch x shape x mesh: three terms, dominant bottleneck,
MODEL_FLOPS/HLO_FLOPS ratio)."""
from __future__ import annotations

import json
import pathlib

DRYRUN = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load_cells(mesh: str = "single"):
    cells = []
    d = DRYRUN / mesh
    if not d.exists():
        return cells
    for p in sorted(d.glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("tag"):          # hillclimb variants live in SPerf
            continue
        cells.append(rec)
    return cells


def table_rows(mesh: str = "single"):
    rows = []
    for c in load_cells(mesh):
        name = f"{c['arch']}/{c['shape']}"
        if c.get("skipped"):
            rows.append((f"roofline[{mesh}]/{name}", 0.0, "SKIP(full-attn@500k)"))
            continue
        if not c.get("ok"):
            rows.append((f"roofline[{mesh}]/{name}", 0.0,
                         "FAIL:" + c.get("error", "?")[:60]))
            continue
        r = c["roofline"]
        mem = c["memory"]["peak_per_device"] / 1e9
        ratio = r.get("model_flops_ratio")
        rows.append((
            f"roofline[{mesh}]/{name}", 0.0,
            f"tc={r['t_compute_s']:.3f}s;tm={r['t_memory_s']:.3f}s;"
            f"tn={r['t_collective_s']:.3f}s;dom={r['dominant'][2:-2]};"
            f"mem={mem:.1f}GB;useful={ratio:.2f}" if ratio else "n/a"))
    return rows


def markdown_table(mesh: str = "single") -> str:
    lines = [
        f"| arch | shape | t_compute | t_memory | t_collective | dominant "
        f"| useful-flops ratio | mem/dev | fits 16GB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for c in load_cells(mesh):
        if c.get("skipped"):
            lines.append(f"| {c['arch']} | {c['shape']} | -- | -- | -- | "
                         f"n/a (skipped: full attention @524k) | -- | -- | -- |")
            continue
        if not c.get("ok"):
            lines.append(f"| {c['arch']} | {c['shape']} | FAILED | | | | | | |")
            continue
        r = c["roofline"]
        mem = c["memory"]["peak_per_device"] / 1e9
        ratio = r.get("model_flops_ratio")
        lines.append(
            f"| {c['arch']} | {c['shape']} | {r['t_compute_s']:.3f}s | "
            f"{r['t_memory_s']:.3f}s | {r['t_collective_s']:.3f}s | "
            f"{r['dominant'].replace('t_', '').replace('_s', '')} | "
            f"{ratio:.2f} | {mem:.1f}GB | "
            f"{'yes' if c['memory']['fits_16GB'] else 'NO'} |"
            if ratio is not None else
            f"| {c['arch']} | {c['shape']} | ? | | | | | | |")
    return "\n".join(lines)


ALL = [lambda: table_rows("single"), lambda: table_rows("multi")]
