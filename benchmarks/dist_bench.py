"""Distributed (mesh execution layer) benchmarks.

Runs each payload in a subprocess with a FORCED host platform device
count (``--xla_force_host_platform_device_count=N``) so the shard_map
mesh path is real even on single-device CI runners; the parent process
keeps its single device.  The device count is parameterized per row
family: the original ``dist/`` rows stay on 4 devices so they remain
comparable to the committed ``BENCH_*.json`` trajectory, while the
``dist/overlap_*`` rows force 8 devices -- enough shards that splitting
the reduction (``psum_scatter`` + delayed ``all_gather``) is a real
schedule change, not a 2x2 toy.

The probative columns are structural, not wall-clock (CPU collective
timings say nothing about ICI): ``psums_per_iter`` counted in the traced
scan body (1 for the pipelined engine's fused payload vs 2 for the
classic-CG baseline), ``ppermutes_per_iter`` (the 4 halo exchanges),
lane-scaling efficiency of the batched ``shard_map(vmap(scan))`` sweep,
and for the comm policies the full per-iteration collective signature
(blocking: one bare psum; overlap: one reduce_scatter + one all_gather,
zero psums; ring: ppermutes only).  The ``us_per_iter`` columns still
ride along so the hiding ratio is diffable across revisions.
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys

_PAYLOAD = r"""
import json, time
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
from repro.core.shifts import chebyshev_shifts
from repro.distributed import DistPoisson, cg_mesh_sweep, plcg_mesh_sweep
from repro.kernels.introspect import count_primitive_in_scan_bodies
from repro.launch.mesh import make_mesh_compat

mesh = make_mesh_compat((2, 2), ("data", "model"))
nx = ny = 32
op = DistPoisson(nx, ny, mesh)
sig = tuple(chebyshev_shifts(0.0, 8.0, 2))
iters = 50
rows = []

def timeit(fn, *a, reps=2):
    jax.block_until_ready(fn(*a))          # warmup absorbs compile
    t0 = time.perf_counter()
    out = None
    for _ in range(reps):
        out = fn(*a)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6

b = jnp.ones((nx, ny))
x0 = jnp.zeros_like(b)
fp = plcg_mesh_sweep(op, l=2, iters=iters, sigma=sig, tol=0.0)
psums = count_primitive_in_scan_bodies(fp, "psum", b, x0, iters)[0]
ppers = count_primitive_in_scan_bodies(fp, "ppermute", b, x0, iters)[0]
rows.append(["dist/plcg_sweep_2x2", timeit(fp, b, x0, iters),
             f"psums_per_iter={psums};ppermutes_per_iter={ppers};"
             f"iters={iters}"])
fc = cg_mesh_sweep(op, iters=iters, tol=0.0)
psums_c = count_primitive_in_scan_bodies(fc, "psum", b, x0)[0]
rows.append(["dist/cg_sweep_2x2", timeit(fc, b, x0),
             f"psums_per_iter={psums_c};iters={iters}"])

fb = plcg_mesh_sweep(op, l=2, iters=iters, sigma=sig, tol=0.0, batched=True)
base = None
for lanes in (1, 4, 8):
    B = jnp.ones((lanes, nx, ny)) * (1.0 + jnp.arange(lanes)[:, None, None])
    psums_b = count_primitive_in_scan_bodies(fb, "psum", B, B * 0, iters)[0]
    us = timeit(fb, B, B * 0, iters)
    if base is None:
        base = us
    rows.append([f"dist/plcg_lanes_{lanes}", us,
                 f"psums_per_iter={psums_b};us_per_lane={us / lanes:.0f};"
                 f"eff_vs_1lane={base * lanes / us:.2f}x"])
print(json.dumps(rows))
"""

_OVERLAP_PAYLOAD = r"""
import json, time
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
from repro.core.shifts import chebyshev_shifts
from repro.distributed import DistPoisson, plcg_mesh_sweep
from repro.kernels.introspect import count_collectives_in_scan_bodies
from repro.launch.mesh import make_mesh_compat

mesh = make_mesh_compat((2, 4), ("data", "model"))
nx = ny = 32
op = DistPoisson(nx, ny, mesh)
l = 5                                # deep enough for the (2,4) ring (4 hops)
sig = tuple(chebyshev_shifts(0.0, 8.0, l))
iters = 50
rows = []

def timeit(fn, *a, reps=2):
    jax.block_until_ready(fn(*a))          # warmup absorbs compile
    t0 = time.perf_counter()
    out = None
    for _ in range(reps):
        out = fn(*a)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6

b = jnp.ones((nx, ny))
x0 = jnp.zeros_like(b)
us_blocking = None
for comm in ("blocking", "overlap", "ring"):
    f = plcg_mesh_sweep(op, l=l, iters=iters, sigma=sig, tol=0.0, comm=comm)
    cc = count_collectives_in_scan_bodies(f, b, x0, iters)[0]
    us = timeit(f, b, x0, iters)
    if us_blocking is None:
        us_blocking = us
    detail = (f"psum={cc['psum']};reduce_scatter={cc['reduce_scatter']};"
              f"all_gather={cc['all_gather']};ppermute={cc['ppermute']};"
              f"us_per_iter={us / iters:.1f};"
              f"vs_blocking={us_blocking / us:.2f}x;l={l};iters={iters}")
    rows.append([f"dist/overlap_{comm}_8dev", us, detail])
print(json.dumps(rows))
"""


def _rows_forced(payload: str, ndevices: int) -> list[tuple]:
    """Run ``payload`` in a subprocess on ``ndevices`` forced host devices
    and parse its last stdout line as the row list."""
    repo = pathlib.Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndevices}"
    env["PYTHONPATH"] = str(repo / "src")
    out = subprocess.run([sys.executable, "-c", payload], env=env,
                         capture_output=True, text=True, timeout=900)
    if out.returncode != 0:
        raise RuntimeError(
            f"dist bench subprocess failed: {out.stderr[-500:]}")
    return [tuple(r) for r in json.loads(out.stdout.strip().splitlines()[-1])]


def dist_rows():
    """dist/ row family, produced on a host-count-forced 4-device mesh
    (kept at 4 so the rows stay comparable across the BENCH trajectory)."""
    return _rows_forced(_PAYLOAD, 4)


def overlap_rows():
    """dist/overlap_* rows: the comm-policy ladder (blocking | overlap |
    ring) on a forced 8-device (2,4) mesh at depth l=5, same sweep per
    row so the per-iteration wall-clock and collective signature are
    directly comparable."""
    return _rows_forced(_OVERLAP_PAYLOAD, 8)


ALL = [dist_rows, overlap_rows]
SMOKE = [dist_rows, overlap_rows]
