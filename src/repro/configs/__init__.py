"""Architecture registry: --arch <id> resolves here."""
from __future__ import annotations

import importlib

ARCHS = [
    "mamba2-370m",
    "qwen2-vl-2b",
    "qwen3-moe-235b-a22b",
    "arctic-480b",
    "mistral-large-123b",
    "chatglm3-6b",
    "qwen1.5-32b",
    "qwen3-14b",
    "recurrentgemma-9b",
    "whisper-large-v3",
]

#: the paper's own workload (Poisson solves) -- not an LM architecture
SOLVER_CONFIGS = ["poisson2d"]

_MODULE_FOR = {a: a.replace("-", "_").replace(".", "_") for a in ARCHS}


def get_config(arch_id: str):
    if arch_id not in _MODULE_FOR:
        raise KeyError(f"unknown arch {arch_id!r}; choose from {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[arch_id]}")
    return mod.CONFIG


def get_reduced(arch_id: str):
    from repro.models.config import reduced
    return reduced(get_config(arch_id))
