"""qwen2-vl-2b [vlm] -- M-RoPE, dynamic resolution, arXiv:2409.12191.

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.  Backbone only:
input_specs provides precomputed patch embeddings (frontend stub).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv=2,
    d_ff=8960,
    vocab=151936,
    head_dim=128,
    rope_style="mrope",
    mrope_sections=(16, 24, 24),
    qkv_bias=True,
    tie_embeddings=True,
    embeds_input=True,
)
