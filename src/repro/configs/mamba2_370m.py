"""mamba2-370m [ssm] -- SSD (state-space duality), arXiv:2405.21060.

48L d_model=1024 (attention-free) vocab=50280, ssm_state=128.
Sub-quadratic: runs the long_500k shape (O(1) decode state).
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=32,            # d_inner/headdim = 2048/64
    n_kv=32,
    d_ff=0,
    vocab=50280,
    rope_style="none",
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=128,
                  n_groups=1),
    tie_embeddings=True,
    subquadratic=True,
)
