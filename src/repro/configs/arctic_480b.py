"""arctic-480b [moe] -- 128 experts top-2 + dense residual
(hf:Snowflake/snowflake-arctic-base).

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv=8,
    d_ff=4864,
    vocab=32000,
    head_dim=128,
    moe=MoEConfig(num_experts=128, top_k=2, d_ff_expert=4864,
                  dense_residual=True, d_ff_dense=4864),
)
