"""whisper-large-v3 [audio] -- enc-dec, conv frontend (stub), arXiv:2212.04356.

32L (enc) + 32L (dec) d_model=1280 20H d_ff=5120 vocab=51866.  Backbone
only: input_specs provides precomputed mel-frame embeddings.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-large-v3",
    family="encdec",
    n_layers=32,
    n_enc_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv=20,
    d_ff=5120,
    vocab=51866,
    rope_style="none",
    embeds_input=True,
)
