"""recurrentgemma-9b [hybrid] -- RG-LRU + local attention 1:2,
arXiv:2402.19427 (Griffin).

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000.
Sub-quadratic: runs the long_500k shape (O(1) recurrent state + fixed
local-attention window).
"""
from repro.models.config import ModelConfig, HybridConfig

CONFIG = ModelConfig(
    arch_id="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv=1,
    d_ff=12288,
    vocab=256000,
    head_dim=256,
    rope_theta=1e4,
    hybrid=HybridConfig(window=2048, pattern=("rglru", "rglru", "attn"),
                        lru_width=4096, conv_width=4),
    subquadratic=True,
)
