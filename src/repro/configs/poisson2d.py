"""The paper's own workload: 2D Poisson systems solved with p(l)-CG.

Grid sizes follow Sec. 5: 1000x1000 (test setup 1), 1750x1750 (test setup
2), 200x200 (stability study).  The production solve distributes the grid
over the full ("data","model") device grid -- see repro.distributed.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    arch_id: str = "poisson2d"
    nx: int = 1000
    ny: int = 1000
    l: int = 3
    tol: float = 1e-5
    maxiter: int = 2000
    lmin: float = 0.0
    lmax: float = 8.0
    dtype: str = "float64"


CONFIG = SolverConfig()
