"""GGN operators: the damped Gauss-Newton Hessian as a solver operand.

Newton-CG training maps onto the paper's cost model exactly (see
``newton_pcg.py``): SPMV <-> one GGN Hessian-vector product, GLRED <->
the CG dot products over the (FSDP-sharded) flat parameter vector, and
``l`` <-> how many HVPs one global reduction is hidden behind.  This
module packages the damped GGN ``(J^T H J + lambda I)`` as operators the
prepared-solver engine (``repro.core.session``) can drive directly:

  * :class:`GGNOperator` -- single-device, a
    :class:`repro.core.linop.BindableOperator`: the HVP closure is built
    ONCE per (pytree structure, damping) and the ``(p_flat, batch)``
    context is threaded through every compiled sweep as a traced operand,
    so successive outer steps rebind fresh parameters/batches with ZERO
    retraces;
  * :class:`GGNDistOperator` -- the mesh twin, implementing the
    ``repro.distributed.operator.DistributedOperator`` protocol over the
    flat parameter vector sharded along the FSDP axis (the same
    ``embed -> data`` rule ``models/sharding.py`` applies to the weight
    matrices).  ``matvec_local_ctx`` all-gathers the parameter and
    direction shards (the FSDP param-gather analog), runs the HVP
    shard-locally, and returns this shard's chunk; the CG dots then
    reduce through the engine's ONE stacked ``psum`` per iteration
    (``reduce_scalars``), with the split-phase / ring forms backing
    ``comm="overlap"`` / ``comm="ring"``.

:func:`estimate_ggn_lmax` replaces a hardcoded spectral bound with a
cheap power-iteration estimate, following the
``BlockJacobi.precond_spectrum`` conventions (fixed seed, Rayleigh
quotient, 1.05 safety factor), so the Chebyshev shifts of the auxiliary
basis track the actual GGN spectrum.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree
from jax.sharding import Mesh, PartitionSpec as P

from ..core.linop import BindableOperator
from ..models import sharding as shd


def ggn_hvp(loss_fn: Callable, unravel: Callable, p_flat, batch, v_flat,
            damping):
    """Damped GGN product ``(J^T H J + damping) v`` on flat vectors.

    One forward-over-reverse pass (jvp of grad): compute-heavy,
    reduction-free -- precisely the operation the deep pipeline overlaps
    the global reduction with.  For softmax-CE composites the Fisher ==
    GGN, so the hvp of the scalar loss is the (PSD) Gauss-Newton matrix.
    """
    def f(q):
        return loss_fn(unravel(q), batch)

    _, hv = jax.jvp(jax.grad(f), (p_flat,), (v_flat,))
    return hv + damping * v_flat


def estimate_ggn_lmax(loss_fn: Callable, unravel: Callable, p_flat, batch,
                      *, damping: float, power_iters: int = 8) -> float:
    """Power-iteration estimate of ``lmax(GGN + damping I)``.

    Same conventions as ``BlockJacobi.precond_spectrum``: fixed
    ``default_rng(7)`` start vector, Rayleigh-quotient iteration, final
    1.05 safety factor.  Host-side (called once per prepared trainer,
    never inside a jitted step); the HVP itself is jitted so the
    ``power_iters`` products reuse one compiled program.
    """
    n = int(p_flat.shape[0])
    v = jnp.asarray(np.random.default_rng(7).standard_normal(n),
                    dtype=p_flat.dtype)
    hvp = jax.jit(functools.partial(ggn_hvp, loss_fn, unravel))
    lam = float(damping)
    for _ in range(max(int(power_iters), 0)):
        w = hvp(p_flat, batch, v, damping)
        lam = float(jnp.vdot(v, w) / jnp.vdot(v, v))
        v = w / jnp.linalg.norm(w)
    return 1.05 * lam


class GGNOperator(BindableOperator):
    """Damped GGN of ``loss_fn`` at ``(params, batch)`` as a bindable
    SPD operator over the flat parameter vector.

    The flatten/unravel pair is built ONCE here (not per HVP): the
    operator owns ``unravel`` and its context carries the already-flat
    ``p_flat``, so the inner solve's k matvecs never re-flatten the
    pytree.  ``bind(p_flat, batch)`` swaps in the next outer step's data
    without touching the compiled sweeps.
    """

    def __init__(self, loss_fn: Callable, params, batch, *,
                 damping: float = 1e-3, name: str = "ggn"):
        p_flat, unravel = ravel_pytree(params)
        self.loss_fn = loss_fn
        self.unravel = unravel
        self.damping = float(damping)
        dmp = self.damping

        def matvec_ctx(ctx, v):
            pf, bt = ctx
            return ggn_hvp(loss_fn, unravel, pf, bt, v, dmp)

        super().__init__(matvec_ctx=matvec_ctx, n=int(p_flat.shape[0]),
                         context=(p_flat, batch), name=name)

    def bind(self, p_flat, batch) -> "GGNOperator":
        """Rebind to fresh (flat params, batch); shapes must match."""
        if tuple(p_flat.shape) != (self.n,):
            raise ValueError(
                f"flat parameter shape {tuple(p_flat.shape)} does not match "
                f"operator dimension ({self.n},)")
        self.context = (p_flat, batch)
        return self

    def lmax_estimate(self, *, power_iters: int = 8) -> float:
        """Power-iteration ``lmax`` bound at the CURRENT context."""
        p_flat, batch = self.context
        return estimate_ggn_lmax(self.loss_fn, self.unravel, p_flat, batch,
                                 damping=self.damping,
                                 power_iters=power_iters)


def _fsdp_axis(mesh: Mesh) -> str:
    """The FSDP shard axis for a flat parameter vector on ``mesh``: the
    axis ``models/sharding.py`` maps the ``embed`` logical dimension to
    (``data`` under the default rules), falling back to the first mesh
    axis when the rule names an axis the mesh does not have."""
    rule = shd.DEFAULT_RULES.get("embed") or ("data",)
    cand = rule[0] if rule[0] is not None else "data"
    return cand if cand in mesh.axis_names else mesh.axis_names[0]


class GGNDistOperator:
    """Damped GGN over the FSDP-sharded flat parameter vector.

    Implements the mesh ``DistributedOperator`` protocol *and* the
    bindable-context extension (``matvec_local_ctx`` / ``context`` /
    ``context_specs``), so prepared mesh sweeps thread
    ``(p_flat, batch)`` as a traced, sharded operand -- outer training
    steps rebind without retracing the shard_map program.

    Sharding: the flat vector is zero-padded to a multiple of the FSDP
    axis size (``n_pad``) and split 1-D along that axis -- the same
    ``embed -> data`` placement ``models/sharding.py`` gives the weight
    matrices, collapsed to the ravel.  The padded tail rides a decoupled
    ``damping * I`` block, so the operator stays SPD and a zero-padded
    RHS keeps a zero tail in the solution.  ``matvec_local_ctx``
    all-gathers the parameter and direction shards along the FSDP axis
    (the standard FSDP param-gather; per-shard ``ppermute``/``all_gather``
    traffic does not count against the one-reduction-per-iteration gate,
    exactly like DistPoisson's halo exchanges), runs the full HVP
    redundantly per shard, and slices out this shard's chunk.  The CG
    scalar payloads reduce via ``reduce_scalars`` -- ONE stacked ``psum``
    over the FSDP axis per p(l)-CG iteration -- with
    ``reduce_scalars_start``/``finish`` (psum_scatter + delayed
    all_gather) backing ``comm="overlap"`` and ``ring_schedule`` backing
    ``comm="ring"``.
    """

    def __init__(self, loss_fn: Callable, params, batch, *, mesh: Mesh,
                 damping: float = 1e-3, axis: str | None = None):
        if axis is None:
            axis = _fsdp_axis(mesh)
        if axis not in mesh.axis_names:
            raise ValueError(f"axis {axis!r} not in mesh axes "
                             f"{tuple(mesh.axis_names)}")
        p_flat, unravel = ravel_pytree(params)
        n = int(p_flat.shape[0])
        k = int(mesh.shape[axis])
        n_pad = -(-n // k) * k
        self.loss_fn = loss_fn
        self.unravel = unravel
        self.damping = float(damping)
        self.mesh = mesh
        self.axis = axis
        self.n = n
        self.n_pad = n_pad
        self.name = "ggn@mesh"
        self._batch_specs = jax.tree.map(lambda _: P(), batch)
        dmp = self.damping

        def matvec_local_ctx(ctx, v_local):
            p_loc, bt = ctx
            # FSDP param/direction gather along the shard axis; tiled so
            # the result is the flat (n_pad,) vector
            pf = jax.lax.all_gather(p_loc, axis, tiled=True)
            vf = jax.lax.all_gather(v_local, axis, tiled=True)
            hv = ggn_hvp(loss_fn, unravel, pf[:n], bt, vf[:n], dmp)
            if n_pad > n:
                hv = jnp.concatenate([hv, dmp * vf[n:]])
            i = jax.lax.axis_index(axis)
            chunk = n_pad // k
            return jax.lax.dynamic_slice_in_dim(hv, i * chunk, chunk)

        self.matvec_local_ctx = matvec_local_ctx
        self.context = (self.pad(p_flat), batch)

    # ---- bindable-context extension -----------------------------------

    def bind(self, p_flat, batch) -> "GGNDistOperator":
        """Rebind to fresh (flat params, batch); pads to ``n_pad``."""
        if tuple(p_flat.shape) not in ((self.n,), (self.n_pad,)):
            raise ValueError(
                f"flat parameter shape {tuple(p_flat.shape)} does not match "
                f"operator dimension ({self.n},)")
        self.context = (self.pad(p_flat), batch)
        return self

    def context_specs(self):
        return (P(self.axis), self._batch_specs)

    # ---- padding helpers ----------------------------------------------

    def pad(self, v):
        """Zero-pad a flat ``(n,)`` vector to the sharded ``(n_pad,)``."""
        if v.shape[-1] == self.n_pad:
            return v
        return jnp.pad(v, [(0, 0)] * (v.ndim - 1)
                       + [(0, self.n_pad - self.n)])

    def unpad(self, v):
        """Drop the shard padding back to the true dimension ``n``."""
        return v[..., :self.n]

    # ---- DistributedOperator protocol ---------------------------------

    @property
    def shards(self) -> int:
        return int(self.mesh.shape[self.axis])

    @property
    def nshards(self) -> int:
        return self.shards

    @property
    def global_shape(self) -> tuple:
        return (self.n_pad,)

    @property
    def local_shape(self) -> tuple:
        return (self.n_pad // self.shards,)

    def spec(self) -> P:
        return P(self.axis)

    def matvec_local(self, xflat):
        """Calibration-only local HVP: the autotuner's throwaway probe
        binds the CURRENT context as trace constants.  Real solves go
        through ``matvec_local_ctx`` with the context as a traced
        operand."""
        p_full, bt = self.context
        i = jax.lax.axis_index(self.axis)
        chunk = self.n_pad // self.shards
        p_loc = jax.lax.dynamic_slice_in_dim(p_full, i * chunk, chunk)
        return self.matvec_local_ctx((p_loc, bt), xflat)

    def dot_local(self, u, v):
        return jnp.sum(u * v)

    def reduce_scalars(self, payload):
        """The ONE stacked psum per p(l)-CG iteration (FSDP axis only:
        the other mesh axes hold replicas of the same shard)."""
        return jax.lax.psum(payload, (self.axis,))

    def reduce_scalars_start(self, payload):
        """Split-phase issue (``comm="overlap"``): psum_scatter of the
        zero-padded payload along the FSDP axis; the matching ``finish``
        all-gathers the partial-sum chunks any number of iterations
        later."""
        w = payload.shape[-1]
        wp = -(-w // self.nshards) * self.nshards
        if wp != w:
            pad = [(0, 0)] * (payload.ndim - 1) + [(0, wp - w)]
            payload = jnp.pad(payload, pad)
        return jax.lax.psum_scatter(payload, (self.axis,),
                                    scatter_dimension=payload.ndim - 1,
                                    tiled=True)

    def reduce_scalars_finish(self, shard, width: int):
        full = jax.lax.all_gather(shard, (self.axis,), axis=shard.ndim - 1,
                                  tiled=True)
        return full[..., :width]

    def ring_schedule(self) -> tuple:
        """``shards - 1`` circulate-accumulate hops around the 1-D FSDP
        ring (``comm="ring"``); composes to the full ``psum``."""
        k = self.shards
        ring = tuple((i, (i + 1) % k) for i in range(k))
        return tuple((self.axis, ring, False) for _ in range(k - 1))

    # ---- spectral estimate --------------------------------------------

    def lmax_estimate(self, *, power_iters: int = 8) -> float:
        """Power-iteration ``lmax`` bound at the CURRENT context (runs
        the plain single-program HVP on the unpadded vector -- the
        estimate is a host-side scalar, not part of the mesh program)."""
        p_full, batch = self.context
        return estimate_ggn_lmax(self.loss_fn, self.unravel,
                                 self.unpad(p_full), batch,
                                 damping=self.damping,
                                 power_iters=power_iters)
