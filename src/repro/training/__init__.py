from .optim import (AdamWConfig, adamw_init, adamw_update,
                    abstract_adamw_state, compress_grads, decompress_grads,
                    compress_init)
from .checkpoint import CheckpointManager
from .data import Prefetcher, synth_batch
from .monitor import StragglerMonitor
from .newton_pcg import NewtonPCGConfig, newton_pcg_step
from .ggn import (GGNDistOperator, GGNOperator, estimate_ggn_lmax, ggn_hvp)
from .trainer import NewtonPCGTrainer

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "abstract_adamw_state",
    "compress_grads", "decompress_grads", "compress_init",
    "CheckpointManager", "Prefetcher", "synth_batch", "StragglerMonitor",
    "NewtonPCGConfig", "newton_pcg_step",
    "GGNDistOperator", "GGNOperator", "NewtonPCGTrainer",
    "estimate_ggn_lmax", "ggn_hvp",
]
