from .optim import (AdamWConfig, adamw_init, adamw_update,
                    abstract_adamw_state, compress_grads, decompress_grads,
                    compress_init)
from .checkpoint import CheckpointManager
from .data import Prefetcher, synth_batch
from .monitor import StragglerMonitor
from .newton_pcg import NewtonPCGConfig, newton_pcg_step

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "abstract_adamw_state",
    "compress_grads", "decompress_grads", "compress_init",
    "CheckpointManager", "Prefetcher", "synth_batch", "StragglerMonitor",
    "NewtonPCGConfig", "newton_pcg_step",
]
