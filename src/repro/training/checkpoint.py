"""Fault-tolerant checkpointing: atomic, mesh-agnostic, async, keep-N.

Design for 1000+-node operation:
  * **atomic commit**: writes go to ``step_XXXX.tmp/`` and are renamed into
    place only after every array and the manifest are fsync'd -- a crash
    mid-save can never corrupt the latest checkpoint;
  * **mesh-agnostic**: arrays are saved unsharded (gathered per leaf, not
    per tree, bounding host memory); restore re-shards onto whatever mesh
    the restart runs with -- elastic rescaling after node loss;
  * **async**: ``save_async`` snapshots to host then writes on a background
    thread so the train loop continues (one outstanding save max);
  * **keep-N GC** + ``latest_step`` discovery for automatic resume.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in sorted(tree.items()):
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for key, v in flat.items():
        parts = key.split("/")
        cur = root
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = v
    return root


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ---------------- save ------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        flat = _flatten(tree)
        tmp = self.dir / f"step_{step:08d}.tmp"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        manifest = {"step": step, "arrays": [], "extra": extra or {}}
        for i, (key, arr) in enumerate(flat.items()):
            host = np.asarray(arr)        # per-leaf gather bounds host memory
            fname = f"arr_{i:05d}.npy"
            with open(tmp / fname, "wb") as f:
                np.save(f, host)
                f.flush()
                os.fsync(f.fileno())
            manifest["arrays"].append({"key": key, "file": fname,
                                       "dtype": str(host.dtype),
                                       "shape": list(host.shape)})
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)             # atomic commit
        self._gc()
        return final

    def save_async(self, step: int, tree: Any, extra: Optional[dict] = None):
        """Snapshot to host synchronously, write in the background."""
        self.wait()
        host_flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}

        def work():
            self.save(step, _unflatten(host_flat), extra)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ---------------- restore --------------------------------------------
    def steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: Optional[int] = None, shardings: Any = None):
        """Returns (step, tree, extra).  ``shardings``: optional pytree of
        NamedShardings to place leaves onto (elastic re-sharding)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None, None
        path = self.dir / f"step_{step:08d}"
        manifest = json.loads((path / "manifest.json").read_text())
        flat = {}
        shard_flat = _flatten(shardings) if shardings is not None else {}
        for ent in manifest["arrays"]:
            arr = np.load(path / ent["file"])
            sh = shard_flat.get(ent["key"])
            flat[ent["key"]] = (jax.device_put(arr, sh) if sh is not None
                                else jax.numpy.asarray(arr))
        return step, _unflatten(flat), manifest.get("extra", {})
