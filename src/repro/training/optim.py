"""Optimizers: AdamW (fp32 state) and 8-bit Adam (block-quantized state).

8-bit Adam stores m/v as int8 with per-256-element fp32 block scales
(bitsandbytes-style).  At 6 bytes/param total train state (bf16 param + 2x
int8 + scales) arctic-480b fits a single v5e-256 pod -- see DESIGN.md
'distributed-optimization tricks'.

Also: int8 gradient compression with error feedback for the DP all-reduce
(halves/quarters the gradient collective bytes; the residual buffer keeps
convergence unbiased to first order).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

F32 = jnp.float32
_BLOCK = 256


# --------------------------------------------------------------------------
# block int8 quantization
# --------------------------------------------------------------------------

def _q8_block(last: int) -> int:
    """Largest power-of-two divisor of the last dim, capped at _BLOCK.

    Blocking along the LAST axis (instead of flattening the whole tensor)
    preserves the sharding of every leading dimension -- the flatten
    formulation forced GSPMD to replicate TB-scale expert-weight moments
    (see EXPERIMENTS.md dry-run iteration log)."""
    bs = 1
    while bs < _BLOCK and last % (bs * 2) == 0:
        bs *= 2
    return bs


def quantizable(shape) -> bool:
    return len(shape) >= 2 and _q8_block(shape[-1]) >= 16


def _q8(x: jax.Array):
    shape = x.shape
    bs = _q8_block(shape[-1])
    blocks = x.reshape(*shape[:-1], shape[-1] // bs, bs).astype(F32)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[..., 0]


def _dq8(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    return (q.astype(F32) * scale[..., None]).reshape(shape)


def _q8_shapes(shape):
    bs = _q8_block(shape[-1])
    qshape = tuple(shape[:-1]) + (shape[-1] // bs, bs)
    return qshape, qshape[:-1]


# --------------------------------------------------------------------------
# AdamW
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    eightbit: bool = False


def adamw_init(params, cfg: AdamWConfig):
    if cfg.eightbit:
        def mk(p):
            if not quantizable(p.shape):       # small/1-D params: fp32 state
                return jnp.zeros(p.shape, F32)
            qs, ss = _q8_shapes(p.shape)
            return {"q": jnp.zeros(qs, jnp.int8), "s": jnp.zeros(ss, F32)}
        return {"m": jax.tree.map(mk, params), "v": jax.tree.map(mk, params),
                "count": jnp.zeros((), jnp.int32)}
    return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params),
            "count": jnp.zeros((), jnp.int32)}


def abstract_adamw_state(abstract_params, cfg: AdamWConfig):
    return jax.eval_shape(functools.partial(adamw_init, cfg=cfg),
                          abstract_params)


#: top-level param subtrees stacked along a layer axis (updated via scan so
#: only ONE layer's f32 master copies are live at a time -- a whole stacked
#: MoE tensor in f32 is ~39 GB/device even sharded)
STACKED_KEYS = ("layers", "groups", "enc_layers", "dec_layers")


def _unzip3(out):
    is_t = lambda t: isinstance(t, tuple)  # noqa: E731
    return (jax.tree.map(lambda t: t[0], out, is_leaf=is_t),
            jax.tree.map(lambda t: t[1], out, is_leaf=is_t),
            jax.tree.map(lambda t: t[2], out, is_leaf=is_t))


def adamw_update(params, grads, state, cfg: AdamWConfig):
    cnt = state["count"] + 1
    b1c = 1.0 - cfg.b1 ** cnt.astype(F32)
    b2c = 1.0 - cfg.b2 ** cnt.astype(F32)

    def upd(p, g, m, v):
        g = g.astype(F32)
        q8 = isinstance(m, dict)
        mf = _dq8(m["q"], m["s"], p.shape) if q8 else m
        vf = _dq8(v["q"], v["s"], p.shape) if q8 else v
        mf = cfg.b1 * mf + (1 - cfg.b1) * g
        vf = cfg.b2 * vf + (1 - cfg.b2) * g * g
        step = (mf / b1c) / (jnp.sqrt(vf / b2c) + cfg.eps)
        newp = (p.astype(F32) - cfg.lr * (step + cfg.weight_decay
                                          * p.astype(F32))).astype(p.dtype)
        if q8:
            mq, ms = _q8(mf)
            vq, vs = _q8(vf)
            return newp, {"q": mq, "s": ms}, {"q": vq, "s": vs}
        return newp, mf, vf

    def apply_tree(p, g, m, v):
        out = jax.tree.map(upd, p, g, m, v,
                           is_leaf=lambda x: isinstance(x, jax.Array)
                           or hasattr(x, "shape"))
        return _unzip3(out)

    newp: dict = {}
    newm: dict = {}
    newv: dict = {}
    for key in params:
        sub = (params[key], grads[key], state["m"][key], state["v"][key])
        if key in STACKED_KEYS:
            def body(_, xs):
                return None, apply_tree(*xs)
            _, (np_, nm, nv) = jax.lax.scan(body, None, sub)
        else:
            np_, nm, nv = apply_tree(*sub)
        newp[key], newm[key], newv[key] = np_, nm, nv
    return newp, {"m": newm, "v": newv, "count": cnt}


# --------------------------------------------------------------------------
# gradient compression (int8 + error feedback)
# --------------------------------------------------------------------------

def compress_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)


def compress_grads(grads, residual):
    """Returns (int8 payloads with scales, new residual).  The all-reduce is
    then performed on the int8 payload (4x fewer bytes than f32)."""
    def comp(g, r):
        gf = g.astype(F32) + r
        q, s = _q8(gf)
        deq = _dq8(q, s, g.shape)
        return (q, s), gf - deq
    out = jax.tree.map(comp, grads, residual)
    payload = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    newres = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return payload, newres


def decompress_grads(payload, shapes):
    return jax.tree.map(lambda qs, p: _dq8(qs[0], qs[1], p.shape), payload,
                        shapes, is_leaf=lambda t: isinstance(t, tuple))
