"""NewtonPCGTrainer: prepared deep-pipelined HVP solves as the inner loop.

The legacy :func:`repro.training.newton_pcg.newton_pcg_step` calls
``plcg_scan`` directly and re-traces whenever its closure changes; this
trainer is the subsystem form: it prepares ONE :class:`repro.core.Solver`
per parameter shape at the first step and runs every outer step's
``(GGN + lambda I) d = -g`` solve through it.  The inner solve therefore
inherits the full engine feature set -- per-lane convergence masking,
``comm="blocking"|"overlap"|"ring"`` reduction policies on a mesh,
``precision=`` bf16 window storage, in-scan ``restart=`` /
``residual_replacement=`` breakdown recovery, and ``l="auto"`` /
``comm="auto"`` calibration against the *measured* HVP latency (the
autotuner probes the GGN matvec itself, so the chosen depth reflects how
many HVPs one gradient-sized reduction actually hides).

Zero-retrace outer loop: the GGN operators are *bindable* -- the
``(p_flat, batch)`` context is a traced operand of the prepared sweeps,
so step 2..N rebind fresh data into the step-1 compiled programs
(asserted via ``Solver.compile_counts()`` in the tests).

The parameter pytree is flattened once per OUTER step; the inner solve's
k HVPs all reuse that flat view (``ggn.GGNOperator`` owns the one
``unravel``).  On a mesh the flat vector is FSDP-sharded along the same
``embed -> data`` axis ``models/sharding.py`` gives the weight matrices,
and the CG dots reduce via the engine's ONE stacked psum per iteration.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from ..core.session import Solver
from .ggn import GGNDistOperator, GGNOperator, estimate_ggn_lmax
from .newton_pcg import NewtonPCGConfig


class NewtonPCGTrainer:
    """Second-order trainer: p(l)-CG Newton direction per outer step.

    ``cfg`` carries the optimizer-level knobs (depth ``l`` -- an int or
    ``"auto"`` --, inner budget ``cg_iters``, damping, learning rate,
    inner tolerance ``cg_tol``, optional pinned ``lmax_estimate``); the
    keyword-only constructor arguments carry the solver-engine knobs
    (``mesh=``, ``comm=``, ``precision=``, ``restart=``,
    ``residual_replacement=``), all forwarded verbatim to the prepared
    :class:`repro.core.Solver`.

    ``monitor=`` (a :class:`repro.training.monitor.StragglerMonitor`)
    receives per-step solver evidence through ``record_solve`` --
    inner iterations, restarts/replacements, and the autotuner's
    decision record when ``l="auto"``/``comm="auto"`` calibrated.

    Preparation is lazy (first :meth:`step`): the spectral estimate, the
    operator and the prepared solver all need a concrete
    ``(params, batch)``.
    """

    def __init__(self, loss_fn: Callable, cfg: Optional[NewtonPCGConfig]
                 = None, *, mesh=None, comm=None, precision=None,
                 restart="auto", residual_replacement: Optional[int] = None,
                 axis: Optional[str] = None, monitor=None,
                 power_iters: int = 8, method: str = "plcg_scan"):
        self.loss_fn = loss_fn
        self.cfg = cfg if cfg is not None else NewtonPCGConfig()
        self.mesh = mesh
        self.comm = comm
        self.precision = precision
        self.restart = restart
        self.residual_replacement = residual_replacement
        self.axis = axis
        self.monitor = monitor
        self.power_iters = power_iters
        self.method = method
        self.op = None
        self.solver: Optional[Solver] = None
        self.spectrum: Optional[tuple] = None
        self._unravel = None
        self._val_grad = None
        self._step = 0

    # ---- lazy preparation -------------------------------------------------

    def _prepare(self, params, batch):
        """First-step setup: flat loss + grad program, spectral estimate,
        operator, prepared solver.  Returns the flat parameter vector."""
        cfg = self.cfg
        p_flat, unravel = ravel_pytree(params)
        self._unravel = unravel
        loss_fn = self.loss_fn

        def flat_loss(pf, bt):
            return loss_fn(unravel(pf), bt)

        self._val_grad = jax.jit(jax.value_and_grad(flat_loss))

        lmax = cfg.lmax_estimate
        if lmax is None:
            # satellite of the hardcoded-10.0 bound: cheap power iteration
            # so the Chebyshev shifts track the actual GGN spectrum
            lmax = estimate_ggn_lmax(loss_fn, unravel, p_flat, batch,
                                     damping=cfg.damping,
                                     power_iters=self.power_iters)
        self.spectrum = (cfg.damping, float(lmax))

        if self.mesh is not None:
            self.op = GGNDistOperator(loss_fn, params, batch,
                                      mesh=self.mesh, damping=cfg.damping,
                                      axis=self.axis)
        else:
            self.op = GGNOperator(loss_fn, params, batch,
                                  damping=cfg.damping)
        self.solver = Solver(self.op, self.method, tol=cfg.cg_tol,
                             maxiter=cfg.cg_iters, l=cfg.l,
                             spectrum=self.spectrum, comm=self.comm,
                             restart=self.restart,
                             residual_replacement=self.residual_replacement,
                             precision=self.precision)
        return p_flat

    # ---- outer step -------------------------------------------------------

    def _replicate(self, v):
        """Commit ``v`` as mesh-replicated: every outer step must present
        the prepared programs with the SAME input sharding (step 1 would
        otherwise arrive single-device and step 2+ mesh-replicated -- one
        spurious retrace)."""
        return jax.device_put(
            v, jax.sharding.NamedSharding(self.mesh,
                                          jax.sharding.PartitionSpec()))

    def step(self, params, batch):
        """One outer Newton step.  Returns ``(new_params, stats)``."""
        t0 = time.perf_counter()
        cfg = self.cfg
        if self.solver is None:
            p_flat = self._prepare(params, batch)
        else:
            p_flat, _ = ravel_pytree(params)
        if self.mesh is not None:
            p_flat = self._replicate(p_flat)
        else:
            # commit: step 1's host-built flat vector must present the
            # prepared sweep with the same placement as step 2+'s
            # committed update outputs (placement keys the jit cache)
            p_flat = jax.device_put(p_flat, jax.devices()[0])
        loss, g_flat = self._val_grad(p_flat, batch)

        self.op.bind(p_flat, batch)
        if self.mesh is not None:
            res = self.solver.solve(self._replicate(self.op.pad(-g_flat)))
            # replicate the direction (one param-sized all-gather, the
            # FSDP param-gather analog of the outer update): res.x comes
            # back P(axis)-sharded, and letting that leak into the next
            # step's p_flat would present the prepared programs with a
            # different input sharding than step 1 -- a spurious retrace
            d = self._replicate(self.op.unpad(res.x))
        else:
            res = self.solver.solve(-g_flat)
            d = res.x
        if int(res.iters) < 1:
            # truncated-Newton fallback: the inner solve committed no
            # update (immediate breakdown) -> steepest descent
            d = -g_flat

        new_flat = p_flat + cfg.lr * d
        new_params = self._unravel(new_flat)
        step_s = time.perf_counter() - t0
        stats = {
            "loss": loss,
            "grad_norm": jnp.linalg.norm(g_flat),
            "cg_resnorm": res.final_resnorm,
            "cg_iters": int(res.iters),
            "cg_converged": bool(res.converged),
            "cg_breakdown": int(res.breakdowns) > 0,
            "restarts": int(res.restarts),
            "replacements": int(res.replacements),
            "auto": res.info.get("auto"),
            "step_s": step_s,
        }
        if self.monitor is not None:
            self.monitor.record_solve(
                self._step, iters=stats["cg_iters"],
                converged=stats["cg_converged"],
                restarts=stats["restarts"],
                replacements=stats["replacements"],
                resnorm=(None if res.final_resnorm is None
                         else float(res.final_resnorm)),
                auto=stats["auto"])
        self._step += 1
        return new_params, stats

    # ---- introspection ----------------------------------------------------

    def compile_counts(self) -> dict:
        """Per-prepared-sweep XLA compile counts (the zero-retrace gate);
        empty before the first step."""
        return {} if self.solver is None else self.solver.compile_counts()
