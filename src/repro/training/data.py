"""Deterministic synthetic data pipeline with exact-restart semantics.

Each (step, shard) pair maps to an independent PRNG stream, so:
  * restarts resume mid-epoch exactly (``start_step`` skip-ahead costs O(1));
  * elastic re-sharding (different data-parallel degree after a restart)
    still yields the same global batch sequence;
  * no host state to checkpoint beyond the step counter.

A double-buffered prefetch thread overlaps host batch synthesis with device
execution (the host->device transfer of the next batch hides behind the
current step, mirroring a production input pipeline).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np

from repro.models.config import ModelConfig


def synth_batch(cfg: ModelConfig, step: int, batch: int, seq: int,
                seed: int = 0) -> dict:
    """Global batch for one step (deterministic in (cfg, step, seed))."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    out: dict = {}
    if cfg.family == "encdec":
        out["frames"] = rng.standard_normal(
            (batch, seq, cfg.d_model)).astype(np.float32)
        out["tokens"] = rng.integers(0, cfg.vocab, (batch, seq),
                                     dtype=np.int32)
    elif cfg.embeds_input:
        out["embeds"] = rng.standard_normal(
            (batch, seq, cfg.d_model)).astype(np.float32)
        out["labels"] = rng.integers(0, cfg.vocab, (batch, seq),
                                     dtype=np.int32)
    else:
        # zipfian token stream packed into fixed-length rows: gives the loss
        # a learnable structure (frequent tokens) unlike uniform noise
        z = rng.zipf(1.3, size=(batch, seq)).astype(np.int64)
        out["tokens"] = np.minimum(z, cfg.vocab - 1).astype(np.int32)
    return out


class Prefetcher:
    """Double-buffered background batch producer."""

    def __init__(self, cfg: ModelConfig, batch: int, seq: int, *,
                 start_step: int = 0, seed: int = 0, depth: int = 2):
        self.cfg, self.batch, self.seq, self.seed = cfg, batch, seq, seed
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            b = synth_batch(self.cfg, step, self.batch, self.seq, self.seed)
            self._q.put((step, b))
            step += 1

    def __iter__(self) -> Iterator[tuple]:
        while True:
            yield self._q.get()

    def close(self):
        self._stop.set()
        try:
            self._q.get_nowait()
        except queue.Empty:
            pass
