"""Straggler / health monitoring hooks for large-fleet operation.

Per-step wall times feed an online mean/variance estimate; steps slower
than ``mean + k * std`` are flagged (the production hook would trigger
hot-spare rescheduling / ICI route avoidance -- here we log and count,
which is what the train loop consumes to decide on checkpoint-and-restart).
A heartbeat file lets an external supervisor detect a hung process.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from typing import Optional


@dataclasses.dataclass
class StragglerMonitor:
    k_sigma: float = 3.0
    warmup: int = 5
    heartbeat_path: Optional[str] = None
    _n: int = 0
    _mean: float = 0.0
    _m2: float = 0.0
    flagged: int = 0
    history: list = dataclasses.field(default_factory=list)

    def record(self, step: int, seconds: float) -> bool:
        """Returns True when the step is a straggler."""
        self.history.append(seconds)
        slow = False
        if self._n >= self.warmup:
            std = (self._m2 / max(self._n - 1, 1)) ** 0.5
            slow = seconds > self._mean + self.k_sigma * max(std, 1e-9)
        self._n += 1
        delta = seconds - self._mean
        self._mean += delta / self._n
        self._m2 += delta * (seconds - self._mean)
        if slow:
            self.flagged += 1
        if self.heartbeat_path:
            pathlib.Path(self.heartbeat_path).write_text(json.dumps(
                {"step": step, "t": time.time(), "step_s": seconds,
                 "stragglers": self.flagged}))
        return slow

    @property
    def mean_step_s(self) -> float:
        return self._mean
