"""Straggler / health monitoring hooks for large-fleet operation.

Per-step wall times feed an online mean/variance estimate; steps slower
than ``mean + k * std`` are flagged (the production hook would trigger
hot-spare rescheduling / ICI route avoidance -- here we log and count,
which is what the train loop consumes to decide on checkpoint-and-restart).
A heartbeat file lets an external supervisor detect a hung process.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from typing import Optional


@dataclasses.dataclass
class StragglerMonitor:
    k_sigma: float = 3.0
    warmup: int = 5
    heartbeat_path: Optional[str] = None
    _n: int = 0
    _mean: float = 0.0
    _m2: float = 0.0
    flagged: int = 0
    history: list = dataclasses.field(default_factory=list)
    solves: list = dataclasses.field(default_factory=list)
    last_solve: Optional[dict] = None

    def record(self, step: int, seconds: float) -> bool:
        """Returns True when the step is a straggler."""
        self.history.append(seconds)
        slow = False
        if self._n >= self.warmup:
            std = (self._m2 / max(self._n - 1, 1)) ** 0.5
            slow = seconds > self._mean + self.k_sigma * max(std, 1e-9)
        self._n += 1
        delta = seconds - self._mean
        self._mean += delta / self._n
        self._m2 += delta * (seconds - self._mean)
        if slow:
            self.flagged += 1
        if self.heartbeat_path:
            beat = {"step": step, "t": time.time(), "step_s": seconds,
                    "stragglers": self.flagged}
            if self.last_solve is not None:
                beat["solve"] = self.last_solve
            pathlib.Path(self.heartbeat_path).write_text(json.dumps(beat))
        return slow

    def record_solve(self, step: int, *, iters: int, converged: bool,
                     restarts: int = 0, replacements: int = 0,
                     resnorm: Optional[float] = None,
                     auto: Optional[dict] = None) -> None:
        """Per-step inner-solver evidence from the Newton-CG trainer:
        inner iteration count, convergence, in-scan restart /
        residual-replacement counts, and the autotuner's decision record
        (``info["auto"]``: chosen depth/policy + measured latencies) when
        ``l="auto"``/``comm="auto"`` calibrated the session.  Rides the
        next heartbeat so an external supervisor sees solver health, not
        just wall times."""
        entry = {"step": step, "iters": int(iters),
                 "converged": bool(converged), "restarts": int(restarts),
                 "replacements": int(replacements)}
        if resnorm is not None:
            entry["resnorm"] = float(resnorm)
        if auto is not None:
            entry["auto"] = auto
        self.solves.append(entry)
        self.last_solve = entry

    @property
    def mean_step_s(self) -> float:
        return self._mean
