"""Newton-pCG: the paper's p(l)-CG as a second-order training optimizer.

Each outer step solves (GGN + lambda I) d = -g with the *deep-pipelined* CG
engine (core/plcg_scan.py).  The mapping onto the paper's cost model is
exact:

  SPMV   <-> Gauss-Newton Hessian-vector product (one extra fwd+bwd pass:
             compute-heavy, reduction-light -- precisely the operation the
             paper overlaps the global reduction with);
  GLRED  <-> the CG dot products over the FSDP-sharded parameter vector
             (all-reduces across the whole mesh);
  l      <-> how many HVPs one reduction is hidden behind.

The parameter pytree is flattened ONCE per outer step (ravel_pytree); the
inner solver runs on flat vectors with the depth-l in-flight queue, and
every one of its k HVPs reuses that flat view.  A damped-GGN solve is
SPD, so CG applies; square-root breakdowns fall back to the last iterate
(equivalent to truncated-Newton early stopping).

This module is the *direct* form -- one ``plcg_scan`` call per step, fully
jittable, no session state.  The subsystem form is
:class:`repro.training.trainer.NewtonPCGTrainer`, which prepares a
:class:`repro.core.Solver` once per shape and adds mesh execution,
``comm=``/``precision=`` policies and ``l="auto"`` calibration on top of
the same GGN operator (``repro.training.ggn``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.core.plcg_scan import plcg_scan
from repro.core.shifts import chebyshev_shifts
from repro.training.ggn import estimate_ggn_lmax, ggn_hvp

#: Conservative legacy spectral bound, used only when the step runs under
#: an outer jit with ``lmax_estimate=None`` (the Chebyshev shifts must be
#: trace-time constants, so no host-side power iteration can run there).
FALLBACK_LMAX = 10.0


@dataclasses.dataclass(frozen=True)
class NewtonPCGConfig:
    l: Union[int, str] = 2         # pipeline depth (int, or "auto" for the
    #                                prepared trainer's calibrated depth)
    cg_iters: int = 16             # inner iterations (solution index budget)
    damping: float = 1e-3          # lambda (Levenberg-Marquardt)
    lr: float = 1.0                # step on the Newton direction
    cg_tol: float = 1e-4           # inner relative-residual tolerance
    lmax_estimate: Optional[float] = None
    #: spectral bound for the Chebyshev shifts; None (default) estimates
    #: it by power iteration (``repro.training.ggn.estimate_ggn_lmax``)


def ggn_matvec(loss_fn: Callable, p_flat, batch, unravel, v_flat, damping):
    """Gauss-Newton product (J^T H_out J + damping) v on flat vectors.

    Operates on the already-flat ``p_flat`` -- the flatten/unravel pair is
    hoisted to once per outer step (``newton_pcg_step``), so the inner
    solve's k HVPs never re-ravel the parameter pytree.
    """
    return ggn_hvp(loss_fn, unravel, p_flat, batch, v_flat, damping)


def resolve_lmax(loss_fn: Callable, unravel, p_flat, batch,
                 cfg: NewtonPCGConfig) -> float:
    """The spectral bound feeding the Chebyshev shifts: the pinned
    ``cfg.lmax_estimate`` when given, else a cheap power-iteration
    estimate at the current (params, batch).  Under an outer jit the
    shifts must be trace-time constants, so a traced ``p_flat`` falls
    back to the conservative :data:`FALLBACK_LMAX` (pin the estimate or
    use the prepared trainer to avoid that)."""
    if cfg.lmax_estimate is not None:
        return float(cfg.lmax_estimate)
    if isinstance(p_flat, jax.core.Tracer):
        return FALLBACK_LMAX
    return estimate_ggn_lmax(loss_fn, unravel, p_flat, batch,
                             damping=cfg.damping)


def newton_pcg_step(loss_fn: Callable, params, batch, cfg: NewtonPCGConfig):
    """One outer step.  Returns (new_params, stats)."""
    p_flat, unravel = ravel_pytree(params)
    loss, g_tree = jax.value_and_grad(lambda p: loss_fn(p, batch))(params)
    g_flat, _ = ravel_pytree(g_tree)

    matvec = functools.partial(ggn_matvec, loss_fn, p_flat, batch, unravel,
                               damping=cfg.damping)

    if not isinstance(cfg.l, int):
        raise ValueError("the direct newton_pcg_step needs an integer "
                         "pipeline depth; l='auto' calibration lives in "
                         "repro.training.trainer.NewtonPCGTrainer")
    lmax = resolve_lmax(loss_fn, unravel, p_flat, batch, cfg)
    sigma = chebyshev_shifts(cfg.damping, lmax, cfg.l)
    out = plcg_scan(matvec, -g_flat, None,
                    l=cfg.l, iters=cfg.cg_iters + cfg.l + 1,
                    sigma=tuple(sigma), tol=cfg.cg_tol)
    d = jnp.where(out.k_done >= 0, 1.0, 0.0) * out.x
    # fall back to steepest descent if the inner solve broke down at once
    d = jnp.where(out.breakdown & (out.k_done < 1), -g_flat * cfg.lr, d)
    new_flat = p_flat + cfg.lr * d
    stats = {"loss": loss, "cg_resnorm": out.resnorms,
             "cg_converged": out.converged, "cg_breakdown": out.breakdown,
             "grad_norm": jnp.linalg.norm(g_flat)}
    return unravel(new_flat), stats
