"""Newton-pCG: the paper's p(l)-CG as a second-order training optimizer.

Each outer step solves (GGN + lambda I) d = -g with the *deep-pipelined* CG
engine (core/plcg_scan.py).  The mapping onto the paper's cost model is
exact:

  SPMV   <-> Gauss-Newton Hessian-vector product (one extra fwd+bwd pass:
             compute-heavy, reduction-light -- precisely the operation the
             paper overlaps the global reduction with);
  GLRED  <-> the CG dot products over the FSDP-sharded parameter vector
             (all-reduces across the whole mesh);
  l      <-> how many HVPs one reduction is hidden behind.

The parameter pytree is flattened once per outer step (ravel_pytree); the
inner solver runs on flat vectors with the depth-l in-flight queue.  A
damped-GGN solve is SPD, so CG applies; square-root breakdowns fall back to
the last iterate (equivalent to truncated-Newton early stopping).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.core.plcg_scan import plcg_scan
from repro.core.shifts import chebyshev_shifts


@dataclasses.dataclass(frozen=True)
class NewtonPCGConfig:
    l: int = 2                     # pipeline depth
    cg_iters: int = 16             # inner iterations (solution index budget)
    damping: float = 1e-3          # lambda (Levenberg-Marquardt)
    lr: float = 1.0                # step on the Newton direction
    lmax_estimate: float = 10.0    # spectral bound for the Chebyshev shifts


def ggn_matvec(loss_fn: Callable, params, batch, unravel, v_flat, damping):
    """Gauss-Newton product (J^T H_out J + damping) v on flat vectors."""
    p_flat, _ = ravel_pytree(params)

    def f(pf):
        return loss_fn(unravel(pf), batch)

    # GGN via double-backprop on the scalar loss: here we use the (PSD)
    # Gauss-Newton approximation J^T J for the softmax-CE composite by
    # hvp of the loss plus damping; for CE the Fisher == GGN.
    def grad_f(pf):
        return jax.grad(f)(pf)

    _, hv = jax.jvp(grad_f, (p_flat,), (v_flat,))
    return hv + damping * v_flat


def newton_pcg_step(loss_fn: Callable, params, batch, cfg: NewtonPCGConfig):
    """One outer step.  Returns (new_params, stats)."""
    p_flat, unravel = ravel_pytree(params)
    loss, g_tree = jax.value_and_grad(lambda p: loss_fn(p, batch))(params)
    g_flat, _ = ravel_pytree(g_tree)

    matvec = functools.partial(ggn_matvec, loss_fn, params, batch, unravel,
                               damping=cfg.damping)

    sigma = chebyshev_shifts(cfg.damping, cfg.lmax_estimate, cfg.l)
    out = plcg_scan(matvec, -g_flat, None,
                    l=cfg.l, iters=cfg.cg_iters + cfg.l + 1,
                    sigma=tuple(sigma), tol=1e-4)
    d = jnp.where(out.k_done >= 0, 1.0, 0.0) * out.x
    # fall back to steepest descent if the inner solve broke down at once
    d = jnp.where(out.breakdown & (out.k_done < 1), -g_flat * cfg.lr, d)
    new_flat = p_flat + cfg.lr * d
    stats = {"loss": loss, "cg_resnorm": out.resnorms,
             "cg_converged": out.converged, "cg_breakdown": out.breakdown,
             "grad_norm": jnp.linalg.norm(g_flat)}
    return unravel(new_flat), stats
