"""Communication policies: how the per-iteration global reduction runs.

The paper's point is that p(l)-CG *tolerates an l-iteration delay* on the
scalar payload of each iteration -- the reduction may be in flight while
the next l SPMVs (and the shard-local preconditioner apply, Remark 13)
proceed.  The mesh engine realizes that tolerance through one of three
:class:`CommPolicy` modes, selected with the ``comm=`` keyword of
``repro.core.solve`` / :class:`repro.core.session.Solver` /
``repro.distributed.prepare_on_mesh``:

  ==============  =========================================================
  ``"blocking"``  one stacked ``psum`` per iteration (the default; the
                  delay exists only as scheduler slack)
  ``"overlap"``   the psum is SPLIT: a ``psum_scatter`` issued at
                  iteration k and a delayed ``all_gather`` consumed at
                  iteration k+d -- the reduction is *structurally* in
                  flight for d iterations of local compute (the
                  reduction-pipelining design of arXiv:1905.06850)
  ``"ring"``      no all-reduce primitive at all: a circulate-accumulate
                  ppermute ring staged ACROSS scan iterations, one
                  neighbor hop per in-flight slot per iteration (needs
                  pipeline depth l >= ring hops + 1)
  ==============  =========================================================

``depth`` (overlap only) is the number of iterations the scattered
partial stays in flight before the gather, ``1 <= depth <= l`` (default
``l``, the maximum slack).  The *total* consumption delay is always
exactly l in every mode -- the p(l)-CG recurrences require it -- so the
policy changes only where inside that window the reduction completes.

The policy is normalized ONCE by the engine front-end
(``repro.core.engine._prepare_comm``); execution layers receive a
:class:`CommPolicy` and build a :class:`CommRuntime` against the
operator's split-phase reduction methods
(``reduce_scalars_start`` / ``reduce_scalars_finish`` /
``ring_schedule`` -- see ``repro.distributed.operator``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

COMM_MODES = ("blocking", "overlap", "ring")


@dataclasses.dataclass(frozen=True)
class CommPolicy:
    """Normalized communication policy (hashable; part of sweep-cache keys).

    ``mode`` is one of :data:`COMM_MODES`; ``depth`` is the overlap
    staging depth d (``None`` resolves to the pipeline depth l at use).
    """

    mode: str = "blocking"
    depth: Optional[int] = None

    def __post_init__(self):
        if self.mode not in COMM_MODES:
            raise ValueError(
                f"comm mode must be one of {'|'.join(COMM_MODES)}, got "
                f"{self.mode!r}")
        if self.depth is not None:
            if self.mode != "overlap":
                raise ValueError(
                    f"comm depth applies to mode 'overlap' only (mode "
                    f"{self.mode!r} stages are fixed by l and the mesh)")
            if int(self.depth) < 1:
                raise ValueError(f"comm depth must be >= 1, got {self.depth}")
            object.__setattr__(self, "depth", int(self.depth))

    @property
    def is_blocking(self) -> bool:
        return self.mode == "blocking"

    def resolve_depth(self, l: int) -> int:
        """The staging depth d for pipeline depth ``l`` (overlap: the
        explicit depth or l; ring/blocking: the full window l)."""
        return l if self.depth is None else self.depth


def as_comm_policy(comm) -> CommPolicy:
    """Promote ``comm`` (None | mode string | CommPolicy) to a
    :class:`CommPolicy` -- the one normalization point, mirroring
    ``as_preconditioner`` for ``M=``."""
    if comm is None:
        return CommPolicy()
    if isinstance(comm, CommPolicy):
        return comm
    if isinstance(comm, str):
        return CommPolicy(mode=comm)
    raise TypeError(
        f"cannot interpret {type(comm).__name__} as a communication "
        f"policy; pass one of {'|'.join(COMM_MODES)} or a "
        "repro.core.comm.CommPolicy")


@dataclasses.dataclass(frozen=True)
class CommRuntime:
    """Resolved split-phase reduction, consumed by ``plcg_scan``'s
    in-flight queue (built per sweep by :func:`build_comm_runtime`).

    ``overlap``: ``start(payload)`` issues the ``psum_scatter`` (returns
    the local shard of the partially reduced, zero-padded payload);
    ``finish(shard, width)`` issues the delayed ``all_gather`` and
    unpads; ``nshards`` sizes the in-flight shard slots.

    ``ring``: ``schedule`` is the static hop list -- one
    ``(axis_name, perm, reset_circ)`` per neighbor exchange of the
    circulate-accumulate all-reduce (rows then columns on a 2-D torus);
    slot j of the queue applies hop ``l-1-j`` while shifting, so a
    payload completes all hops strictly before reaching the head.
    """

    mode: str
    depth: int
    nshards: int = 1
    start: Optional[Callable] = None
    finish: Optional[Callable] = None
    schedule: tuple = ()


def build_comm_runtime(policy: CommPolicy, op, l: int) -> Optional[CommRuntime]:
    """Resolve ``policy`` against operator ``op`` for pipeline depth l.

    Returns ``None`` for the blocking policy (the engine keeps its plain
    ``reduce_scalars`` psum).  Raises the uniform capability errors when
    the operator lacks the split-phase form or the pipeline is too
    shallow for the requested staging -- called once at preparation time
    (``PreparedMeshSolver``), never per solve.
    """
    policy = as_comm_policy(policy)
    if policy.is_blocking:
        return None
    if policy.mode == "overlap":
        if (getattr(op, "reduce_scalars_start", None) is None
                or getattr(op, "reduce_scalars_finish", None) is None):
            raise ValueError(
                f"operator {type(op).__name__!r} has no split-phase "
                "reduction (reduce_scalars_start/reduce_scalars_finish), "
                "so comm='overlap' has no execution path on it; implement "
                "the split-phase form of the DistributedOperator protocol "
                "or use comm='blocking'")
        d = policy.resolve_depth(l)
        if not 1 <= d <= l:
            raise ValueError(
                f"comm='overlap' depth must satisfy 1 <= depth <= l "
                f"(the reduction is consumed exactly l={l} iterations "
                f"after issue), got depth={d}")
        # late-binding closures: ``op`` may be a weakref.proxy (the mesh
        # sweep builders trace through one so the cached jitted program
        # never pins the operator) -- resolving the bound method here
        # would capture a strong reference to the referent
        return CommRuntime(mode="overlap", depth=d, nshards=_nshards(op),
                           start=lambda p: op.reduce_scalars_start(p),
                           finish=lambda s, w: op.reduce_scalars_finish(s, w))
    # ring
    sched_fn = getattr(op, "ring_schedule", None)
    if sched_fn is None:
        raise ValueError(
            f"operator {type(op).__name__!r} has no ring reduction "
            "schedule (ring_schedule), so comm='ring' has no execution "
            "path on it; implement the split-phase form of the "
            "DistributedOperator protocol or use comm='blocking'")
    schedule = tuple(sched_fn())
    if l < len(schedule) + 1:
        raise ValueError(
            f"comm='ring' needs pipeline depth l >= {len(schedule) + 1} "
            f"(= {len(schedule)} ring hops of this mesh + 1) so every "
            f"payload completes its hops before consumption, got l={l}; "
            "deepen the pipeline or use comm='overlap'")
    return CommRuntime(mode="ring", depth=l, schedule=schedule)


def _nshards(op) -> int:
    """Number of shards the split reduction scatters over: the
    operator's own ``nshards`` when it declares one (an operator may
    scatter over a subset of the mesh axes, e.g. the FSDP axis only),
    else the full device grid."""
    n = getattr(op, "nshards", None)
    if n is not None:
        return int(n)
    import numpy as np
    return int(np.prod(list(op.mesh.shape.values())))


def ring_hop(spec, acc, circ):
    """Apply one circulate-accumulate ring hop.

    ``spec = (axis_name, perm, reset_circ)``: circulate the running
    buffer (or, entering a new torus phase, the accumulated partial) one
    position around the ring and fold it into the accumulator.  Pure
    neighbor traffic -- exactly one ``ppermute``.
    """
    import jax

    axis, perm, reset = spec
    circ2 = jax.lax.ppermute(acc if reset else circ, axis, list(perm))
    return acc + circ2, circ2
