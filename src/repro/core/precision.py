"""Precision policies: window *storage* dtype vs scalar *compute* dtype.

The p(l)-CG memory footprint is dominated by the 3l+3 basis/window
vectors (paper Table 1; the engine's lane-major ``(n, l+1)`` ``Zw``,
``(n, 2l+1)`` ``Vw`` and ``(n, 3)`` ``Zhw`` arrays), and every fused
iteration streams all of them through HBM -- so *storage* precision, not
compute precision, bounds kernel throughput at depth.  A
:class:`PrecisionPolicy` splits the two:

  * ``storage`` -- the dtype of the window arrays and the SPMV
    input/output stream.  ``bfloat16`` halves the dominant HBM traffic;
    the Pallas kernels and their jnp oracles load it, accumulate in
    ``promote_types(storage, float32)`` and store back in ``storage``
    (the accumulator pattern they have had since the fused megakernel
    landed).
  * ``compute`` -- the dtype of ALL scalar state: the ``gam``/``dlt``/
    ``eta``/``zeta`` recurrences, the banded basis-change rows ``Gb``,
    dot-product payloads, the in-flight reduction queue (and therefore
    every psum / reduce_scatter / ring collective buffer on a mesh),
    the solution/search updates ``x``/``p``, and the convergence and
    breakdown tests.  Never below ``float32``; never below the dtype of
    ``b`` (an ``float64`` problem keeps ``float64`` scalars under the
    ``"bf16"`` ladder entry).

The attainable-accuracy cost of low-precision storage grows with
pipeline depth l (arXiv:1804.02962 framework, surfaced as
``residual_gap()``); pair deep-l bf16 runs with ``residual_replacement=``
to claw the gap back (``benchmarks/mp_bench.py`` commits the ladder).

The policy is normalized ONCE by the engine front-end
(``repro.core.engine._prepare_precision``) via
:func:`as_precision_policy` -- the same one-normalization-point contract
as ``as_preconditioner`` for ``M=`` and ``as_comm_policy`` for
``comm=``.  Execution layers receive a frozen, hashable
:class:`PrecisionPolicy` (part of every sweep-cache key) and resolve it
against the right-hand side's dtype with :meth:`PrecisionPolicy.resolve`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

#: dtype spellings accepted for either side of a policy
_DTYPE_NAMES = {
    "bf16": "bfloat16", "bfloat16": "bfloat16",
    "f16": "float16", "fp16": "float16", "float16": "float16",
    "f32": "float32", "fp32": "float32", "float32": "float32",
    "f64": "float64", "fp64": "float64", "float64": "float64",
}

#: the named storage ladder accepted by ``precision=`` (compute side
#: resolves per problem: promote_types(b.dtype, float32))
PRECISION_MODES = ("f32", "bf16", "f16", "f64")


def _canon(name, *, side):
    if name is None:
        return None
    key = str(name).lower()
    # accept numpy/jax dtype objects and strings alike
    key = {"<f4": "float32", "<f8": "float64"}.get(key, key)
    if key not in _DTYPE_NAMES:
        hint = ""
        if key == "tf32":
            hint = (" (tf32 is a matmul *compute* truncation on NVIDIA "
                    "hardware, not a storage dtype on this stack; use "
                    "'bf16' for low-precision storage or 'f32')")
        raise ValueError(
            f"unknown precision {side} dtype {name!r}; expected one of "
            f"{sorted(set(_DTYPE_NAMES))}{hint}")
    return _DTYPE_NAMES[key]


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Normalized precision policy (hashable; part of sweep-cache keys).

    ``storage`` / ``compute`` are canonical dtype names or ``None``:
    ``storage=None`` keeps the windows in ``b.dtype`` (the legacy
    uniform-precision behaviour); ``compute=None`` resolves to
    ``promote_types(b.dtype, float32)``.  The default policy (both
    ``None``) is exactly the pre-policy engine -- bit-identical graphs.
    """

    storage: Optional[str] = None
    compute: Optional[str] = None

    def __post_init__(self):
        object.__setattr__(self, "storage", _canon(self.storage,
                                                   side="storage"))
        object.__setattr__(self, "compute", _canon(self.compute,
                                                   side="compute"))
        if self.compute in ("bfloat16", "float16"):
            raise ValueError(
                f"compute dtype must be float32 or float64 -- the scalar "
                f"recurrences, collective payloads and convergence tests "
                f"are what keep low-precision storage usable -- got "
                f"{self.compute!r}")

    @property
    def is_default(self) -> bool:
        return self.storage is None and self.compute is None

    def resolve(self, b_dtype):
        """``(storage_dtype, compute_dtype)`` for a problem in ``b_dtype``.

        The default policy resolves to ``(b.dtype, b.dtype)`` exactly.
        Otherwise storage is the declared dtype (or ``b.dtype``) and
        compute is ``promote_types(b.dtype, declared-or-float32)`` --
        scalars never drop below the problem's own precision.
        """
        import jax.numpy as jnp
        b_dtype = jnp.dtype(b_dtype)
        if self.is_default:
            return b_dtype, b_dtype
        sdt = jnp.dtype(self.storage) if self.storage else b_dtype
        cdt = jnp.promote_types(b_dtype, self.compute or "float32")
        return sdt, jnp.dtype(cdt)

    def compute_dtype(self, b_dtype):
        """The scalar/convergence dtype for a problem in ``b_dtype`` --
        what tolerance floors must be validated against (an eps check on
        the *storage* dtype of ``b`` would spuriously reject tolerances
        the f32/f64 recurrences can reach)."""
        return self.resolve(b_dtype)[1]


def as_precision_policy(precision) -> PrecisionPolicy:
    """Promote ``precision`` (None | storage name | ``"<storage>x<bits>"``
    compound | dtype | PrecisionPolicy) to a :class:`PrecisionPolicy` --
    the one normalization point, mirroring ``as_comm_policy``.

    String forms: ``"bf16"`` (bf16 windows, f32-or-better scalars),
    ``"f32"``/``"f64"``/``"f16"`` likewise, and the explicit compounds
    ``"bf16x32"`` / ``"bf16x64"`` / ``"f32x64"`` pinning the compute
    side (``x<bits>`` = scalar recurrences in ``float<bits>``).
    """
    if precision is None:
        return PrecisionPolicy()
    if isinstance(precision, PrecisionPolicy):
        return precision
    if isinstance(precision, str):
        name = precision.lower()
        if "x" in name and name not in _DTYPE_NAMES:
            stor, _, bits = name.rpartition("x")
            return PrecisionPolicy(storage=stor, compute=f"f{bits}")
        return PrecisionPolicy(storage=name)
    try:  # numpy/jax dtype-likes name a storage dtype
        import numpy as np
        return PrecisionPolicy(storage=np.dtype(precision).name)
    except TypeError:
        pass
    raise TypeError(
        f"cannot interpret {type(precision).__name__} as a precision "
        f"policy; pass one of {'|'.join(PRECISION_MODES)}, a compound "
        "like 'bf16x32', or a repro.core.precision.PrecisionPolicy")
