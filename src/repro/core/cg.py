"""Classic (preconditioned) Conjugate Gradients -- paper Alg. 4.

Array-library agnostic: works on numpy or JAX arrays (python loop driver).
This is the baseline every communication-hiding variant is compared against;
per iteration it has 2 global reduction phases (the two dot products) that
are *synchronous* -- nothing overlaps them (Table 1, row 'CG').
"""
from __future__ import annotations

from typing import Optional

from .linop import LinearOperator
from .precond import Preconditioner
from .results import SolveResult


def _dot(a, b):
    return (a * b).sum()


def classic_cg(
    A: LinearOperator,
    b,
    x0=None,
    *,
    tol: float = 1e-8,
    maxiter: int = 1000,
    M: Optional[Preconditioner] = None,
    trace_true_residual: bool = False,
) -> SolveResult:
    """Hestenes-Stiefel CG with optional SPD preconditioner M^{-1}.

    Stops on ||r_i|| / ||b|| <= tol (recursive residual).
    """
    x = b * 0 if x0 is None else x0
    r = b - A @ x
    u = M(r) if M is not None else r            # preconditioned residual
    p = u
    gamma = _dot(r, u)
    bnorm = float(_dot(b, b)) ** 0.5
    resnorms = [float(_dot(r, r)) ** 0.5]
    true_resnorms = [resnorms[0]] if trace_true_residual else None
    converged = resnorms[-1] <= tol * bnorm
    it = 0
    while not converged and it < maxiter:
        s = A @ p
        sp = _dot(s, p)
        if sp == 0 or gamma == 0:     # exact convergence / lucky breakdown
            converged = True
            break
        alpha = gamma / sp
        x = x + alpha * p
        r = r - alpha * s
        u = M(r) if M is not None else r
        gamma_new = _dot(r, u)
        beta = gamma_new / gamma
        gamma = gamma_new
        p = u + beta * p
        it += 1
        resnorms.append(float(_dot(r, r)) ** 0.5)
        if trace_true_residual:
            tr = b - A @ x
            true_resnorms.append(float(_dot(tr, tr)) ** 0.5)
        converged = resnorms[-1] <= tol * bnorm
    return SolveResult(
        x=x, resnorms=resnorms, iters=it, converged=bool(converged),
        true_resnorms=true_resnorms,
        info={"method": "cg"},
    )
