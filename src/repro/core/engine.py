"""Unified solver engine: one front-end for every Krylov method in the repo.

``solve(A, b, method=..., l=..., M=...)`` dispatches through a method
registry that every solver registers into with a common
:class:`~repro.core.results.SolveResult` contract:

  =============  ========================================================
  ``cg``         classic Hestenes-Stiefel CG (paper Alg. 4)
  ``pcg``        Ghysels-Vanroose pipelined CG, depth 1 (paper Alg. 5)
  ``plcg``       deep-pipelined p(l)-CG, python reference (paper Alg. 2)
  ``plcg_scan``  jitted ``lax.scan`` p(l)-CG production engine (Alg. 3)
  ``dlanczos``   direct Lanczos (exact-arithmetic oracle, Remark 7)
  ``plminres``   deep-pipelined MINRES (paper Remark 6; indefinite OK)
  =============  ========================================================

Batched multi-RHS: a 2-D right-hand side ``B`` of shape ``(nrhs, n)``
solves all systems at once.  For the scan-engine methods (``plcg``,
``plcg_scan``) the batch runs as **one jitted ``vmap`` of the
``lax.scan`` engine** -- a single XLA compilation, a single fused program
in which every per-iteration reduction covers all right-hand sides.
Per-RHS convergence is masked inside the scan: a converged column's
state is frozen through the ``jnp.where``/``lax.select`` commit gate of
the engine body (under ``vmap`` that gate batches into a per-lane
``select``), mirroring how the paper's pipeline keeps all lanes busy
while individual systems finish at different iterations.  Methods
without a batched engine fall back to a loop of single-RHS solves.

The ``backend`` switch ("fused" | "pallas" | "ref" | "auto" | None)
selects the kernel tier used inside the scan engine's hot path (see
``plcg_scan``); it is threaded through both the single-RHS and the
batched paths, together with the operator's ``stencil2d`` structural
hint that lets ``backend="fused"`` fold the SPMV into its single
per-iteration Pallas launch.  Under the batched path the lane-major
``(n, window)`` state means every kernel batches to ONE
``(B, n, window)`` launch rather than B replays.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import solver_cache
from .cg import classic_cg
from .dlanczos import d_lanczos
from .linop import LinearOperator, dense_operator, is_bindable
from .pcg import ghysels_pcg
from .plcg import plcg
from .precision import as_precision_policy
from .precond import as_preconditioner
from .plcg_scan import plcg_solve
from .plcg_scan import plcg_scan as _plcg_scan_engine
from .plminres import plminres
from .results import SolveResult
from .shifts import chebyshev_shifts

Array = Any

_REGISTRY: dict[str, "MethodSpec"] = {}

#: Trace-time log of the batched vmap(scan) engine: one entry is appended
#: each time XLA *traces* (= compiles) the batched engine (single-device
#: and mesh-aware), so tests can assert that a batched ``solve(A, B)``
#: compiles exactly once.
BATCH_TRACE_EVENTS: list[tuple] = []


def clear_batch_trace() -> None:
    """Reset :data:`BATCH_TRACE_EVENTS` (test helper).

    The mesh engine and the single-device batched engine both append to
    this exact list object, so it must be cleared in place -- rebinding
    the module attribute would silently detach their appends.  This
    helper is the one supported way to reset it.
    """
    BATCH_TRACE_EVENTS.clear()


@dataclasses.dataclass(frozen=True)
class MethodSpec:
    """Registry entry for one solver method.

    ``fn(A, b, x0, *, tol, maxiter, M, l, sigma, spectrum, backend, **opts)``
    must return a :class:`SolveResult`.  ``batched`` is ``"vmap"`` when the
    method is backed by the jittable scan engine (batch solves run as one
    ``jit(vmap(scan))``) and ``"loop"`` otherwise.  ``supports_M`` /
    ``supports_mesh`` are the capability flags :func:`solve` checks up
    front -- the single source of truth replacing per-adapter
    ``ValueError``s, so every method rejects an unsupported ``M=`` /
    ``mesh=`` with the same documented message.  ``options`` declares the
    method-specific ``**options`` keys the adapter accepts: unknown keys
    are rejected by :func:`solve` / :class:`~repro.core.session.Solver`
    with a uniform error instead of leaking into the method body (where
    they used to surface as an adapter-dependent ``TypeError`` or be
    swallowed silently).  ``supports_comm`` marks methods whose mesh
    execution honors a ``comm=`` communication policy (split-phase /
    ring reductions; see ``repro.core.comm``).  ``mesh_options`` is the
    subset of ``options`` the mesh execution path honors -- the single
    place that restriction lives (checked by ``_prepare_mesh_options``;
    the mesh adapters no longer carry their own allow-lists).
    ``supports_restart`` marks methods whose scan engine can re-seed
    broken lanes in-trace (``restart=`` / ``residual_replacement=``, see
    ``plcg_scan``); only those accept the stability knob pair.
    ``supports_precision`` marks methods whose engine splits window
    *storage* dtype from scalar *compute* dtype (``precision=``, see
    ``repro.core.precision``); only those accept non-default policies.
    """

    name: str
    fn: Callable[..., SolveResult]
    batched: str = "loop"
    description: str = ""
    supports_M: bool = True
    supports_mesh: bool = False
    supports_comm: bool = False
    supports_restart: bool = False
    supports_precision: bool = False
    uses_sigma: bool = False
    options: frozenset = frozenset()
    mesh_options: frozenset = frozenset()


def register(name: str, *, batched: str = "loop", description: str = "",
             supports_M: bool = True, supports_mesh: bool = False,
             supports_comm: bool = False, supports_restart: bool = False,
             supports_precision: bool = False, uses_sigma: bool = False,
             options: Sequence[str] = (), mesh_options: Sequence[str] = ()):
    """Decorator registering a solver adapter under ``name``.

    ``uses_sigma`` marks pipelined methods that consume the auxiliary-
    basis shifts -- only those trigger the (possibly costly) default
    shift-interval derivation from ``M.precond_spectrum``.  ``options``
    is the closed set of method-specific ``**options`` keys the adapter
    accepts; ``mesh_options`` (must be a subset) is what survives on the
    mesh execution path (execution paths may restrict the sets further,
    never widen them).
    """
    if batched not in ("loop", "vmap"):
        raise ValueError(f"batched must be 'loop' or 'vmap', got {batched!r}")
    if set(mesh_options) - set(options):
        raise ValueError(
            f"mesh_options {sorted(set(mesh_options) - set(options))} of "
            f"method {name!r} are not declared in options")
    if supports_comm and not supports_mesh:
        raise ValueError(
            f"method {name!r} declares supports_comm without supports_mesh; "
            "communication policies only select the mesh reduction")

    def deco(fn):
        _REGISTRY[name] = MethodSpec(name=name, fn=fn, batched=batched,
                                     description=description,
                                     supports_M=supports_M,
                                     supports_mesh=supports_mesh,
                                     supports_comm=supports_comm,
                                     supports_restart=supports_restart,
                                     supports_precision=supports_precision,
                                     uses_sigma=uses_sigma,
                                     options=frozenset(options),
                                     mesh_options=frozenset(mesh_options))
        return fn

    return deco


def methods() -> tuple[str, ...]:
    """Registered method names, sorted."""
    return tuple(sorted(_REGISTRY))


#: The cross-cutting solve knobs -- the keyword-only group every entry
#: point (:func:`solve`, :class:`~repro.core.session.Solver`,
#: ``prepare_on_mesh``) accepts on top of the per-method ``**options``.
#: ONE validation table: each knob maps to the ``MethodSpec`` capability
#: flag that gates it (None = accepted by every method) and the execution
#: path it selects; the ``_prepare_*`` helper named in the third column
#: normalizes it exactly once per prepared solver (never per call).
#:
#:   knob        capability flag   normalized by                path
#:   ----------  ----------------  ---------------------------  -----------
#:   ``M=``      ``supports_M``    ``_prepare_preconditioner``  all
#:   ``mesh=``   ``supports_mesh`` ``_prepare_mesh_check``      mesh only
#:   ``backend=``  --              ``plcg_scan`` BACKENDS       single-dev
#:                                 (warned + ignored on a mesh)
#:   ``comm=``   ``supports_comm`` ``_prepare_comm``            mesh only
#:                                 (rejected off-mesh up front;
#:                                 ``"auto"`` = calibrated pick)
#:   ``l=``      ``uses_sigma``    ``_prepare_depth``           pipelined
#:                                 (``"auto"`` = calibrated pick,
#:                                 resolved at session construction
#:                                 via ``repro.core.autotune``)
#:   ``restart=``            ``supports_restart``
#:                                 ``_prepare_restart``         all
#:   ``residual_replacement=``  ``supports_restart``
#:                                 ``_prepare_restart``         all
#:   ``precision=``  ``supports_precision``
#:                                 ``_prepare_precision``       all
_KNOB_TABLE = {
    "M": "supports_M",
    "mesh": "supports_mesh",
    "backend": None,
    "l": "uses_sigma",
    "comm": "supports_comm",
    "restart": "supports_restart",
    "residual_replacement": "supports_restart",
    "precision": "supports_precision",
}


def methods_supporting(capability: str) -> tuple[str, ...]:
    """Registered method names carrying a capability flag
    ("M" | "mesh" | "comm" | "restart" | "precision") -- derived from
    :data:`_KNOB_TABLE`."""
    flag = _KNOB_TABLE[capability]
    if flag is None:
        return methods()
    return tuple(m for m in methods() if getattr(_REGISTRY[m], flag))


def describe_methods() -> dict[str, str]:
    """name -> one-line description for every registered method."""
    return {k: _REGISTRY[k].description for k in methods()}


def get_method(name: str) -> MethodSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown method {name!r}; registered methods: "
            f"{', '.join(methods())}") from None


def as_operator(A, b=None) -> LinearOperator:
    """Coerce ``A`` (LinearOperator | BindableOperator | dense square array
    | matvec callable) into an operator the engine can run."""
    if isinstance(A, LinearOperator):
        return A
    if is_bindable(A):
        # rebindable-context operator: pass through as-is -- the engine
        # threads A.context into the jitted sweeps as a traced operand
        # and keys its caches on the stable A.matvec_ctx callable
        return A
    if hasattr(A, "ndim") and getattr(A, "ndim") == 2:
        if A.shape[0] != A.shape[1]:
            raise ValueError(f"dense operator must be square, got {A.shape}")
        return dense_operator(A)
    if callable(A):
        if b is None:
            raise ValueError("a matvec callable needs b to infer the "
                             "problem dimension")
        n = b.shape[-1]
        return LinearOperator(matvec=A, n=n, name="matvec")
    raise TypeError(f"cannot interpret {type(A).__name__} as a linear "
                    "operator")


#: Modules whose frames count as "inside the engine" for warning
#: attribution: the front-end itself and the prepared-solver session layer
#: it delegates to.
_INTERNAL_MODULES = (__name__, __name__.rsplit(".", 1)[0] + ".session")


def _stacklevel_outside_engine() -> int:
    """``warnings.warn`` stacklevel of the first frame outside the engine
    (this module and the session layer).

    Used so engine warnings point at the *caller of* :func:`solve` /
    :class:`~repro.core.session.Solver` regardless of how many internal
    dispatch frames sit in between (the depth differs between the
    batched, loop, mesh and prepared-session paths and would otherwise
    silently drift on refactors).
    """
    import sys
    level = 1
    frame = sys._getframe(1)
    while (frame is not None
           and frame.f_globals.get("__name__") in _INTERNAL_MODULES):
        level += 1
        frame = frame.f_back
    return level


def _is_mesh_operator(A) -> bool:
    """Duck-typed DistributedOperator check (no distributed import)."""
    return hasattr(A, "matvec_local") and hasattr(A, "mesh")


def _resolve_sigma(sigma, spectrum, l: int) -> list[float]:
    if sigma is not None:
        sig = [float(s) for s in sigma]
        if len(sig) != l:
            raise ValueError(f"need exactly l={l} shifts, got {len(sig)}")
        return sig
    lmin, lmax = spectrum if spectrum is not None else (0.0, 8.0)
    return chebyshev_shifts(lmin, lmax, l)


# --------------------------------------------------------------------------
# one-time preparation helpers (shared by solve() and session.Solver)
# --------------------------------------------------------------------------
#
# These are the pieces of the old monolithic solve() body that must run
# exactly ONCE per prepared solver but used to run on every call: method
# lookup, option validation, preconditioner normalization and the
# shift-interval defaulting.  solve() composes them per call (one-shot
# semantics unchanged); session.Solver composes them at construction.

def _prepare_method(method: str) -> MethodSpec:
    """Registry lookup (raises the uniform unknown-method error)."""
    return get_method(method)


def _prepare_options(spec: MethodSpec, options: dict) -> None:
    """Reject ``**options`` keys outside the method's declared set.

    Before this gate, unknown keys leaked into the adapter bodies where
    they surfaced as an adapter-dependent ``TypeError`` (or were silently
    swallowed by a ``**kw`` sink); now every method raises one uniform
    error naming its accepted keys.  Execution paths (batched vmap, mesh)
    may restrict the set further at dispatch time -- they can never widen
    it.
    """
    unknown = set(options) - spec.options
    if unknown:
        accepted = (", ".join(sorted(spec.options)) if spec.options
                    else "none")
        raise ValueError(
            f"method {spec.name!r} does not accept options "
            f"{sorted(unknown)}; accepted options for {spec.name!r}: "
            f"{accepted}")


def _prepare_preconditioner(spec: MethodSpec, M):
    """Normalize ``M`` once: bare callables promote to the Preconditioner
    protocol, Identity collapses to the cheaper unpreconditioned pipeline,
    and methods without the capability flag reject it up front -- every
    downstream layer sees either None or a structured Preconditioner,
    never a raw closure."""
    M = as_preconditioner(M).runtime()
    if M is not None and not spec.supports_M:
        raise ValueError(
            f"method {spec.name!r} does not support preconditioning (M=); "
            f"methods with M= support: {', '.join(methods_supporting('M'))}")
    return M


def _prepare_spectrum(spec: MethodSpec, M, sigma, spectrum):
    """Default the auxiliary-basis shift interval from the preconditioned
    spectrum when the preconditioner knows it (only for shift-consuming
    pipelined methods -- BlockJacobi's estimate runs a power iteration,
    which cg/pcg would discard)."""
    if (M is not None and sigma is None and spectrum is None
            and spec.uses_sigma):
        return M.precond_spectrum((0.0, 8.0))
    return spectrum


def _prepare_mesh_check(spec: MethodSpec, backend) -> None:
    """Mesh-capability gate + the backend-ignored warning (the injected
    local-partial dots bypass every kernel tier by construction)."""
    if not spec.supports_mesh:
        raise ValueError(
            f"method {spec.name!r} has no mesh-aware execution path; "
            f"methods available on a mesh: "
            f"{', '.join(methods_supporting('mesh'))}")
    if backend is not None:
        import warnings
        warnings.warn(
            f"backend={backend!r} is ignored on the mesh path: the "
            "injected local-partial dots bypass every kernel tier by "
            "construction (the distributed hot path is the "
            "halo-exchange stencil plus the collective schedule)",
            stacklevel=_stacklevel_outside_engine())


def _prepare_depth(spec: MethodSpec, l):
    """Normalize the pipeline depth ``l`` once: a positive int, or the
    ``"auto"`` sentinel selecting measured-latency calibration
    (``repro.core.autotune``).  ``"auto"`` is resolved where the operator
    is known -- session construction (``Solver`` / ``prepare_on_mesh``)
    -- so this helper only validates; methods that do not consume a
    pipeline depth (``uses_sigma`` is the capability that moves with it)
    reject the sentinel up front with the uniform knob style."""
    if l == "auto":
        if not spec.uses_sigma:
            raise ValueError(
                f"method {spec.name!r} has no pipeline depth to tune "
                "(l='auto' calibrates the depth of the pipelined "
                "methods); methods with a depth knob: "
                f"{', '.join(m for m in methods() if _REGISTRY[m].uses_sigma)}")
        return "auto"
    l = int(l)
    if l < 1:
        raise ValueError(f"pipeline depth l must be >= 1 (or 'auto'), "
                         f"got {l}")
    return l


def _prepare_comm(spec: MethodSpec, comm, on_mesh: bool):
    """Normalize ``comm=`` once (string -> ``CommPolicy``) and gate it on
    the capability flag and the execution path -- non-blocking policies
    select the *mesh* reduction schedule, so off-mesh uses are rejected
    up front with the same uniform style as ``M=`` / ``mesh=``.

    ``comm="auto"`` is a *sentinel*, not a policy mode: on a mesh with a
    ``supports_comm`` method it passes through as the string for the
    session layer to resolve against measured reduction latencies
    (``repro.core.autotune``); anywhere else only the blocking reduction
    exists, so auto degrades to it silently (asking for "the fastest
    available schedule" where exactly one is available is not an error).
    """
    from .comm import as_comm_policy
    if comm == "auto":
        if on_mesh and spec.supports_comm:
            return "auto"
        from .comm import CommPolicy
        return CommPolicy()
    policy = as_comm_policy(comm)
    if policy.is_blocking:
        return policy
    if not spec.supports_comm:
        raise ValueError(
            f"method {spec.name!r} does not support communication "
            f"policies (comm=); methods with comm= support: "
            f"{', '.join(methods_supporting('comm'))}")
    if not on_mesh:
        raise ValueError(
            f"comm={policy.mode!r} selects the mesh reduction schedule "
            "and has no single-device execution path; pass mesh=... (or "
            "a DistributedOperator) or drop comm=")
    return policy


def _prepare_restart(spec: MethodSpec, restart, residual_replacement,
                     options: dict):
    """Normalize the stability knob pair (``restart=`` /
    ``residual_replacement=``) once per prepared solver.

    ``restart`` is ``"auto" | int | None``: an int caps the number of
    in-scan per-lane re-seeds on square-root breakdown; ``None`` disables
    them (a single-RHS solve then falls back to the deprecated host
    restart loop when the legacy ``max_restarts`` option asks for it).
    ``"auto"`` (the default) lets the engine pick: it resolves to 5 when
    ``residual_replacement`` already put the sweep in stability mode
    (recovery is then free) and to ``None`` otherwise -- the stability
    machinery widens the reduction payload by one slot and un-fuses the
    stencil megakernel, so it stays opt-in on the default path.

    ``residual_replacement`` is a period in committed updates (int >= 1)
    for the in-scan true-residual recompute ``r = b - A x``, or ``None``.

    Returns the normalized ``(restart, residual_replacement)`` pair of
    ``Optional[int]``s.  Explicit use of either knob on a method without
    the ``supports_restart`` capability raises up front; combining an
    explicit ``restart=`` int with the legacy ``max_restarts`` option
    raises (two restart caps, ONE semantics).
    """
    rr = residual_replacement
    if rr is not None:
        rr = int(rr)
        if rr < 1:
            raise ValueError(
                f"residual_replacement must be a period >= 1 (committed "
                f"updates between true-residual recomputes), got "
                f"{residual_replacement!r}")
    if restart == "auto":
        restart = 5 if (spec.supports_restart and rr is not None) else None
    elif restart is not None:
        restart = int(restart)
        if restart < 0:
            raise ValueError(f"restart must be >= 0, got {restart!r}")
        if "max_restarts" in options:
            raise ValueError(
                "restart= (in-scan recovery) and the legacy max_restarts "
                "option (host restart loop) are mutually exclusive; drop "
                "max_restarts -- restart= is the one restart semantics")
    if (restart is not None or rr is not None) and not spec.supports_restart:
        raise ValueError(
            f"method {spec.name!r} does not support in-scan restarts / "
            f"residual replacement (restart= / residual_replacement=); "
            f"methods with restart support: "
            f"{', '.join(methods_supporting('restart'))}")
    return restart, rr


def _prepare_precision(spec: MethodSpec, precision):
    """Normalize ``precision=`` once (string/dtype -> ``PrecisionPolicy``)
    and gate it on the capability flag: the storage/compute dtype split
    lives in the scan engine's window handling, so methods without it
    reject non-default policies up front with the uniform style of the
    other knobs.  The default policy (None) is accepted everywhere -- it
    resolves to the legacy uniform-precision graphs bit-identically."""
    policy = as_precision_policy(precision)
    if not policy.is_default and not spec.supports_precision:
        raise ValueError(
            f"method {spec.name!r} does not support precision policies "
            f"(precision=); methods with precision= support: "
            f"{', '.join(methods_supporting('precision'))}")
    return policy


def _prepare_mesh_options(spec: MethodSpec, options: dict) -> None:
    """Reject declared method options the mesh execution path does not
    honor (``MethodSpec.mesh_options``) -- the single validation table
    replacing the allow-lists the mesh adapters used to hard-code."""
    unsupported = set(options) - spec.mesh_options
    if unsupported:
        supported = (f"; mesh-supported options for {spec.name!r}: "
                     f"{', '.join(sorted(spec.mesh_options))}"
                     if spec.mesh_options else "")
        raise ValueError(
            f"options {sorted(unsupported)} are not supported by the "
            f"mesh-aware {spec.name} path{supported}")


def _prepare_knobs(spec: MethodSpec, *, M, backend, mesh, comm,
                   precision=None, on_mesh: Optional[bool] = None):
    """One-stop validation of the cross-cutting knob group (M= / mesh= /
    backend= / comm= / precision= -- see :data:`_KNOB_TABLE`): runs each
    knob's ``_prepare_*`` helper in table order and returns the
    normalized ``(M, comm, precision)`` triple.  ``on_mesh`` may be
    forced when the mesh path is selected by an operator rather than an
    explicit ``mesh=``."""
    on_mesh = (mesh is not None) if on_mesh is None else on_mesh
    M = _prepare_preconditioner(spec, M)
    if on_mesh:
        _prepare_mesh_check(spec, backend)
    comm = _prepare_comm(spec, comm, on_mesh)
    precision = _prepare_precision(spec, precision)
    return M, comm, precision


# --------------------------------------------------------------------------
# the front-end
# --------------------------------------------------------------------------

def solve(
    A,
    b,
    method: str = "plcg",
    *,
    x0=None,
    tol: float = 1e-8,
    maxiter: int = 1000,
    M: Optional[Callable] = None,
    l=1,
    sigma: Optional[Sequence[float]] = None,
    spectrum: Optional[tuple] = None,
    backend: Optional[str] = None,
    mesh=None,
    comm=None,
    restart="auto",
    residual_replacement: Optional[int] = None,
    precision=None,
    **options,
) -> SolveResult:
    """Solve ``A x = b`` (or a stacked batch ``A X[j] = B[j]``).

    Args:
      A: :class:`LinearOperator`, dense square array, or matvec callable;
        with ``mesh=`` also a ``repro.distributed.DistributedOperator``
        (a ``LinearOperator`` with a ``stencil2d`` hint is auto-promoted
        to ``DistPoisson``).
      b: right-hand side ``(n,)``, or ``(nrhs, n)`` for a batched solve;
        on a mesh, the global field ``op.global_shape`` (e.g.
        ``(nx, ny)``) or a stacked batch ``(nrhs, nx, ny)``.
      method: one of :func:`methods` (default the paper's p(l)-CG).
      x0: initial guess, same shape as ``b`` (default zeros).
      tol: relative residual tolerance (``0`` disables early stopping).
      maxiter: solution-update budget.
      M: SPD preconditioner: a structured
        :class:`repro.core.precond.Preconditioner` (``Jacobi`` fuses into
        the Pallas megakernel via its ``inv_diag`` hint; ``BlockJacobi``
        / ``Chebyshev`` / constant-diagonal ``Jacobi`` run shard-local on
        a mesh) or any bare callable applying ``M^{-1} v`` (promoted via
        :func:`repro.core.precond.as_preconditioner`).  ``Identity``
        collapses to the unpreconditioned pipeline.  Methods without the
        ``supports_M`` capability flag reject it up front.
      l: pipeline depth (pipelined methods only), or ``"auto"`` to pick
        it from on-device calibration: the session layer measures one
        local SPMV, one stacked reduction per ``comm=`` mode and the
        per-depth sweep cost, then solves the paper's latency model
        ``t_iter ~ max(glred/l, spmv)`` for the fastest depth whose
        storage-precision residual-gap floor still reaches ``tol`` (see
        ``repro.core.autotune``; the decision and the measured
        latencies are reported in ``SolveResult.info["auto"]``).
        Passing a manual int pins the depth and bypasses calibration.
      sigma: l auxiliary-basis shifts; default Chebyshev roots on
        ``spectrum`` (itself defaulting to the Poisson interval (0, 8)).
      backend: kernel tier for the scan engine
        ("fused" | "pallas" | "ref" | "auto" | None), ignored by
        reference methods and by the distributed injected-dot path.
      mesh: a 2-axis ``jax.sharding.Mesh`` -- dispatches the method onto
        the mesh execution layer: domain decomposition inside
        (``shard_map`` + halo ``ppermute``), RHS batching outside
        (``vmap``), ONE fused psum per iteration carrying all lanes'
        ``(nrhs, 2l+1)`` payloads (``cg`` is the two-psum baseline).
        Methods without the ``supports_mesh`` registry capability raise;
        shard-local preconditioning composes (``M=BlockJacobi(...)``,
        ``Jacobi`` with a constant diagonal, ``Chebyshev``) and keeps the
        one-psum contract.
      comm: communication policy for the mesh reduction -- ``"blocking"``
        (default, one fused psum per iteration), ``"overlap"`` (split
        psum_scatter + delayed all_gather carried in the scan-state
        queue; genuinely in flight across d iterations of local
        compute), ``"ring"`` (circulate-accumulate ppermute hops staged
        across iterations; needs ``l >= hops + 1``), or a
        :class:`repro.core.comm.CommPolicy` (e.g. with an explicit
        overlap ``depth``).  ``"auto"`` picks the policy from measured
        reduction latencies on the live mesh (``repro.core.autotune``;
        off-mesh it degrades to blocking, the only schedule there).
        Methods without the ``supports_comm`` capability, and non-mesh
        calls, reject non-blocking policies up front.  See the
        ``M=``/``mesh=``/``backend=``/``comm=`` knob table in this
        module (``_KNOB_TABLE``).
      restart: in-scan breakdown recovery -- ``"auto" | int | None``.
        An int caps how many times each lane may re-seed its Krylov
        window from the current iterate after a square-root breakdown,
        *inside* the compiled sweep (per lane under batched ``vmap``,
        per shard group on a mesh, zero host round-trips; shifts are
        Ritz-refreshed from the committed tridiagonal).  ``None``
        disables in-scan recovery (legacy behavior; single-RHS solves
        may still use the deprecated host loop via ``max_restarts``).
        ``"auto"`` (default) enables cap 5 when ``residual_replacement``
        is set and resolves to ``None`` otherwise (see
        ``_prepare_restart``).  Methods without the ``supports_restart``
        capability reject explicit values up front.
      residual_replacement: period (committed updates) of the in-scan
        true-residual recompute ``r = b - A x`` countering the residual
        drift of deep pipelines (paper Sec. 4; arXiv:1706.05988), or
        ``None`` (default, off).  Compatible with every ``comm=`` policy
        (the replacement rides the existing per-iteration reduction,
        widened by one slot).
      precision: storage/compute precision policy for the scan engine --
        ``None`` (default: windows and scalars both in ``b.dtype``,
        bit-identical to the pre-policy engine), a storage dtype name
        (``"bf16"`` stores the ``Vw``/``Zw``/``Zhw`` window arrays and
        the SPMV stream in bfloat16 while every scalar recurrence, dot
        payload, collective buffer and convergence test stays in
        ``promote_types(b.dtype, float32)``), an explicit compound like
        ``"bf16x64"`` pinning the compute side, or a
        :class:`repro.core.precision.PrecisionPolicy`.  Methods without
        the ``supports_precision`` capability reject non-default
        policies up front.  See ``repro.core.precision`` and
        ``benchmarks/mp_bench.py`` for the measured traffic/accuracy
        ladder.
      **options: method-specific extras (``trace_gaps``, ``record_G``,
        ``max_restarts``, ``exploit_symmetry``, ...); keys outside the
        method's declared option set raise a uniform error naming the
        accepted keys.

    Returns:
      :class:`SolveResult`; for batched input, ``x`` has shape
      ``(nrhs, n)`` (``(nrhs, nx, ny)`` on a mesh), ``resnorms`` is a
      per-RHS list of traces, and ``info["per_rhs_converged"]`` /
      ``info["per_rhs_iters"]`` hold the per-system outcomes.

    This is the one-shot convenience wrapper around the prepared-solver
    session API: it builds a :class:`repro.core.session.Solver` (all
    validation / normalization / defaulting, once) and runs it on ``b``.
    Callers issuing many solves against one operator should hold the
    :class:`Solver` (or a :class:`repro.core.session.SolverPool`)
    themselves and skip the per-call setup entirely.
    """
    from .session import Solver
    # validate options before the keyword passthrough: session-only
    # constructor keywords (n=) must not absorb a same-named unknown
    # option key and dodge the uniform rejection
    _prepare_options(get_method(method), options)
    return Solver(A, method=method, tol=tol, maxiter=maxiter, M=M, l=l,
                  sigma=sigma, spectrum=spectrum, backend=backend,
                  mesh=mesh, comm=comm, restart=restart,
                  residual_replacement=residual_replacement,
                  precision=precision, **options).solve(b, x0=x0)


# --------------------------------------------------------------------------
# batched multi-RHS paths
# --------------------------------------------------------------------------

def _solve_batched(spec: MethodSpec, A: LinearOperator, B, *, x0, tol,
                   maxiter, M, l, sigma, spectrum, backend,
                   restart=None, rr_period=None, precision=None,
                   get_engine=None, **options) -> SolveResult:
    nrhs = B.shape[0]
    if spec.batched == "vmap":
        return _solve_batched_vmap(spec, A, B, x0=x0, tol=tol,
                                   maxiter=maxiter, M=M, l=l, sigma=sigma,
                                   spectrum=spectrum, backend=backend,
                                   restart=restart, rr_period=rr_period,
                                   precision=precision,
                                   get_engine=get_engine, **options)
    outs = [
        spec.fn(A, B[j], None if x0 is None else x0[j], tol=tol,
                maxiter=maxiter, M=M, l=l, sigma=sigma, spectrum=spectrum,
                backend=backend, **options)
        for j in range(nrhs)
    ]
    return SolveResult(
        x=np.stack([np.asarray(r.x) for r in outs]),
        resnorms=[r.resnorms for r in outs],
        iters=max(r.iters for r in outs),
        converged=all(r.converged for r in outs),
        breakdowns=sum(r.breakdowns for r in outs),
        restarts=sum(r.restarts for r in outs),
        replacements=sum(r.replacements for r in outs),
        info={"method": spec.name, "batched": "loop", "nrhs": nrhs,
              "per_rhs_converged": [r.converged for r in outs],
              "per_rhs_iters": [r.iters for r in outs]},
    )


#: Jitted vmap(scan) engines, keyed weakly on the operator/preconditioner
#: callables (see solver_cache; cleared by ``clear_solver_cache``).
_BATCH_CACHE = solver_cache.WeakCallableCache(maxsize=16)


def _batched_engine(method_name: str, matvec, l: int, iters: int, sigma,
                    tol: float, prec, exploit_symmetry: bool, unroll: int,
                    backend, stencil_hw, restart=None, rr_period=None,
                    ritz_refresh: bool = True, k_budget=None,
                    precision=None, bindable: bool = False):
    """Jitted vmap(scan) engine, cached per configuration so repeated
    batched solves with the same operator/settings compile only once.

    Keyed on ``matvec``/``prec`` object identity through weak references:
    pass a long-lived ``LinearOperator`` (rather than a fresh dense array
    each call, which ``as_operator`` wraps in a new closure) to benefit
    from the cache.  Entries of dead closures are evicted eagerly, so the
    cache no longer pins operators the caller has dropped.

    ``bindable=True`` interprets ``matvec`` as ``matvec_ctx(context, v)``
    and the returned engine takes ``(context, B, X0)``: the context is a
    traced operand shared by every lane (``in_axes=(None, 0, 0)``), so
    rebinding operator data between batched solves reuses the compiled
    program."""

    def build():
        mv = solver_cache.weakly_callable(matvec)
        kwargs = dict(
            l=l, iters=iters, sigma=sigma, tol=tol,
            prec=solver_cache.weakly_callable(prec),
            # diag fusion hint of a structured Preconditioner: captured as
            # an array constant (does not pin the preconditioner object)
            prec_diag=getattr(prec, "inv_diag", None),
            exploit_symmetry=exploit_symmetry, unroll=unroll,
            backend=backend, stencil_hw=stencil_hw,
            restart=restart, rr_period=rr_period,
            ritz_refresh=ritz_refresh, k_budget=k_budget,
            precision=precision)

        if bindable:
            def engine_ctx(ctx, bb, xx):
                return _plcg_scan_engine(lambda v: mv(ctx, v), bb, xx,
                                         **kwargs)

            def _batched_ctx(ctx, Bb, Xb):
                if len(BATCH_TRACE_EVENTS) < 4096:
                    BATCH_TRACE_EVENTS.append(
                        (method_name, tuple(Bb.shape), l))
                return jax.vmap(engine_ctx,
                                in_axes=(None, 0, 0))(ctx, Bb, Xb)

            return jax.jit(_batched_ctx)

        engine = functools.partial(_plcg_scan_engine, mv, **kwargs)

        def _batched(Bb, Xb):
            # trace-time side effect: fires once per XLA compilation, so
            # the test suite can assert the batch compiles exactly once
            if len(BATCH_TRACE_EVENTS) < 4096:  # bounded in long processes
                BATCH_TRACE_EVENTS.append((method_name, tuple(Bb.shape), l))
            return jax.vmap(engine)(Bb, Xb)

        return jax.jit(_batched)

    return _BATCH_CACHE.get_or_build(
        (matvec, prec),
        (method_name, l, iters, sigma, tol, exploit_symmetry, unroll,
         backend, stencil_hw, restart, rr_period, ritz_refresh, k_budget,
         as_precision_policy(precision), bindable),
        build)


def _solve_batched_vmap(spec: MethodSpec, A: LinearOperator, B, *, x0, tol,
                        maxiter, M, l, sigma, spectrum, backend,
                        restart=None, rr_period=None, precision=None,
                        exploit_symmetry: bool = True, unroll: int = 1,
                        ritz_refresh: bool = True,
                        get_engine=None, **options) -> SolveResult:
    """One jitted ``vmap`` of the scan engine over the stacked RHS.

    A single XLA compilation covers all ``nrhs`` systems; converged lanes
    freeze via the engine's per-lane commit select while the remaining
    lanes keep iterating.  Runs ONE sweep always: with ``restart=`` /
    ``rr_period=`` (normalized by ``_prepare_restart``) each lane
    re-seeds itself in-trace on breakdown / on the replacement period --
    recovery is per lane, inside the same compiled program, never a
    second sweep.

    ``get_engine`` (internal) lets a prepared :class:`session.Solver`
    inject its strongly-held jitted engine in place of the weak-key cache
    lookup; it receives exactly :func:`_batched_engine`'s arguments.
    """
    if options:
        # don't silently drop flags the single-RHS call would honor
        # (trace_gaps, record_G, max_restarts, ...)
        raise ValueError(
            f"options {sorted(options)} are not supported by the batched "
            "vmap(scan) engine; solve each RHS individually (1-D b) or "
            "use a loop-batched method (cg, pcg, dlanczos, plminres)")
    sig = tuple(_resolve_sigma(sigma, spectrum, l))
    Bj = jnp.asarray(B)
    precision = as_precision_policy(precision)
    # the attainable floor is set by the *compute* dtype of the scalar
    # recurrences and convergence tests, not the storage dtype of b: a
    # bf16-storage policy over an f32 problem still converges on f32
    # scalars, and must not spuriously warn at tolerances those reach
    cdt = precision.compute_dtype(Bj.dtype)
    if tol and tol < 100 * jnp.finfo(cdt).eps:
        import warnings

        # attribute the warning to the caller of solve(), not to a frame
        # inside this module: count the contiguous run of engine frames
        # above us instead of hard-coding the internal call-chain depth
        warnings.warn(
            f"tol={tol:g} is below ~100*eps of the batched engine compute "
            f"dtype {cdt}; lanes will hit maxiter instead of converging -- "
            "enable jax_enable_x64 or relax tol",
            stacklevel=_stacklevel_outside_engine())
    X0 = jnp.zeros_like(Bj) if x0 is None else jnp.asarray(x0)
    from .plcg_scan import stab_iter_slack
    stab = restart is not None or rr_period is not None
    iters = maxiter + l + 1 + stab_iter_slack(l, restart, rr_period, maxiter)
    build = get_engine if get_engine is not None else _batched_engine
    # the stability slack bodies are pipeline re-fill, not extra updates:
    # an explicit k_budget freezes every lane at maxiter committed updates
    # (without stab, iters itself caps the count -- keep the graph as-is)
    bind = is_bindable(A)
    fn = build(spec.name, A.matvec_ctx if bind else A.matvec, l, iters,
               sig, tol, M, exploit_symmetry, unroll, backend,
               getattr(A, "stencil2d", None), restart, rr_period,
               ritz_refresh, maxiter if stab else None, precision, bind)
    out = fn(A.context, Bj, X0) if bind else fn(Bj, X0)
    resn = np.asarray(out.resnorms)                     # (nrhs, iters)
    conv = np.asarray(out.converged)
    brk = np.asarray(out.breakdown)
    k_done = np.asarray(out.k_done)
    if stab:
        # restart / replacement dead bodies interleave with committed
        # updates, so the in-order residual history is the committed mask
        # (not a contiguous count slice)
        committed = np.asarray(out.committed, dtype=bool)
        resnorms = [[float(r) for r in row[m]]
                    for row, m in zip(resn, committed)]
        restarts_pl = np.asarray(out.restarts)
        repl_pl = np.asarray(out.replacements)
    else:
        # lane j commits |zeta_k| for k = 0..k_done[j] at trace indices
        # l..l+k_done[j]; slicing by count (not value-filtering) keeps a
        # legitimate exact-zero residual in the trace
        resnorms = [[float(r) for r in row[l: l + int(k) + 1]]
                    for row, k in zip(resn, k_done)]
        restarts_pl = np.zeros(Bj.shape[0], dtype=int)
        repl_pl = np.zeros(Bj.shape[0], dtype=int)
    return SolveResult(
        x=out.x,
        resnorms=resnorms,
        iters=int(k_done.max()) + 1,
        converged=bool(conv.all()),
        breakdowns=int(brk.sum()) + int(restarts_pl.sum()),
        restarts=int(restarts_pl.sum()),
        replacements=int(repl_pl.sum()),
        info={"method": f"p({l})-CG[scan,vmap]", "l": l,
              "sigma": list(sig), "backend": backend, "batched": "vmap",
              "prec": getattr(M, "name", None) if M is not None else None,
              "nrhs": int(Bj.shape[0]),
              "restart": restart, "residual_replacement": rr_period,
              "precision": None if precision.is_default else precision,
              "per_rhs_converged": conv,
              "per_rhs_iters": k_done + 1,
              "per_rhs_breakdown": brk,
              "per_rhs_restarts": restarts_pl,
              "per_rhs_replacements": repl_pl},
    )


# --------------------------------------------------------------------------
# registered method adapters
# --------------------------------------------------------------------------

@register("cg", supports_mesh=True, options=("trace_true_residual",),
          description="classic Hestenes-Stiefel CG (paper Alg. 4)")
def _method_cg(A, b, x0=None, *, tol=1e-8, maxiter=1000, M=None, l=1,
               sigma=None, spectrum=None, backend=None, **kw):
    return classic_cg(A, b, x0, tol=tol, maxiter=maxiter, M=M, **kw)


@register("pcg", options=("trace_true_residual",),
          description="Ghysels-Vanroose pipelined CG, depth 1 (Alg. 5)")
def _method_pcg(A, b, x0=None, *, tol=1e-8, maxiter=1000, M=None, l=1,
                sigma=None, spectrum=None, backend=None, **kw):
    return ghysels_pcg(A, b, x0, tol=tol, maxiter=maxiter, M=M, **kw)


@register("dlanczos",
          description="direct Lanczos, exact-arithmetic oracle (Remark 7)")
def _method_dlanczos(A, b, x0=None, *, tol=1e-8, maxiter=1000, M=None, l=1,
                     sigma=None, spectrum=None, backend=None, **kw):
    return d_lanczos(A, b, x0, tol=tol, maxiter=maxiter, M=M, **kw)


@register("plcg", batched="vmap", supports_mesh=True, supports_comm=True,
          uses_sigma=True,
          options=("exploit_symmetry", "record_G", "trace_gaps", "prune",
                   "max_restarts"),
          mesh_options=("exploit_symmetry", "max_restarts"),
          description="deep-pipelined p(l)-CG reference (paper Alg. 2)")
def _method_plcg(A, b, x0=None, *, tol=1e-8, maxiter=1000, M=None, l=1,
                 sigma=None, spectrum=None, backend=None, **kw):
    return plcg(A, b, x0, l=l, tol=tol, maxiter=maxiter, M=M, sigma=sigma,
                spectrum=spectrum, **kw)


def _run_plcg_scan(A, b, x0, *, tol, maxiter, M, l, sigma, spectrum,
                   backend, sweep=None, restart=None,
                   residual_replacement=None, precision=None,
                   **kw) -> SolveResult:
    """Scan-engine single-RHS run + SolveResult packaging.

    Shared by the one-shot adapter below and the prepared session path:
    ``sweep`` (internal) is a pre-built jitted ``(b, x0, k_budget)``
    sweep a :class:`session.Solver` holds strongly -- when given,
    ``plcg_solve`` skips its weak-key cache lookup entirely.
    ``restart``/``residual_replacement`` arrive normalized (see
    ``_prepare_restart``); either being set selects the in-scan
    stability path of ``plcg_solve``.
    """
    sig = _resolve_sigma(sigma, spectrum, l)
    pp = as_precision_policy(precision)
    bj = jnp.asarray(b)
    x0j = None if x0 is None else jnp.asarray(x0)
    bind = is_bindable(A)
    x, resnorms, info = plcg_solve(A.matvec_ctx if bind else A.matvec,
                                   bj, x0j, l=l, sigma=sig,
                                   tol=tol, maxiter=maxiter, prec=M,
                                   backend=backend,
                                   stencil_hw=getattr(A, "stencil2d", None),
                                   sweep=sweep, restart=restart,
                                   residual_replacement=residual_replacement,
                                   precision=precision,
                                   context=A.context if bind else None,
                                   **kw)
    return SolveResult(
        x=x, resnorms=resnorms, iters=info["iterations"],
        converged=info["converged"], breakdowns=info["breakdowns"],
        restarts=info["restarts"],
        replacements=info.get("replacements", 0),
        info={"method": f"p({l})-CG[scan]", "l": l, "sigma": sig,
              "backend": backend,
              "restart": restart,
              "residual_replacement": residual_replacement,
              "precision": (None if pp.is_default else pp),
              "prec": getattr(M, "name", None) if M is not None else None},
    )


@register("plcg_scan", batched="vmap", supports_mesh=True,
          supports_comm=True, supports_restart=True,
          supports_precision=True, uses_sigma=True,
          options=("exploit_symmetry", "max_restarts", "unroll",
                   "ritz_refresh"),
          mesh_options=("exploit_symmetry", "max_restarts", "ritz_refresh"),
          description="jitted lax.scan p(l)-CG production engine (Alg. 3)")
def _method_plcg_scan(A, b, x0=None, *, tol=1e-8, maxiter=1000, M=None, l=1,
                      sigma=None, spectrum=None, backend=None, **kw):
    return _run_plcg_scan(A, b, x0, tol=tol, maxiter=maxiter, M=M, l=l,
                          sigma=sigma, spectrum=spectrum, backend=backend,
                          **kw)


@register("plminres", supports_M=False, uses_sigma=True,
          description="deep-pipelined MINRES (Remark 6; indefinite OK)")
def _method_plminres(A, b, x0=None, *, tol=1e-8, maxiter=1000, M=None, l=1,
                     sigma=None, spectrum=None, backend=None, **kw):
    # solve() enforces supports_M up front with the uniform message;
    # this guard covers direct registry invocation (get_method().fn) so
    # a passed M is never silently dropped
    if as_preconditioner(M).runtime() is not None:
        raise ValueError(
            "plminres does not support preconditioning (M=); see "
            "repro.core.methods_supporting('M')")
    r = plminres(A, b, x0, l=l, m=min(maxiter, A.n), sigma=sigma,
                 spectrum=spectrum, **kw)
    # plgmres runs a fixed m iterations; grade convergence on the true
    # residual with the same convention as the other methods (relative to
    # ||b||, and tol=0 means "never early-converged")
    x = np.asarray(r.x)
    bn = float(np.linalg.norm(np.asarray(b)))
    res = float(np.linalg.norm(np.asarray(b) - np.asarray(A @ x)))
    r.converged = bool(res <= tol * (bn if bn > 0 else 1.0))
    r.info["true_resnorm"] = res
    return r
