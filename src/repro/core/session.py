"""Prepared-solver sessions: the serving API of the unified engine.

The paper hides the latency of the global reduction behind the next l
SPMVs; this module hides the latency of the *front end* behind session
state.  ``repro.core.solve`` pays validation, preconditioner
normalization, sigma defaulting, operator promotion and the weak-key
sweep-cache lookup on EVERY call -- negligible for one large solve,
dominant for the many-concurrent-small-solves serving workload
(ROADMAP "Serving layer").  The session API splits the lifecycle in two:

  * :class:`Solver` -- ``solver = Solver(A, method="plcg_scan", l=2,
    M=..., mesh=...)`` performs every per-problem step exactly ONCE and
    holds the resulting jitted sweeps **strongly** (the weak-key caches
    of ``solver_cache`` still deduplicate against the one-shot path, but
    a live session survives ``clear_solver_cache()`` and cache
    eviction).  ``solver(b)`` / ``solver.solve(b, x0=..., tol=...)``
    then run with zero Python-side re-setup: after the first call of a
    given RHS shape there are ZERO retraces (see
    :meth:`Solver.compile_counts`).
  * :meth:`Solver.submit` / :class:`SolverPool` -- micro-batched
    dispatch: ``submit(b)`` queues a right-hand side and returns a
    :class:`SolveHandle`; a flush packs the pending queue into one
    padded ``(nrhs, n)`` (or ``(nrhs, nx, ny)`` mesh) batch and runs it
    through the existing batched engines -- ``jit(vmap(scan))`` on a
    single device, ``jit(shard_map(vmap(scan)))`` on a mesh -- so every
    per-iteration reduction of the flush carries ALL queued systems
    (the strong-scaling multi-solve workload of arXiv:1905.06850).
    Per-RHS convergence masking already lives in the engines, so one
    compiled batched sweep serves every queue depth; pad bucketing
    (powers of two up to ``max_batch`` by default) keeps the number of
    distinct compilations at a handful.

Padding duplicates lane 0 (never zeros: a zero RHS would inject NaNs
through the ``v0 = r0/||r0||`` normalization; lanes are independent
under vmap, so a duplicated lane is merely discarded on extraction).

Restart-on-breakdown is an in-scan affair (``restart=`` /
``residual_replacement=``, normalized once by the engine's
``_prepare_restart``): a session constructed with the stability knobs
bakes them into every sweep it prepares, so pooled lanes re-seed
themselves *inside* the one masked sweep per flush -- per lane, zero
host round-trips, no second sweep.  The legacy host restart loop
(``max_restarts``) remains a deprecated single-RHS escape hatch, and
``record_G``-style introspection knobs still do not apply to pooled
lanes.

Attainable accuracy stays reportable per lane via
``repro.core.residual_gap(A, b_j, result)`` on the per-handle results
(arXiv:1804.02962).
"""
from __future__ import annotations

from typing import Any, Optional

import numpy as np

from . import engine
from .linop import LinearOperator, is_bindable
from .results import SolveResult

Array = Any

__all__ = ["SolveHandle", "Solver", "SolverPool"]


class SolveHandle:
    """Future-like handle for one submitted right-hand side.

    ``done`` is True once a flush has produced this request's result;
    ``result()`` drains the owning queue on demand (so a bare
    ``solver.submit(b).result()`` is a correct, if unbatched, call).
    """

    __slots__ = ("_owner", "_result")

    def __init__(self, owner):
        self._owner = owner
        self._result: Optional[SolveResult] = None

    @property
    def done(self) -> bool:
        return self._result is not None

    def result(self) -> SolveResult:
        if self._result is None:
            self._owner.flush()
        if self._result is None:    # defensive: flush must have set it
            raise RuntimeError("flush did not produce a result for this "
                               "handle (was the queue cleared externally?)")
        return self._result

    def _set(self, result: SolveResult) -> None:
        self._result = result


def _lane_result(rb: SolveResult, j: int, *, flush_nrhs: int,
                 flush_pad: int) -> SolveResult:
    """Extract lane ``j`` of a batched SolveResult as a single-RHS
    SolveResult (the per-handle contract of pooled dispatch)."""
    info = rb.info
    x = np.asarray(rb.x)[j]
    conv = info.get("per_rhs_converged")
    iters = info.get("per_rhs_iters")
    brk = info.get("per_rhs_breakdown")
    rst = info.get("per_rhs_restarts")
    repl = info.get("per_rhs_replacements")
    n_rst = int(np.asarray(rst)[j]) if rst is not None else 0
    return SolveResult(
        x=x,
        resnorms=list(rb.resnorms[j]),
        iters=int(np.asarray(iters)[j]) if iters is not None else rb.iters,
        converged=(bool(np.asarray(conv)[j]) if conv is not None
                   else rb.converged),
        breakdowns=(int(np.asarray(brk)[j]) + n_rst if brk is not None
                    else 0),
        restarts=n_rst,
        replacements=(int(np.asarray(repl)[j]) if repl is not None else 0),
        info={"method": info.get("method"), "l": info.get("l"),
              "prec": info.get("prec"), "batched": info.get("batched"),
              "pooled": True, "lane": j,
              "flush_nrhs": flush_nrhs, "flush_pad": flush_pad},
    )


def _default_buckets(max_batch: int) -> tuple:
    """Powers of two up to (and always including) ``max_batch``."""
    buckets = []
    p = 1
    while p < max_batch:
        buckets.append(p)
        p *= 2
    buckets.append(max_batch)
    return tuple(buckets)


class Solver:
    """A prepared solver session: compile once, solve many.

    Construction runs the ``_prepare_*`` pipeline of the engine exactly
    once -- method lookup, option validation against the method's
    declared set, ``as_preconditioner(...).runtime()`` normalization,
    shift-interval defaulting from ``M.precond_spectrum`` and operator
    promotion (``as_operator`` / ``as_dist_operator``); each jitted
    sweep is then built exactly once, at its first use, and held
    strongly in ``self._prepared``.  All constructor keywords have the same meaning
    as in :func:`repro.core.solve`; ``tol``/``maxiter`` become session
    defaults that individual :meth:`solve` calls may override (an
    override keys a new prepared sweep, strongly held like the first).

    ``n=`` gives the problem dimension when ``A`` is a bare matvec
    callable (the one-shot ``solve()`` infers it from ``b``; a session
    has no ``b`` yet).  Promotion is deferred to the first call when
    neither is available.

    Threading: sessions are not thread-safe; serve one queue per
    thread or lock externally.
    """

    def __init__(self, A, method: str = "plcg_scan", *, tol: float = 1e-8,
                 maxiter: int = 1000, M=None, l=1, sigma=None,
                 spectrum=None, backend: Optional[str] = None, mesh=None,
                 comm=None, restart="auto",
                 residual_replacement: Optional[int] = None,
                 precision=None,
                 n: Optional[int] = None, **options):
        spec = engine._prepare_method(method)
        engine._prepare_options(spec, options)
        on_mesh = mesh is not None or engine._is_mesh_operator(A)
        # the cross-cutting knob group (M=/mesh=/backend=/comm=/restart=/
        # residual_replacement=/precision=) is validated and normalized
        # ONCE here, through the engine's single knob table -- no layer
        # below re-validates per call
        M, comm, precision = engine._prepare_knobs(
            spec, M=M, backend=backend, mesh=mesh, comm=comm,
            precision=precision, on_mesh=on_mesh)
        l = engine._prepare_depth(spec, l)
        restart, residual_replacement = engine._prepare_restart(
            spec, restart, residual_replacement, options)
        spectrum = engine._prepare_spectrum(spec, M, sigma, spectrum)
        self.method = method
        self.spec = spec
        self.M = M
        self.tol = tol
        self.maxiter = maxiter
        self.l = l
        self.sigma = sigma
        self.spectrum = spectrum
        self.backend = backend
        self.comm = comm
        self.restart = restart
        self.residual_replacement = residual_replacement
        self.precision = precision
        self.auto = None            # AutoDecision once l/comm calibrated
        self.options = dict(options)
        self._pending: list = []
        self._prepared: dict = {}       # strong refs: config -> jitted fn
        self.stats = {"calls": 0, "prepared_builds": 0, "flushes": 0,
                      "flushed_rhs": 0, "padded_lanes": 0}

        self._mesh_session = None
        if on_mesh:
            # lazy import: keeps the core engine importable where the
            # distributed layer (shard_map et al.) is unavailable
            from ..distributed.plcg_dist import prepare_on_mesh
            self._mesh_session = prepare_on_mesh(
                spec, A, mesh, M=M, l=l, sigma=sigma, spectrum=spectrum,
                comm=comm, restart=restart,
                residual_replacement=residual_replacement,
                precision=precision, tol=tol, **options)
            self._op = self._mesh_session.op
            # auto sentinels resolve at mesh-session construction, where
            # the operator and its mesh are known; mirror the concrete
            # choice so session attributes always read as resolved
            self.l = self._mesh_session.l
            self.comm = self._mesh_session.comm
            self.auto = self._mesh_session.auto
            return

        # single-device operator promotion (deferred only for a bare
        # matvec callable with no dimension hint).  Bindable operators
        # must be caught before the bare-callable branch: they define
        # __call__, and wrapping one in a LinearOperator would bake its
        # context into the compiled sweeps as trace-time constants.
        if is_bindable(A):
            self._op = A
        elif isinstance(A, LinearOperator) or getattr(A, "ndim", None) == 2:
            self._op = engine.as_operator(A)
        elif callable(A) and n is not None:
            self._op = LinearOperator(matvec=A, n=int(n), name="matvec")
        elif callable(A):
            self._op = None
            self._A_raw = A
        else:
            raise TypeError(f"cannot interpret {type(A).__name__} as a "
                            "linear operator")
        if self.l == "auto":
            # calibration needs an operator to probe NOW (a prepared
            # session measures once, at construction -- never per call)
            if self._op is None:
                raise ValueError(
                    "l='auto' calibrates against the operator at session "
                    "construction, but a bare matvec callable has no "
                    "dimension yet; pass n= (or pin an integer l)")
            from .autotune import resolve_auto
            self.auto = resolve_auto(self._op, l="auto", comm=self.comm,
                                     tol=tol, precision=precision,
                                     backend=backend)
            self.l = self.auto.l
        # sweep building is lazy-once: the first call of each entry
        # point (single-RHS / batched / tol override) builds its jitted
        # sweep through the memoizing getters and holds it forever --
        # eager wrapping at construction would charge the one-shot
        # solve() path for engines it never runs (XLA compiles at the
        # first real call either way)

    # ---- prepared-sweep plumbing ----------------------------------------

    def _ensure_op(self, b) -> LinearOperator:
        if self._op is None:
            self._op = engine.as_operator(self._A_raw, b)
        return self._op

    def _single_sweep(self, tol: float, maxiter: int):
        """The strongly-held jitted single-RHS scan sweep for one
        (tol, maxiter) configuration (plcg_scan only)."""
        key = ("sweep", float(tol), int(maxiter))
        if key not in self._prepared:
            from .plcg_scan import _jitted_sweep, stab_iter_slack
            sig = tuple(engine._resolve_sigma(self.sigma, self.spectrum,
                                              self.l))
            iters = maxiter + self.l + 1 + stab_iter_slack(
                self.l, self.restart, self.residual_replacement, maxiter)
            bind = is_bindable(self._op)
            self._prepared[key] = _jitted_sweep(
                self._op.matvec_ctx if bind else self._op.matvec,
                self.l, iters, sig, tol,
                self.M, self.options.get("exploit_symmetry", True),
                self.options.get("unroll", 1), self.backend,
                getattr(self._op, "stencil2d", None),
                restart=self.restart,
                rr_period=self.residual_replacement,
                ritz_refresh=self.options.get("ritz_refresh", True),
                precision=self.precision, bindable=bind)
            self.stats["prepared_builds"] += 1
        return self._prepared[key]

    def _batched_engine_getter(self):
        """``get_engine`` hook for the engine's batched path: same
        arguments as ``engine._batched_engine``, memoized strongly here
        (the session holds the operator and preconditioner anyway, so
        the config key pins nothing extra)."""

        def get(*args):
            key = ("batched",) + args
            if key not in self._prepared:
                self._prepared[key] = engine._batched_engine(*args)
                self.stats["prepared_builds"] += 1
            return self._prepared[key]

        return get

    @property
    def prepared_sweeps(self) -> int:
        """Number of jitted sweeps this session holds strongly (single-
        device and mesh)."""
        n = len(self._prepared)
        if self._mesh_session is not None:
            n += self._mesh_session.builds
        return n

    def compile_counts(self) -> dict:
        """Per-prepared-sweep XLA compilation counts (jit cache sizes).

        After the first call of a given RHS shape, repeated calls must
        not grow any entry -- the "zero retraces" serving gate asserted
        by the tests and recorded by ``benchmarks/serve_bench.py``."""
        from ..kernels.introspect import jit_cache_size
        counts = {}
        for key, fn in self._prepared.items():
            counts[key] = jit_cache_size(fn)
        if self._mesh_session is not None:
            for key, fn in self._mesh_session._sweeps.items():
                counts[("mesh",) + key] = jit_cache_size(fn)
        return counts

    # ---- solving ---------------------------------------------------------

    def solve(self, b, x0=None, *, tol: Optional[float] = None,
              maxiter: Optional[int] = None) -> SolveResult:
        """Solve ``A x = b`` with the prepared session (same result
        contract as :func:`repro.core.solve`, including stacked batches).
        ``tol``/``maxiter`` default to the session values; an override
        prepares (and strongly holds) an additional sweep."""
        tol = self.tol if tol is None else tol
        maxiter = self.maxiter if maxiter is None else maxiter
        self.stats["calls"] += 1
        if self._mesh_session is not None:
            r = self._mesh_session.solve(b, x0, tol=tol, maxiter=maxiter)
        else:
            op = self._ensure_op(b)
            spec = self.spec
            if getattr(b, "ndim", 1) == 2:
                r = engine._solve_batched(
                    spec, op, b, x0=x0, tol=tol, maxiter=maxiter, M=self.M,
                    l=self.l, sigma=self.sigma, spectrum=self.spectrum,
                    backend=self.backend, restart=self.restart,
                    rr_period=self.residual_replacement,
                    precision=self.precision,
                    get_engine=(self._batched_engine_getter()
                                if spec.batched == "vmap" else None),
                    **self.options)
            elif spec.name == "plcg_scan":
                sweep = self._single_sweep(tol, maxiter)
                if is_bindable(op):
                    # bind the CURRENT context at call time: the raw
                    # prepared sweep (kept in _prepared for the
                    # compile_counts gate) takes it as a traced operand
                    raw, ctx = sweep, op.context
                    sweep = lambda bb, xx, kb: raw(ctx, bb, xx, kb)  # noqa: E731
                r = engine._run_plcg_scan(
                    op, b, x0, tol=tol, maxiter=maxiter, M=self.M, l=self.l,
                    sigma=self.sigma, spectrum=self.spectrum,
                    backend=self.backend,
                    sweep=sweep,
                    restart=self.restart,
                    residual_replacement=self.residual_replacement,
                    precision=self.precision,
                    **self.options)
            else:
                r = spec.fn(op, b, x0, tol=tol, maxiter=maxiter, M=self.M,
                            l=self.l, sigma=self.sigma,
                            spectrum=self.spectrum,
                            backend=self.backend, **self.options)
        if self.auto is not None:
            r.info["auto"] = self.auto.as_info()
        return r

    __call__ = solve

    # ---- micro-batched dispatch -----------------------------------------

    def submit(self, b, x0=None, *, _owner=None) -> SolveHandle:
        """Queue one right-hand side; returns a :class:`SolveHandle`.

        Nothing runs until a flush -- triggered explicitly
        (:meth:`flush` / ``SolverPool.flush``) or implicitly by
        ``handle.result()``."""
        handle = SolveHandle(_owner if _owner is not None else self)
        self._pending.append((b, x0, handle))
        return handle

    @property
    def pending(self) -> int:
        return len(self._pending)

    def flush(self, *, max_batch: Optional[int] = None,
              buckets: Optional[tuple] = None) -> list:
        """Drain the queue: pack pending RHS into batched sweep calls.

        Chunks of at most ``max_batch`` (default: everything in one) are
        padded up to the smallest bucket >= the chunk size (default: no
        padding) by duplicating lane 0, solved through the batched
        engine, and unpacked into the per-handle results.  Returns a
        list of ``(real, padded)`` flush records.
        """
        records = []
        while self._pending:
            take = len(self._pending) if max_batch is None \
                else min(max_batch, len(self._pending))
            chunk, self._pending = (self._pending[:take],
                                    self._pending[take:])
            try:
                records.append(self._flush_chunk(chunk, buckets))
            except BaseException:
                # leave the failed chunk's UNRESOLVED requests queued
                # (their handles must stay resolvable once the caller
                # fixes the problem -- e.g. mixed shapes flushed per
                # shape); requests the chunk already resolved before the
                # failure must not be re-solved
                self._pending = ([p for p in chunk if not p[2].done]
                                 + self._pending)
                raise
        return records

    def _flush_chunk(self, chunk: list, buckets: Optional[tuple]) -> tuple:
        import jax.numpy as jnp
        k = len(chunk)
        pad = k
        if buckets:
            for size in sorted(buckets):
                if size >= k:
                    pad = size
                    break
        can_batch = (self.spec.batched == "vmap"
                     or self._mesh_session is not None)
        if not can_batch:
            # loop methods: per-RHS dispatch (restart semantics of the
            # plain solve apply -- there is no batched sweep to share)
            for b, x0, handle in chunk:
                handle._set(self.solve(b, x0))
            self.stats["flushes"] += 1
            self.stats["flushed_rhs"] += k
            return (k, k)
        # batchable methods ALWAYS take the batched sweep, even for a
        # lone request: pooled lanes must have one contract (masked
        # single sweep, no data-dependent restarts) regardless of how
        # many requests happened to be co-queued
        bs = [jnp.asarray(b) for b, _, _ in chunk]
        shape = bs[0].shape
        if any(b.shape != shape for b in bs):
            raise ValueError(
                f"cannot micro-batch mixed RHS shapes "
                f"{sorted({tuple(b.shape) for b in bs})}; flush per shape")
        bs += [bs[0]] * (pad - k)               # pad lanes: duplicate lane 0
        B = jnp.stack(bs)
        X0 = None
        if any(x0 is not None for _, x0, _ in chunk):
            X0 = jnp.stack([jnp.zeros_like(bs[0]) if x0 is None
                            else jnp.asarray(x0)
                            for _, x0, _ in chunk]
                           + [jnp.zeros_like(bs[0])] * (pad - k))
        rb = self._solve_batched_for_pool(B, X0)
        for j, (_, _, handle) in enumerate(chunk):
            handle._set(_lane_result(rb, j, flush_nrhs=k, flush_pad=pad))
        self.stats["flushes"] += 1
        self.stats["flushed_rhs"] += k
        self.stats["padded_lanes"] += pad - k
        return (k, pad)

    def _solve_batched_for_pool(self, B, X0) -> SolveResult:
        """Batched solve for pooled dispatch: legacy host-driver knobs
        (``max_restarts``, ``record_G``-style introspection) are stripped
        -- the batched engines would reject them loudly -- but the
        normalized in-scan stability knobs (``restart=`` /
        ``residual_replacement=``) thread through, so each pooled lane
        re-seeds itself independently inside the one masked sweep per
        flush."""
        self.stats["calls"] += 1
        if self._mesh_session is not None:
            opts = {key: v for key, v in self.options.items()
                    if key in ("exploit_symmetry", "ritz_refresh")}
            sess = self._mesh_session
            if sess.spec.name == "cg":
                from ..distributed.plcg_dist import _mesh_cg
                return _mesh_cg(sess.op, B, X0, tol=self.tol,
                                maxiter=self.maxiter, prec=sess.prec,
                                get_sweep=sess._get_sweep("cg", self.tol))
            from ..distributed.plcg_dist import _mesh_plcg
            return _mesh_plcg(sess.op, B, X0, tol=self.tol,
                              maxiter=self.maxiter, l=sess.l,
                              sigma=sess.sig, prec=sess.prec,
                              comm=sess.comm, restart=sess.restart,
                              residual_replacement=sess.residual_replacement,
                              precision=sess.precision,
                              get_sweep=sess._get_sweep("plcg", self.tol),
                              **opts)
        op = self._ensure_op(B[0])
        opts = {key: v for key, v in self.options.items()
                if key in ("exploit_symmetry", "unroll", "ritz_refresh")}
        return engine._solve_batched(
            self.spec, op, B, x0=X0, tol=self.tol, maxiter=self.maxiter,
            M=self.M, l=self.l, sigma=self.sigma, spectrum=self.spectrum,
            backend=self.backend, restart=self.restart,
            rr_period=self.residual_replacement,
            precision=self.precision,
            get_engine=(self._batched_engine_getter()
                        if self.spec.batched == "vmap" else None),
            **opts)


class SolverPool:
    """Micro-batching policy over a :class:`Solver`: bounded flush size
    and pad bucketing, plus occupancy accounting.

    ``max_batch`` caps the lanes of one batched sweep call; ``pad_to``
    is the ascending bucket ladder a chunk is padded up to (default:
    powers of two up to ``max_batch``), so at most ``len(pad_to)``
    distinct batch shapes -- and therefore compilations -- ever exist
    per RHS shape.  ``submit`` delegates to the solver's queue;
    ``flush`` drains it under this policy and records occupancy
    (real lanes / padded lanes, the utilization of every flush's fused
    reductions).
    """

    def __init__(self, solver: Solver, *, max_batch: int = 8,
                 pad_to: Optional[tuple] = None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.solver = solver
        self.max_batch = int(max_batch)
        self.buckets = (tuple(sorted(int(p) for p in pad_to)) if pad_to
                        else _default_buckets(self.max_batch))
        if self.buckets[-1] < self.max_batch:
            raise ValueError(
                f"largest pad bucket {self.buckets[-1]} is below "
                f"max_batch={self.max_batch}; a full chunk could not be "
                "padded to any bucket")
        self.stats = {"requests": 0, "flushes": 0, "batches": 0,
                      "lanes_real": 0, "lanes_padded": 0}

    def submit(self, b, x0=None) -> SolveHandle:
        self.stats["requests"] += 1
        return self.solver.submit(b, x0, _owner=self)

    @property
    def pending(self) -> int:
        return self.solver.pending

    def flush(self) -> list:
        """Drain the solver's queue in batches of <= ``max_batch``,
        padded to the bucket ladder.  Returns the flush records."""
        records = self.solver.flush(max_batch=self.max_batch,
                                    buckets=self.buckets)
        self.stats["flushes"] += 1
        self.stats["batches"] += len(records)
        for real, padded in records:
            self.stats["lanes_real"] += real
            self.stats["lanes_padded"] += padded
        return records

    @property
    def occupancy(self) -> float:
        """Mean fraction of real (non-pad) lanes across flushed batches
        (1.0 = every fused reduction fully utilized)."""
        if not self.stats["lanes_padded"]:
            return 1.0
        return self.stats["lanes_real"] / self.stats["lanes_padded"]
