"""Pipelined CG of Ghysels & Vanroose 2014 -- paper Alg. 5 (+ preconditioning).

Pipeline length one: a *single* global reduction per iteration (the fused
(gamma_i, delta_i) pair) overlapped with one SPMV + preconditioner apply.
Included both as the closest-related prior method (paper Remark 10 stresses
p-CG and p(l)-CG are *different* algorithms) and as the l=1 comparison point
in every accuracy/performance experiment.
"""
from __future__ import annotations

from typing import Optional

from .linop import LinearOperator
from .precond import Preconditioner
from .results import SolveResult


def _dot(a, b):
    return (a * b).sum()


def ghysels_pcg(
    A: LinearOperator,
    b,
    x0=None,
    *,
    tol: float = 1e-8,
    maxiter: int = 1000,
    M: Optional[Preconditioner] = None,
    trace_true_residual: bool = False,
) -> SolveResult:
    """Ghysels-Vanroose pipelined CG.

    Unpreconditioned recurrences (Alg. 5): auxiliary vectors
    w = A r, z = A s, s = A p; one fused reduction for (gamma, delta).
    Preconditioned version introduces u = M^{-1} r, q = M^{-1} s,
    following Ghysels & Vanroose (2014), Alg. 5 therein.
    """
    x = b * 0 if x0 is None else x0
    r = b - A @ x
    u = M(r) if M is not None else r
    w = A @ u
    bnorm = float(_dot(b, b)) ** 0.5
    resnorms = [float(_dot(r, r)) ** 0.5]
    true_resnorms = [resnorms[0]] if trace_true_residual else None
    converged = resnorms[-1] <= tol * bnorm
    it = 0
    alpha_prev = None
    gamma_prev = None
    z = s = p = q = None
    while not converged and it < maxiter:
        # --- one fused global reduction (overlapped with the SPMV below) ---
        gamma = float(_dot(r, u))
        delta = float(_dot(w, u))
        # --- SPMV (+ preconditioner) that hides the reduction latency ------
        m_vec = M(w) if M is not None else w
        n_vec = A @ m_vec
        # --- scalar updates ------------------------------------------------
        if it > 0:
            beta = gamma / gamma_prev
            alpha = 1.0 / (delta / gamma - beta / alpha_prev)
        else:
            beta = 0.0
            alpha = gamma / delta
        # --- AXPY recurrences ----------------------------------------------
        z = n_vec + beta * z if it > 0 else n_vec
        q = m_vec + beta * q if it > 0 else m_vec
        s = w + beta * s if it > 0 else w
        p = u + beta * p if it > 0 else u
        x = x + alpha * p
        r = r - alpha * s
        u = u - alpha * q
        w = w - alpha * z
        gamma_prev, alpha_prev = gamma, alpha
        it += 1
        resnorms.append(float(_dot(r, r)) ** 0.5)
        if trace_true_residual:
            tr = b - A @ x
            true_resnorms.append(float(_dot(tr, tr)) ** 0.5)
        converged = resnorms[-1] <= tol * bnorm
    return SolveResult(x=x, resnorms=resnorms, iters=it, converged=bool(converged),
                       true_resnorms=true_resnorms, info={"method": "pcg-ghysels"})
