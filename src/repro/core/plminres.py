"""Deep-pipelined MINRES (paper Remark 6).

For symmetric (possibly indefinite) A, running the pipelined Arnoldi
process of Alg. 1 and replacing the Galerkin solve by the least-squares
minimization over the Krylov subspace yields a pipelined MINRES: exactly
``plgmres(mode="gmres")`` specialized by the symmetry simplifications.
This wrapper exposes it under its proper name and verifies the residual
optimality property the method guarantees:

    ||b - A x_m||_2 = min_{y} ||b - A (x_0 + V_m y)||_2,

which, unlike p(l)-CG, holds for indefinite systems too.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from .linop import LinearOperator
from .plgmres import plgmres
from .results import SolveResult


def plminres(
    A: LinearOperator,
    b: np.ndarray,
    x0: Optional[np.ndarray] = None,
    *,
    l: int = 1,
    m: int = 50,
    sigma: Optional[Sequence[float]] = None,
    spectrum: Optional[tuple] = None,
) -> SolveResult:
    """m iterations of l-deep pipelined MINRES (symmetric, indefinite OK)."""
    r = plgmres(A, b, x0, l=l, m=m, sigma=sigma, spectrum=spectrum,
                mode="gmres")
    r.info["method"] = f"p({l})-MINRES"
    return r
