"""Preconditioning as a first-class layer (paper Sec. 6, Alg. 4).

The flagship variant of the paper is *preconditioned* p(l)-CG, yet a bare
``M=`` callable tells the execution layers nothing: the fused Pallas tier
cannot fold an opaque closure into its megakernel, and the mesh layer
cannot know whether an apply is shard-local (no communication), neighbor-
local (halo ``ppermute`` only) or global (forbidden -- it would add a
reduction to the paper's single ``psum`` per iteration).

:class:`Preconditioner` makes those properties structural:

  * ``apply(v)``        -- the full-vector ``M^{-1} v`` (single device);
  * ``inv_diag``        -- optional diagonal hint: when set, ``M^{-1}`` IS
    an elementwise multiply, so ``backend="fused"`` folds the apply (and
    the zhat window recurrence) into its single per-iteration Pallas
    launch instead of splitting the body;
  * ``local_apply(op)`` -- optional shard-local apply bound to a
    :class:`~repro.distributed.operator.DistributedOperator`; returning a
    callable declares "no global communication inside", which is what
    lets the mesh engine run preconditioned p(l)-CG with still exactly
    ONE stacked psum per iteration;
  * ``precond_spectrum(base)`` -- optional inclusion interval for the
    spectrum of ``M^{-1} A``, used to default the auxiliary-basis shifts
    (``core.shifts.chebyshev_shifts``) of the preconditioned pipeline;
  * ``residual_gap`` diagnostics (module function): the attainable-
    accuracy gap ``(b - A x_k) - zeta_k v_k`` of arXiv:1804.02962 for any
    finished solve, preconditioned or not.

Concrete implementations: :class:`Identity` (the collapsed
unpreconditioned case), :class:`Jacobi` (diagonal; fuses into the
megakernel; shard-local when the diagonal is constant),
:class:`BlockJacobi` (block-local Chebyshev approximate inverse of the
Poisson stencil -- the paper's natural mesh preconditioner: zero
communication by construction) and :class:`Chebyshev` (polynomial in the
full operator, built on the SAME Chebyshev-root machinery as the basis
shifts; neighbor-halo traffic only on a mesh).

``as_preconditioner`` promotes bare callables (and the legacy
``linop.Preconditioner`` dataclass) so the public ``M=`` API is
unchanged.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Optional, Sequence

import numpy as np

from .shifts import chebyshev_shifts

Array = Any


# --------------------------------------------------------------------------
# shared polynomial machinery (reuses the shift roots of core.shifts)
# --------------------------------------------------------------------------

def chebyshev_inverse_apply(matvec: Callable, v: Array,
                            shifts: Sequence[float]) -> Array:
    """``p(A) v`` with ``p(t) = (1 - prod_i (1 - t/sigma_i)) / t``.

    The ``sigma_i`` are the degree-m Chebyshev roots on ``[lmin, lmax]``
    (``core.shifts.chebyshev_shifts``), so ``1 - t p(t)`` is the scaled
    Chebyshev residual polynomial: ``|1 - t p(t)| <= 1/T_m(theta/delta)``
    on the interval, and ``p(t) > 0`` for every ``0 < t <= lmax`` -- i.e.
    ``p(A)`` is SPD whenever ``spec(A) \\subset (0, lmax]``.  Uses
    ``len(shifts) - 1`` operator applications.
    """
    # factored update: x_{k+1} = x_k + r_k / s_{k+1}, r_{k+1} = (I - A/s) r_k
    x = v * 0
    r = v
    for j, s in enumerate(shifts):
        x = x + r / s
        if j + 1 < len(shifts):            # last residual is never read
            r = r - matvec(r) / s
    return x


def _cheb_tp_range(lmin: float, lmax: float, degree: int,
                   tmax: float) -> tuple:
    """Numerical range of ``t * p(t)`` (= spectrum map of ``p(A) A``) over
    ``(0, tmax]`` for the degree-``degree`` Chebyshev inverse polynomial
    on ``[lmin, lmax]``."""
    sig = np.asarray(chebyshev_shifts(lmin, lmax, degree))
    t = np.linspace(tmax / 4096.0, tmax, 4096)
    r = np.ones_like(t)
    for s in sig:
        r *= 1.0 - t / s
    tp = 1.0 - r
    return float(tp.min()), float(tp.max())


# --------------------------------------------------------------------------
# the protocol
# --------------------------------------------------------------------------

class Preconditioner:
    """Base class / structural protocol for SPD preconditioners.

    Only the *inverse* application ``M^{-1} v`` is ever required (the
    paper's preconditioned p(l)-CG never applies ``M`` itself, Sec. 2.3).
    Subclasses override :meth:`apply`; everything else has safe defaults
    (no hints, no mesh path).
    """

    name: str = "M"

    def apply(self, v: Array) -> Array:
        raise NotImplementedError

    def __call__(self, v: Array) -> Array:
        return self.apply(v)

    # ---- structural hints ------------------------------------------------

    @property
    def is_identity(self) -> bool:
        """True when ``apply`` is the identity -- the engines then run the
        cheaper unpreconditioned pipeline (3l+2 instead of 3l+5 vectors).
        ``M=None`` and ``M=Identity()`` are the same solve."""
        return False

    @property
    def inv_diag(self):
        """Inverse diagonal when ``M^{-1}`` is an elementwise multiply
        (scalar or ``(n,)`` array), else None.  Set => ``backend="fused"``
        folds the apply into its single per-iteration Pallas launch."""
        return None

    def local_apply(self, op) -> Optional[Callable]:
        """Shard-local apply bound to a DistributedOperator, or None.

        The returned callable maps one *local flat block* to its
        preconditioned block inside ``shard_map`` and must not perform any
        global collective (neighbor ``ppermute`` halos are fine) -- that
        contract is what keeps the preconditioned mesh sweep at exactly
        ONE psum per iteration.
        """
        return None

    def precond_spectrum(self, base: tuple = (0.0, 8.0)) -> Optional[tuple]:
        """Inclusion interval for ``spec(M^{-1} A)`` given an interval
        ``base`` for ``spec(A)``, or None when unknown.  Drives the
        default auxiliary-basis shifts of the preconditioned pipeline."""
        return None

    def runtime(self) -> Optional["Preconditioner"]:
        """Self, or None for the identity -- the single place where the
        unpreconditioned code path collapses into ``M=Identity``."""
        return None if self.is_identity else self


class Identity(Preconditioner):
    """The trivial preconditioner: every unpreconditioned solve is the
    ``M=Identity`` case of the preconditioned pipeline."""

    name = "I"

    def apply(self, v):
        return v

    @property
    def is_identity(self):
        return True

    @property
    def inv_diag(self):
        return 1.0

    def local_apply(self, op):
        return lambda v: v

    def precond_spectrum(self, base=(0.0, 8.0)):
        return tuple(base)


class Jacobi(Preconditioner):
    """Diagonal preconditioner ``M = diag(d)``; ``apply`` multiplies by
    ``1/d``.  Carries the ``inv_diag`` fusion hint, so the fused scan
    backend keeps ONE Pallas launch per steady-state body.  Mesh-capable
    either way: a constant (scalar) diagonal is trivially shard-local,
    and a full ``(n,)`` diagonal is shard-split through the operator's
    2-D processor grid (each shard slices its own block of the inverse
    diagonal by mesh axis index -- an elementwise multiply, zero
    communication, so the preconditioned mesh sweep keeps exactly ONE
    psum per iteration).
    """

    def __init__(self, diag, name: str = "jacobi"):
        self.name = name
        d = np.asarray(diag, dtype=float)
        if d.ndim == 0 or (d.size and np.all(d == d.reshape(-1)[0])):
            self._inv = float(1.0 / (d if d.ndim == 0 else d.reshape(-1)[0]))
            self._scalar = True
        else:
            self._inv = 1.0 / d
            self._scalar = False

    @classmethod
    def from_operator(cls, A) -> "Jacobi":
        if getattr(A, "diag", None) is None:
            raise ValueError("operator exposes no diagonal")
        return cls(A.diag, name=f"jacobi({getattr(A, 'name', 'A')})")

    def apply(self, v):
        return v * self._inv

    @property
    def inv_diag(self):
        return self._inv

    def local_apply(self, op):
        if self._scalar:                # constant: trivially shard-local
            inv = self._inv
            return lambda v: v * inv
        # full (n,) diagonal: shard-split through the operator's 2-D
        # decomposition.  The global inverse diagonal rides the traced
        # program as a replicated constant; each shard dynamic-slices its
        # own (H, W) block by mesh axis index -- no collective, keeping
        # the one-psum-per-iteration gate of the mesh sweep.
        gshape = tuple(getattr(op, "global_shape", ()) or ())
        lshape = tuple(getattr(op, "local_shape", ()) or ())
        axes = getattr(op, "axes", None)
        if (len(gshape) != 2 or len(lshape) != 2 or axes is None
                or np.size(self._inv) != gshape[0] * gshape[1]):
            return None
        inv2d = np.asarray(self._inv).reshape(gshape)
        row_axis, col_axis = tuple(axes)[:2]

        def apply_local(vflat):
            import jax
            import jax.numpy as jnp
            H, W = lshape
            i = jax.lax.axis_index(row_axis)
            j = jax.lax.axis_index(col_axis)
            blk = jax.lax.dynamic_slice(
                jnp.asarray(inv2d, dtype=vflat.dtype),
                (i * H, j * W), (H, W))
            return (vflat.reshape(H, W) * blk).reshape(-1)

        return apply_local

    def precond_spectrum(self, base=(0.0, 8.0)):
        lo, hi = base
        if self._scalar:
            return (lo * self._inv, hi * self._inv)
        imin, imax = float(np.min(self._inv)), float(np.max(self._inv))
        return (lo * imin, hi * imax)


def _block_stencil5(g):
    """Zero-Dirichlet 5-point stencil on one 2-D block (no halos): the
    block-diagonal part of the Poisson operator.  jnp so it traces under
    jit/vmap/shard_map; identical math on a shard and on a vmapped block,
    which is what makes mesh vs single-device BlockJacobi bit-comparable.
    """
    import jax.numpy as jnp
    g = jnp.asarray(g)
    out = 4.0 * g
    out = out.at[1:, :].add(-g[:-1, :])
    out = out.at[:-1, :].add(-g[1:, :])
    out = out.at[:, 1:].add(-g[:, :-1])
    out = out.at[:, :-1].add(-g[:, 1:])
    return out


class BlockJacobi(Preconditioner):
    """Block-Jacobi for the 2-D Poisson stencil: each ``(nx/px, ny/py)``
    block is approximately inverted by a degree-``degree`` Chebyshev
    polynomial of the *block-local* zero-Dirichlet stencil.

    This is the paper's natural mesh preconditioner (Fig. 5 uses block
    Jacobi): the block grid is the processor grid, so ``local_apply`` is
    literally the one-block apply on the shard -- zero communication, and
    the preconditioned mesh sweep keeps its single psum per iteration.
    The polynomial local solve replaces the paper's ILU block solve,
    whose sequential triangular sweeps map poorly onto the TPU VPU; a
    positive Chebyshev polynomial of an SPD block is SPD by construction.

    On a single device ``apply`` partitions the global field into the
    SAME ``(px, py)`` blocks (one ``vmap`` over blocks), so mesh and
    single-device preconditioned solves agree to roundoff.
    """

    def __init__(self, stencil2d: tuple, blocks: tuple = (1, 1),
                 degree: int = 4, spectrum: tuple = (0.5, 8.0),
                 power_iters: int = 32, name: Optional[str] = None):
        nx, ny = stencil2d
        px, py = blocks
        if nx % px or ny % py:
            raise ValueError(f"grid {stencil2d} must divide blocks {blocks}")
        if not 0 < spectrum[0] < spectrum[1]:
            raise ValueError(f"need 0 < lmin < lmax, got {spectrum}")
        self.stencil2d = (int(nx), int(ny))
        self.blocks = (int(px), int(py))
        self.degree = int(degree)
        self.spectrum = (float(spectrum[0]), float(spectrum[1]))
        self.power_iters = int(power_iters)
        self._shifts = tuple(chebyshev_shifts(*self.spectrum, degree))
        self._pspec: Optional[tuple] = None     # lazy precond_spectrum
        self.name = name or f"block-jacobi{self.blocks}-cheb{degree}"

    @classmethod
    def for_mesh(cls, A, mesh, *, degree: int = 4,
                 spectrum: tuple = (0.5, 8.0), **kw) -> "BlockJacobi":
        """Blocks = the processor grid of ``mesh`` (first two axes), grid
        from the operator's ``stencil2d`` hint."""
        hint = getattr(A, "stencil2d", None) or getattr(A, "global_shape",
                                                        None)
        if hint is None:
            raise ValueError("BlockJacobi.for_mesh needs an operator with "
                             "a stencil2d hint (repro.operators.poisson2d)")
        names = tuple(mesh.axis_names)[:2]
        return cls(tuple(hint), (mesh.shape[names[0]], mesh.shape[names[1]]),
                   degree=degree, spectrum=spectrum, **kw)

    def _local2d(self, gb):
        """Chebyshev approximate inverse of one zero-Dirichlet block."""
        return chebyshev_inverse_apply(_block_stencil5, gb, self._shifts)

    def apply(self, v):
        import jax
        import jax.numpy as jnp
        v = jnp.asarray(v)
        nx, ny = self.stencil2d
        px, py = self.blocks
        bx, by = nx // px, ny // py
        g = (v.reshape(nx, ny).reshape(px, bx, py, by)
             .transpose(0, 2, 1, 3).reshape(px * py, bx, by))
        out = jax.vmap(self._local2d)(g)
        out = (out.reshape(px, py, bx, by).transpose(0, 2, 1, 3)
               .reshape(nx, ny))
        return out.reshape(v.shape)

    def local_apply(self, op):
        gshape = tuple(getattr(op, "global_shape", ()) or ())
        lshape = tuple(getattr(op, "local_shape", ()) or ())
        if gshape != self.stencil2d or len(lshape) != 2:
            return None
        nx, ny = self.stencil2d
        if (nx // lshape[0], ny // lshape[1]) != self.blocks:
            raise ValueError(
                f"BlockJacobi blocks {self.blocks} do not match the "
                f"operator's processor grid "
                f"{(nx // lshape[0], ny // lshape[1])}; build the "
                "preconditioner with BlockJacobi.for_mesh(A, mesh)")
        return lambda vflat: self._local2d(
            vflat.reshape(lshape)).reshape(-1)

    def precond_spectrum(self, base=(0.0, 8.0)):
        # a TIGHT interval matters here: a slack upper bound misplaces
        # the auxiliary-basis shifts, which degrades the conditioning of
        # G and triggers square-root breakdowns near the accuracy floor
        # (paper Sec. 4).  The stencil2d hint IS the global operator (the
        # zero-Dirichlet 5-point stencil on the full grid), so estimate
        # lam_max(M^{-1} A) directly by power iteration at first use;
        # power_iters=0 falls back to the analytic split bound
        # max t*p(t) + ||p||_inf * ||A - A_blk||_2  (cut coupling <= 2).
        if self._pspec is not None:
            return self._pspec
        lo, hi = self.spectrum
        if self.power_iters > 0:
            import jax.numpy as jnp
            nx, ny = self.stencil2d
            v = jnp.asarray(np.random.default_rng(7)
                            .standard_normal(nx * ny))
            lam = hi
            for _ in range(self.power_iters):
                w = self.apply(_block_stencil5(
                    v.reshape(nx, ny)).reshape(-1))
                lam = float(jnp.vdot(v, w) / jnp.vdot(v, v))
                v = w / jnp.linalg.norm(w)
            self._pspec = (0.0, 1.05 * lam)
            return self._pspec
        tmax = float(base[1])
        tp_max = _cheb_tp_range(lo, hi, self.degree, tmax)[1]
        theta = 0.5 * (hi + lo)
        delta = 0.5 * (hi - lo)
        s = theta / delta
        m = self.degree
        tm = math.cosh(m * math.acosh(s))
        tmp = m * math.sinh(m * math.acosh(s)) / math.sinh(math.acosh(s))
        p0 = tmp / (delta * tm)
        self._pspec = (0.0, tp_max + 2.0 * p0)
        return self._pspec


class Chebyshev(Preconditioner):
    """Polynomial preconditioner ``M^{-1} = p(A)`` with ``p`` the
    degree-``degree`` Chebyshev approximation of ``1/t`` on ``spectrum``
    -- the same root machinery (``core.shifts.chebyshev_shifts``) that
    generates the auxiliary-basis shifts.

    SPD whenever ``spec(A) \\subset (0, lmax]`` (the residual polynomial
    satisfies ``1 - t p(t) < 1`` there).  On a mesh, ``local_apply``
    applies the polynomial through the operator's ``matvec_local`` --
    ``degree - 1`` extra halo exchanges per iteration, neighbor traffic
    only, still zero extra global reductions.
    """

    def __init__(self, A=None, *, spectrum: tuple = (0.5, 8.0),
                 degree: int = 3, matvec: Optional[Callable] = None,
                 name: Optional[str] = None):
        if matvec is None:
            if A is None:
                raise ValueError("Chebyshev needs A (operator) or matvec=")
            if hasattr(A, "matvec"):
                matvec = A.matvec
            elif callable(A):
                matvec = A
            elif hasattr(A, "matvec_local"):
                matvec = None       # mesh-only: apply via local_apply(op)
            else:
                raise TypeError(f"cannot take a matvec from "
                                f"{type(A).__name__}")
        if not 0 < spectrum[0] < spectrum[1]:
            raise ValueError(f"need 0 < lmin < lmax, got {spectrum}")
        self._matvec = matvec
        self.degree = int(degree)
        self.spectrum = (float(spectrum[0]), float(spectrum[1]))
        self._shifts = tuple(chebyshev_shifts(*self.spectrum, degree))
        self.name = name or f"chebyshev-{degree}"

    def apply(self, v):
        if self._matvec is None:
            raise ValueError(
                "this Chebyshev preconditioner was built from a "
                "DistributedOperator and is mesh-local only; construct it "
                "from a LinearOperator/matvec for single-device applies")
        return chebyshev_inverse_apply(self._matvec, v, self._shifts)

    def local_apply(self, op):
        mv = getattr(op, "matvec_local", None)
        if mv is None:
            return None
        shifts = self._shifts
        return lambda vflat: chebyshev_inverse_apply(mv, vflat, shifts)

    def precond_spectrum(self, base=(0.0, 8.0)):
        lo, hi = self.spectrum
        tpmin, tpmax = _cheb_tp_range(lo, hi, self.degree, float(base[1]))
        return (0.0, tpmax)


class _CallablePreconditioner(Preconditioner):
    """Promotion of a bare ``M=`` callable (incl. the legacy
    ``linop.Preconditioner`` dataclass): full-vector apply only -- no
    fusion hint, no shard-local form."""

    def __init__(self, fn: Callable, name: str = "M"):
        self._fn = fn
        self.name = name

    def apply(self, v):
        return self._fn(v)


def as_preconditioner(M) -> Preconditioner:
    """Coerce ``M`` (None | Preconditioner | callable) to the protocol.

    ``None`` becomes :class:`Identity` -- downstream code then handles
    exactly one shape of object and collapses the identity back to the
    cheap unpreconditioned pipeline via :meth:`Preconditioner.runtime`.
    """
    if M is None:
        return _IDENTITY
    if isinstance(M, Preconditioner):
        return M
    if callable(M):
        return _CallablePreconditioner(M, name=getattr(M, "name", "M"))
    raise TypeError(f"cannot interpret {type(M).__name__} as a "
                    "preconditioner (need a callable applying M^{-1} v)")


_IDENTITY = Identity()


# --------------------------------------------------------------------------
# attainable-accuracy diagnostics (paper Sec. 4 / arXiv:1804.02962)
# --------------------------------------------------------------------------

def residual_gap(A, b, result, lane: Optional[int] = None) -> dict:
    """Residual-gap report for a finished solve.

    The pipelined recurrences drift: the *implicit* residual norm
    ``|zeta_k|`` (what the stopping test sees) and the *true* residual
    ``||b - A x_k||`` separate by the gap that bounds attainable accuracy
    (paper eq. 41/42, arXiv:1804.02962).  For a batched result pass
    ``lane`` (and that lane's ``b``).  Returns ``{"true_resnorm",
    "implicit_resnorm", "gap", "rel_gap"}``; with a preconditioner the
    implicit norm is the M-inner-product residual, so the gap is the
    honest cross-metric drift the caller should monitor.
    """
    x = np.asarray(result.x)
    bb = np.asarray(b)
    traces = result.resnorms
    if x.size != bb.size:
        if lane is None:
            raise ValueError(
                "batched result: pass lane= (and that lane's b) to "
                "residual_gap")
        x = x[lane]
        traces = traces[lane]
    elif lane is not None:
        traces = traces[lane]
    true = float(np.linalg.norm((bb.reshape(-1)
                                 - np.asarray(A @ x.reshape(-1)))
                                .reshape(-1)))
    last = traces[-1] if len(traces) else 0.0
    while isinstance(last, (list, tuple, np.ndarray)):
        last = last[-1] if len(last) else 0.0
    implicit = float(last)
    bnorm = float(np.linalg.norm(bb.reshape(-1))) or 1.0
    return {
        "true_resnorm": true,
        "implicit_resnorm": implicit,
        "gap": abs(true - implicit),
        "rel_gap": abs(true - implicit) / bnorm,
    }
