"""Common result record for every Krylov solver in the library."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

Array = Any


@dataclasses.dataclass
class SolveResult:
    x: Array                      # final iterate
    resnorms: list                # recursive / implicit residual norm history
    iters: int                    # number of solution updates performed
    converged: bool
    breakdowns: int = 0           # square-root breakdowns encountered (p(l)-CG)
    restarts: int = 0             # explicit restarts performed after breakdowns
    replacements: int = 0         # periodic true-residual replacements (r=b-Ax)
    true_resnorms: Optional[list] = None   # ||b - A x_j|| when traced
    info: dict = dataclasses.field(default_factory=dict)

    @property
    def final_resnorm(self):
        return self.resnorms[-1] if self.resnorms else None
