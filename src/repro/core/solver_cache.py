"""Bounded weak-key caches for jitted solver sweeps.

PR 1 cached the jitted single-RHS sweep and the batched vmap(scan) engine
with ``functools.lru_cache`` keyed on the ``matvec``/``prec`` callables.
That had two failure modes:

* **retention**: the cache held strong references to the operator closures
  (and every array they captured) until 16 *other* configurations evicted
  them -- effectively forever in a long-lived solver process;
* **churn**: a fresh closure per call (``lambda v: A @ v`` built inline)
  missed the cache every time while still pinning the previous 16 closures.

:class:`WeakCallableCache` fixes both.  Keys hold the callables through
``weakref.ref`` (dead referents evict their entries eagerly via the ref
callback), and -- crucially -- the cached jitted functions are built over
:func:`weakly_callable` proxies, so the cache value does not keep the
operator alive either.  Dropping the operator therefore releases the
compiled sweep; the LRU bound caps worst-case retention for callables that
cannot be weak-referenced.

Every cache instance self-registers so :func:`clear_solver_cache` can drop
all compiled sweeps (single-RHS and batched) in one call -- the public
escape hatch for memory-sensitive serving loops.
"""
from __future__ import annotations

import weakref
from collections import OrderedDict
from typing import Any, Callable, Optional

_REGISTRY: list["WeakCallableCache"] = []


def clear_solver_cache() -> None:
    """Drop every cached jitted solver sweep (single-RHS and batched)."""
    for cache in _REGISTRY:
        cache.clear()


def weakly_callable(fn: Optional[Callable]) -> Optional[Callable]:
    """A proxy that calls ``fn`` through a weak reference.

    Closing a jitted partial over the proxy (rather than ``fn`` itself)
    keeps the cache from pinning the operator: once the caller drops
    ``fn``, the cache entry is evicted and retracing the stale jitted
    object raises ``ReferenceError`` instead of resurrecting it.  ``None``
    passes through (preserves ``prec is None`` dispatch) and callables
    that cannot be weak-referenced are returned as-is.
    """
    if fn is None:
        return None
    try:
        ref = weakref.ref(fn)
    except TypeError:
        return fn

    def call(*args, **kwargs):
        target = ref()
        if target is None:
            raise ReferenceError(
                "solver operator callable was garbage-collected; rebuild "
                "the sweep (see repro.core.clear_solver_cache)")
        return target(*args, **kwargs)

    return call


class WeakCallableCache:
    """LRU cache keyed on (callable identities, hashable config).

    Callables are held via ``weakref.ref`` when possible; when a referent
    dies, its entries are purged immediately through the ref callback.
    Unweakrefable callables fall back to strong keys (retention then
    bounded by ``maxsize``).
    """

    def __init__(self, maxsize: int = 16):
        self._maxsize = maxsize
        self._data: OrderedDict[tuple, Any] = OrderedDict()
        self._dead: set = set()        # refs whose purge was deferred
        self._mutating = False         # reentrancy guard for _on_death
        _REGISTRY.append(self)

    def __len__(self) -> int:
        return len(self._data)

    def clear(self) -> None:
        # dropping the cached values can kill their last strong referents,
        # firing _on_death REENTRANTLY inside OrderedDict.clear(); the
        # guard makes those callbacks defer (everything is going away
        # anyway) instead of iterating a dict in mid-mutation state
        self._mutating = True
        try:
            self._data.clear()
            self._dead.clear()
        finally:
            self._mutating = False

    def _on_death(self, dead_ref) -> None:
        """weakref callback: purge the dead referent's entries.

        May fire while this cache is itself mutating (e.g. ``clear()``
        drops the last reference to a cached sweep whose closure held the
        last reference to the operator): iterating ``self._data`` then
        raises (OrderedDict signals mutation-during-iteration with
        ``KeyError``), so in that case the purge is deferred to the next
        ``get_or_build``/``_purge_dead`` instead of touching the dict.
        """
        self._dead.add(dead_ref)
        if not self._mutating:
            self._purge_dead()

    def _purge_dead(self) -> None:
        self._mutating = True
        try:
            while self._dead:
                dead_ref = self._dead.pop()
                # reentrant callbacks during this scan/pop only append to
                # self._dead (guard is set) and are drained by the loop
                for key in [k for k in self._data if dead_ref in k[0]]:
                    self._data.pop(key, None)
        finally:
            self._mutating = False

    def _key(self, callables, config) -> tuple:
        refs = []
        for c in callables:
            if c is None:
                refs.append(None)
                continue
            try:
                refs.append(weakref.ref(c, self._on_death))
            except TypeError:
                refs.append(c)
        return (tuple(refs), config)

    def get_or_build(self, callables: tuple, config: tuple,
                     build: Callable[[], Any]) -> Any:
        self._purge_dead()              # drain any deferred evictions
        key = self._key(callables, config)
        if key in self._data:
            self._data.move_to_end(key)
            return self._data[key]
        value = build()
        self._mutating = True           # LRU eviction can fire callbacks
        try:
            self._data[key] = value
            while len(self._data) > self._maxsize:
                self._data.popitem(last=False)
        finally:
            self._mutating = False
        self._purge_dead()
        return value
