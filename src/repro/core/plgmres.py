"""Deep-pipelined GMRES p(l)-GMRES (Ghysels et al.) -- paper Alg. 1.

Full-storage reference implementation.  Two roles in this repo:

1. derivation cross-check: p(l)-CG (Alg. 2) is derived from this algorithm
   by exploiting symmetry; for SPD systems the Hessenberg matrix produced
   here must be tridiagonal and the FOM-mode iterates (Remark 6) must match
   p(l)-CG / classic CG;
2. storage comparison: p(l)-GMRES keeps *all* basis vectors (O(i) memory,
   Table 1) versus p(l)-CG's 3l+2 window -- quantified in the benchmarks.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import numpy as np

from .linop import LinearOperator
from .results import SolveResult
from .shifts import chebyshev_shifts


def plgmres(
    A: LinearOperator,
    b: np.ndarray,
    x0: Optional[np.ndarray] = None,
    *,
    l: int = 1,
    m: int = 50,
    sigma: Optional[Sequence[float]] = None,
    spectrum: Optional[tuple] = None,
    mode: str = "gmres",          # 'gmres' (least squares) or 'fom' (Remark 6)
) -> SolveResult:
    """Run m iterations of p(l)-GMRES and return x_m (no restarts)."""
    if sigma is None:
        lmin, lmax = spectrum if spectrum is not None else (0.0, 8.0)
        sigma = chebyshev_shifts(lmin, lmax, l)
    sigma = list(sigma)
    n = A.n
    x = np.zeros(n) if x0 is None else np.asarray(x0, dtype=float)
    N = m + l + 2
    V = np.zeros((N, n))
    Z = np.zeros((N, n))
    G = np.zeros((N, N))
    H = np.zeros((N, N))

    r0 = b - A @ x
    beta = float(np.linalg.norm(r0))
    if beta == 0.0:
        return SolveResult(x=x, resnorms=[0.0], iters=0, converged=True,
                           info={"method": f"p({l})-GMRES"})
    V[0] = r0 / beta
    Z[0] = V[0]
    G[0, 0] = 1.0
    breakdown_at = None
    n_v = 1                        # number of finalized v basis vectors

    for i in range(m + l):
        # (K1) SPMV
        znew = A @ Z[i]
        if i < l:
            znew = znew - sigma[i] * Z[i]
        if i >= l:
            c = i - l + 1          # new basis vector index
            # lines 7-8: finalize column c of G
            for j in range(max(0, c - l + 1), c):
                s = float(G[:j, j] @ G[:j, c])
                G[j, c] = (G[j, c] - s) / G[j, j]
            arg = G[c, c] - float(G[:c, c] @ G[:c, c])
            if arg <= 0.0:
                breakdown_at = i
                break
            G[c, c] = math.sqrt(arg)
            # lines 10-15: Hessenberg column col = i-l
            col = i - l
            if i < 2 * l:
                for j in range(0, i - l + 1):
                    s = float(H[j, :col] @ G[:col, col])
                    H[j, col] = (G[j, col + 1] + sigma[col] * G[j, col] - s) / G[col, col]
                H[col + 1, col] = G[col + 1, col + 1] / G[col, col]
            else:
                for j in range(0, i - l + 1):
                    s1 = sum(G[j, k + l] * H[k, i - 2 * l] for k in range(0, i - 2 * l + 2))
                    s2 = float(H[j, :col] @ G[:col, col])
                    H[j, col] = (s1 - s2) / G[col, col]
                H[col + 1, col] = G[col + 1, col + 1] * H[i - 2 * l + 1, i - 2 * l] / G[col, col]
            # line 17: extend V
            V[c] = (Z[c] - G[:c, c] @ V[:c]) / G[c, c]
            n_v = c + 1
            # line 18: finish the z recurrence
            znew = (znew - H[: i - l + 1, col] @ Z[l: i + 1]) / H[col + 1, col]
        Z[i + 1] = znew
        # line 20: dot products for column i+1
        if i - l + 1 >= 0:
            for j in range(0, i - l + 2):
                G[j, i + 1] = float(Z[i + 1] @ V[j])
        for j in range(max(0, i - l + 2), i + 2):
            G[j, i + 1] = float(Z[i + 1] @ Z[j])

    m_eff = min(m, n_v - 1) if breakdown_at is not None else m
    m_eff = max(m_eff, 1)
    e1 = np.zeros(m_eff + 1)
    e1[0] = beta
    Hm = H[: m_eff + 1, :m_eff]
    if mode == "gmres":
        y, *_ = np.linalg.lstsq(Hm, e1, rcond=None)
        resnorm = float(np.linalg.norm(Hm @ y - e1))
    elif mode == "fom":
        y = np.linalg.solve(H[:m_eff, :m_eff], e1[:m_eff])
        resnorm = float(abs(H[m_eff, m_eff - 1] * y[-1]))
    else:
        raise ValueError(f"unknown mode {mode!r}")
    x_m = x + V[:m_eff].T @ y
    return SolveResult(
        x=x_m, resnorms=[beta, resnorm], iters=m_eff,
        converged=breakdown_at is None,
        breakdowns=0 if breakdown_at is None else 1,
        info={"method": f"p({l})-GMRES[{mode}]", "H": H[: m_eff + 1, :m_eff].copy(),
              "V": V[:n_v].copy(), "G": G[:n_v, :n_v].copy(),
              "breakdown_at": breakdown_at},
    )
