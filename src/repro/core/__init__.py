"""Core solver library: the paper's Krylov methods behind one front-end.

``repro.core.solve(A, b, method=..., l=..., M=...)`` dispatches every
registered solver (``cg``, ``pcg``, ``plcg``, ``plcg_scan``, ``dlanczos``,
``plminres``) through a single signature and a common ``SolveResult``
contract, including the batched multi-RHS ``vmap(scan)`` path and the
mesh execution layer (``mesh=``).  Preconditioning is a first-class
layer (``repro.core.precond``): ``M=`` accepts a structured
:class:`Preconditioner` (``Jacobi`` fuses into the Pallas megakernel,
``BlockJacobi``/``Chebyshev`` run shard-local on a mesh) or any bare
callable, which is promoted via :func:`as_preconditioner`.  For
many-solves serving workloads, :class:`Solver` / :class:`SolverPool`
(``repro.core.session``) prepare a solver once -- validation,
normalization and sweep building out of the per-call path -- and
micro-batch concurrent right-hand sides into one batched sweep;
``solve()`` itself is the one-shot wrapper around that session API.
On a mesh, ``comm=`` (``repro.core.comm.CommPolicy``) selects how the
per-iteration reduction runs: blocking psum, split psum_scatter +
delayed all_gather genuinely overlapped with compute, or a staged
ppermute ring.  ``precision=`` (``repro.core.precision.PrecisionPolicy``)
splits window *storage* dtype from scalar *compute* dtype -- bf16 window
arrays halve the dominant HBM traffic while recurrences, collective
payloads and convergence tests stay f32/f64.  ``l="auto"`` /
``comm="auto"`` (``repro.core.autotune``) calibrate the pipeline depth
and reduction policy from measured on-device latencies, clamped so the
storage-precision residual-gap floor never misses the requested ``tol``.
Individual algorithm modules (``cg.py``, ``plcg.py``, ``plcg_scan.py``,
...) stay importable directly for research use.
"""
from .autotune import (AutoDecision, clear_calibration_events, decide,
                       depth_budget, override_latencies, resolve_auto)
from .comm import CommPolicy, as_comm_policy
from .engine import (as_operator, clear_batch_trace, describe_methods,
                     get_method, methods, methods_supporting, register,
                     solve)
from .linop import (BindableOperator, LinearOperator, dense_operator,
                    identity_preconditioner, is_bindable)
from .precision import (PRECISION_MODES, PrecisionPolicy,
                        as_precision_policy)
from .precond import (BlockJacobi, Chebyshev, Identity, Jacobi,
                      Preconditioner, as_preconditioner, residual_gap)
from .results import SolveResult
from .session import SolveHandle, Solver, SolverPool
from .solver_cache import clear_solver_cache

__all__ = [
    "AutoDecision",
    "BindableOperator",
    "BlockJacobi",
    "Chebyshev",
    "CommPolicy",
    "Identity",
    "Jacobi",
    "LinearOperator",
    "PRECISION_MODES",
    "PrecisionPolicy",
    "Preconditioner",
    "SolveHandle",
    "SolveResult",
    "Solver",
    "SolverPool",
    "as_comm_policy",
    "as_operator",
    "as_precision_policy",
    "as_preconditioner",
    "clear_batch_trace",
    "clear_calibration_events",
    "clear_solver_cache",
    "decide",
    "dense_operator",
    "depth_budget",
    "describe_methods",
    "get_method",
    "identity_preconditioner",
    "is_bindable",
    "methods",
    "methods_supporting",
    "override_latencies",
    "register",
    "residual_gap",
    "resolve_auto",
    "solve",
]
