"""Core solver library: the paper's Krylov methods behind one front-end.

``repro.core.solve(A, b, method=..., l=..., M=...)`` dispatches every
registered solver (``cg``, ``pcg``, ``plcg``, ``plcg_scan``, ``dlanczos``,
``plminres``) through a single signature and a common ``SolveResult``
contract, including the batched multi-RHS ``vmap(scan)`` path.  Individual
algorithm modules (``cg.py``, ``plcg.py``, ``plcg_scan.py``, ...) stay
importable directly for research use.
"""
from .engine import (as_operator, clear_batch_trace, describe_methods,
                     get_method, methods, register, solve)
from .linop import (LinearOperator, Preconditioner, dense_operator,
                    identity_preconditioner)
from .results import SolveResult
from .solver_cache import clear_solver_cache

__all__ = [
    "LinearOperator",
    "Preconditioner",
    "SolveResult",
    "as_operator",
    "clear_batch_trace",
    "clear_solver_cache",
    "dense_operator",
    "describe_methods",
    "get_method",
    "identity_preconditioner",
    "methods",
    "register",
    "solve",
]
