"""Measured-latency autotuner: ``l="auto"`` depth + ``comm="auto"`` policy.

The paper's whole performance argument is a latency-ratio model -- per
iteration the pipelined engine costs ``max(glred / l, spmv)`` while
classic CG pays ``2 glred + spmv`` (Figs. 3/4; reproduced structurally in
the ``fig3/`` bench rows).  Every knob that model depends on is
measurable on the actual device/mesh, so this module closes the loop:
instead of hand-picking ``l``, the ``comm=`` reduction schedule and the
overlap staging depth ``d``, a prepared solver calibrates ONCE --

  (a) one local SPMV (``matvec_local`` including its halo ``ppermute``
      exchanges) under ``jit(shard_map(...))`` on the live mesh;
  (b) one stacked global reduction per ``comm=`` mode: the blocking
      ``psum``, the split ``psum_scatter``/``all_gather`` pair, and the
      circulate-accumulate ``ppermute`` ring hops;
  (c) the per-depth sweep cost: a short fixed-budget p(l)-CG sweep per
      candidate depth, whose per-iteration time captures the window-
      recurrence flop growth Table 1 predicts (``6l+10`` FLOPS x n);

-- and solves the model for the fastest admissible ``(l, comm, d)``.

Stability clamps the search: the attainable-accuracy floor of the
storage precision grows with the basis width (arXiv:1804.02962, measured
in the committed ``mp/gap_*`` ladder of ``benchmarks/mp_bench.py``), so
:func:`depth_budget` caps the candidate depths at the largest ``l``
whose modeled ``residual_gap`` floor still reaches the requested
``tol`` -- auto never picks a depth whose bf16/f32 floor misses the
target (the measured counterpart is ``repro.core.residual_gap``).

Calibration results are cached in the weak-key solver-cache layer
(:class:`~repro.core.solver_cache.WeakCallableCache`) keyed on the
operator plus ``(shape, mesh, backend, precision, dtype)``: a session
measures once, and repeated same-shape solves stay zero-retrace and
zero-re-measure.  Tests pin the choice with :func:`override_latencies`
(the injection hook -- fake tables make the decision reproducible in CI
and are never written into the measurement cache) and audit the
measure-exactly-once contract via :data:`CALIBRATION_EVENTS`.

Entry points: ``solve(A, b, l="auto", comm="auto")`` /
``Solver(A, l="auto", ...)`` / ``prepare_on_mesh(..., l="auto")``; the
chosen depth/policy and the latencies that justified it are reported in
``SolveResult.info["auto"]``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
import warnings
from typing import Optional

from .comm import CommPolicy, as_comm_policy, ring_hop
from .precision import as_precision_policy
from .solver_cache import WeakCallableCache

#: Candidate pipeline depths of the auto search (the paper's deep range;
#: clamped per problem by :func:`depth_budget`).
DEPTH_LADDER = (1, 2, 3, 5, 8)

#: Gap-model coefficient: the committed ``mp/gap_*`` ladder fits
#: ``rel_gap ~ eps_storage * (2l+1)`` to within its own noise, so the
#: modeled floor at depth l is ``GAP_COEFF * eps * (2l+1)`` with the
#: coefficient at its measured order of magnitude, 1.
GAP_COEFF = 1.0

#: Iterations of each per-depth probe sweep (small: the probe measures
#: per-iteration cost, not convergence).
PROBE_ITERS = 8

#: Preference order on equal model scores: shallower pipelines are more
#: stable, simpler reduction schedules are cheaper to reason about.
_MODE_RANK = {"blocking": 0, "overlap": 1, "ring": 2}

#: Audit log of calibrations: one ``(source, kind, shape, mesh)`` entry
#: per actual measurement (or per injected resolution) -- NEVER per cache
#: hit, so tests can assert a prepared Solver calibrates exactly once.
CALIBRATION_EVENTS: list[tuple] = []

#: Measured latency tables, keyed weakly on the operator (matvec /
#: DistributedOperator) plus the (shape, mesh, backend, precision,
#: dtype) configuration; cleared by ``repro.core.clear_solver_cache``.
_CALIB_CACHE = WeakCallableCache(maxsize=16)

_OVERRIDE: Optional[dict] = None


def clear_calibration_events() -> None:
    """Reset :data:`CALIBRATION_EVENTS` (test helper; cleared in place
    like ``clear_batch_trace``)."""
    CALIBRATION_EVENTS.clear()


def set_latency_override(table: Optional[dict]) -> None:
    """Install (or with ``None`` clear) a fake latency table.

    ``table`` must carry ``spmv_us`` (float) and ``glred_us`` (dict
    mode -> float); ``iter_us`` (dict depth -> float) and ``ring_hops``
    (int) are optional.  While installed, :func:`resolve_auto` uses the
    table instead of measuring -- and bypasses the measurement cache, so
    a later real calibration is never poisoned by injected numbers.
    """
    global _OVERRIDE
    if table is not None:
        missing = {"spmv_us", "glred_us"} - set(table)
        if missing:
            raise ValueError(
                f"latency override table is missing {sorted(missing)}; "
                "required keys: spmv_us (float), glred_us (mode -> us)")
    _OVERRIDE = table


@contextlib.contextmanager
def override_latencies(table: dict):
    """Context manager form of :func:`set_latency_override` (restores
    the previous override on exit)."""
    prev = _OVERRIDE
    set_latency_override(table)
    try:
        yield
    finally:
        set_latency_override(prev)


@dataclasses.dataclass(frozen=True)
class AutoDecision:
    """The resolved ``(l, comm, d)`` plus the evidence behind it.

    ``latencies`` holds the calibration inputs (``spmv_us``,
    ``glred_us`` per mode, ``iter_us`` per probed depth) and the model
    score of the winner; ``budget`` is the precision-clamped maximum
    depth; ``source`` is ``"measured"`` or ``"injected"``.
    """

    l: int
    comm: CommPolicy
    depth: Optional[int]
    budget: int
    score_us: float
    latencies: dict
    source: str

    def as_info(self) -> dict:
        """The dict reported as ``SolveResult.info["auto"]``."""
        return {"l": self.l, "comm": self.comm.mode, "depth": self.depth,
                "budget": self.budget, "score_us": self.score_us,
                "source": self.source,
                "latencies": {k: (dict(v) if isinstance(v, dict) else v)
                              for k, v in self.latencies.items()}}


# --------------------------------------------------------------------------
# the stability clamp
# --------------------------------------------------------------------------

def attainable_floor(l: int, storage_dtype) -> float:
    """Modeled residual-gap floor of a depth-``l`` pipeline whose windows
    are stored in ``storage_dtype``.

    The ``mp/gap_*`` ladder (``residual_gap()`` per storage rung at
    depth 5) sits at ``~eps_storage``-scaled floors growing with the
    auxiliary basis width ``2l+1`` -- the linear fit
    ``GAP_COEFF * eps * (2l+1)`` is the clamp model (the measured
    counterpart for a finished solve is ``repro.core.residual_gap``).
    """
    import jax.numpy as jnp
    eps = float(jnp.finfo(jnp.dtype(storage_dtype)).eps)
    return GAP_COEFF * eps * (2 * l + 1)


def depth_budget(tol: float, b_dtype, precision=None) -> int:
    """Largest candidate depth whose modeled precision floor still
    reaches ``tol`` (always >= 1: there is nothing shallower than l=1).

    ``tol=0`` disables early stopping, so no accuracy target constrains
    the depth -- the full ladder stays admissible.  The storage dtype
    comes from resolving the ``precision=`` policy against ``b_dtype``
    (a bf16-storage policy over an f32 problem clamps on eps(bf16)).
    """
    if not tol or tol <= 0:
        return DEPTH_LADDER[-1]
    sdt, _ = as_precision_policy(precision).resolve(b_dtype)
    budget = 1
    for cand in range(1, DEPTH_LADDER[-1] + 1):
        if attainable_floor(cand, sdt) <= tol:
            budget = cand
        else:
            break
    return budget


# --------------------------------------------------------------------------
# measurement
# --------------------------------------------------------------------------

def _time_us(fn, *args, reps: int = 3) -> float:
    """Mean wall time of ``fn(*args)`` in us (one untimed warmup call
    absorbs the jit compile; the last result is blocked on)."""
    import jax
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    out = None
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def _is_mesh_target(target) -> bool:
    return hasattr(target, "matvec_local") and hasattr(target, "mesh")


def _mesh_key(mesh) -> tuple:
    return tuple(mesh.shape.items())


def _nshards(mesh) -> int:
    import numpy as np
    return int(np.prod(list(mesh.shape.values())))


def _measure_mesh(op, *, dtype, width: int, depths: tuple,
                  precision) -> dict:
    """Calibrate on the live mesh: local SPMV + halos, one stacked
    reduction per supported ``comm=`` mode, and a short per-depth sweep.

    The probe jits are local throwaways (they capture the operator only
    for the duration of the calibration); the per-depth sweeps go
    through ``plcg_mesh_sweep``'s weak cache like any other sweep.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map_compat

    from .shifts import chebyshev_shifts

    spec = op.spec()
    x = jnp.ones(tuple(op.global_shape), dtype)
    spmv_fn = jax.jit(shard_map_compat(
        lambda blk: op.matvec_local(blk.reshape(-1)).reshape(blk.shape),
        mesh=op.mesh, in_specs=(spec,), out_specs=spec, check=False))
    spmv_us = _time_us(spmv_fn, x)

    nshards = _nshards(op.mesh)
    sched = (tuple(op.ring_schedule())
             if getattr(op, "ring_schedule", None) is not None else None)
    ring_hops = len(sched) if sched is not None else 0
    payload = jnp.ones((width,), jnp.promote_types(dtype, jnp.float32))

    def reduce_fn(body):
        return jax.jit(shard_map_compat(body, mesh=op.mesh,
                                        in_specs=(P(),), out_specs=P(),
                                        check=False))

    glred = {"blocking": _time_us(reduce_fn(op.reduce_scalars), payload)}
    if nshards > 1:
        if (getattr(op, "reduce_scalars_start", None) is not None
                and getattr(op, "reduce_scalars_finish", None) is not None):
            glred["overlap"] = _time_us(reduce_fn(
                lambda p: op.reduce_scalars_finish(
                    op.reduce_scalars_start(p), width)), payload)
        if ring_hops >= 1:
            def ring_body(p):
                acc, circ = p, p
                for hop in sched:
                    acc, circ = ring_hop(hop, acc, circ)
                return acc
            glred["ring"] = _time_us(reduce_fn(ring_body), payload)

    from repro.distributed.plcg_dist import (_is_bindable_dist,
                                             plcg_mesh_sweep)
    b = jnp.ones(tuple(op.global_shape), dtype)
    x0 = jnp.zeros_like(b)
    # a bindable operator's probe sweep takes its context as the traced
    # leading operand (same program shape the real solves reuse)
    lead = (op.context,) if _is_bindable_dist(op) else ()
    iter_us = {}
    for cand in depths:
        sweep = plcg_mesh_sweep(
            op, l=cand, iters=PROBE_ITERS + cand + 1,
            sigma=tuple(chebyshev_shifts(0.0, 8.0, cand)), tol=0.0,
            precision=precision)
        iter_us[cand] = _time_us(sweep, *lead, b, x0, PROBE_ITERS,
                                 reps=2) / PROBE_ITERS
    return {"spmv_us": spmv_us, "glred_us": glred, "iter_us": iter_us,
            "ring_hops": ring_hops, "nshards": nshards, "width": width}


def _measure_single(op, *, dtype, width: int, depths: tuple, backend,
                    precision) -> dict:
    """Single-device calibration: the jitted SPMV, the stacked dot
    payload standing in for the (collective-free) reduction, and the
    per-depth probe sweeps through ``_jitted_sweep``'s weak cache."""
    import jax
    import jax.numpy as jnp

    from .plcg_scan import _jitted_sweep
    from .shifts import chebyshev_shifts

    n = int(op.n)
    x = jnp.ones((n,), dtype)
    spmv_us = _time_us(jax.jit(op.matvec), x)
    W = jnp.ones((n, width), dtype)
    glred = {"blocking": _time_us(jax.jit(lambda Wm, t: t @ Wm), W, x)}

    b = jnp.ones((n,), dtype)
    x0 = jnp.zeros_like(b)
    iter_us = {}
    for cand in depths:
        sweep = _jitted_sweep(
            op.matvec, cand, PROBE_ITERS + cand + 1,
            tuple(chebyshev_shifts(0.0, 8.0, cand)), 0.0, None, True, 1,
            backend, getattr(op, "stencil2d", None), precision=precision)
        iter_us[cand] = _time_us(sweep, b, x0, PROBE_ITERS,
                                 reps=2) / PROBE_ITERS
    return {"spmv_us": spmv_us, "glred_us": glred, "iter_us": iter_us,
            "ring_hops": 0, "nshards": 1, "width": width}


def measured_latencies(target, *, dtype, backend=None, precision=None,
                       depths: tuple = DEPTH_LADDER) -> tuple:
    """The calibration table for ``target`` -- measured once, then served
    from the weak-key cache.

    ``target`` is a ``DistributedOperator`` (mesh calibration) or a
    ``LinearOperator`` (single device).  Returns ``(table, source)``;
    with :func:`override_latencies` active the injected table is
    returned verbatim (normalized) and the cache is bypassed.  Each
    actual measurement -- and each injected resolution -- appends one
    entry to :data:`CALIBRATION_EVENTS`.
    """
    on_mesh = _is_mesh_target(target)
    kind = "mesh" if on_mesh else "single"
    shape = tuple(target.global_shape) if on_mesh else (int(target.n),)
    meshkey = _mesh_key(target.mesh) if on_mesh else None
    if _OVERRIDE is not None:
        table = {"spmv_us": float(_OVERRIDE["spmv_us"]),
                 "glred_us": {m: float(v)
                              for m, v in _OVERRIDE["glred_us"].items()},
                 "iter_us": {int(k): float(v)
                             for k, v in _OVERRIDE.get("iter_us",
                                                       {}).items()},
                 "ring_hops": int(_OVERRIDE.get("ring_hops", 0)),
                 "nshards": (_nshards(target.mesh) if on_mesh else 1),
                 "width": 2 * max(depths) + 2}
        CALIBRATION_EVENTS.append(("injected", kind, shape, meshkey))
        return table, "injected"
    import jax.numpy as jnp
    pp = as_precision_policy(precision)
    dtype = jnp.dtype(dtype)
    depths = tuple(sorted(set(int(d) for d in depths)))
    width = 2 * max(depths) + 2    # deepest payload + the stability slot
    key = (kind, shape, meshkey, backend, pp, str(dtype), depths)
    # single-device LinearOperators anchor on their (stable) matvec field;
    # mesh and bindable operators anchor on the object itself (a bindable
    # op's .matvec is an ephemeral bound method -- its key would die
    # instantly and defeat the measure-once contract)
    anchor = (target if on_mesh or callable(getattr(target, "matvec_ctx",
                                                    None))
              else target.matvec)

    def build():
        CALIBRATION_EVENTS.append(("measured", kind, shape, meshkey))
        measure = _measure_mesh if on_mesh else _measure_single
        kw = {} if on_mesh else {"backend": backend}
        return measure(target, dtype=dtype, width=width, depths=depths,
                       precision=pp, **kw)

    return _CALIB_CACHE.get_or_build((anchor,), key, build), "measured"


# --------------------------------------------------------------------------
# the model solve
# --------------------------------------------------------------------------

def _local_us(lat: dict, l: int) -> float:
    """Measured per-iteration local-compute time at depth ``l``: the
    probe sweep minus its blocking reduction, floored by the bare SPMV
    (the paper's constant-spmv model is the fallback when no probe for
    this depth exists, e.g. under an injected table)."""
    spmv = float(lat["spmv_us"])
    iter_us = lat.get("iter_us") or {}
    if l not in iter_us:
        return spmv
    return max(float(iter_us[l]) - float(lat["glred_us"]["blocking"]), spmv)


def model_score_us(lat: dict, l: int, mode: str) -> float:
    """The paper's per-iteration latency model with measured inputs:
    ``max(glred(mode) / l, local(l))`` -- the reduction has l iterations
    of slack to hide under the local compute."""
    return max(float(lat["glred_us"][mode]) / l, _local_us(lat, l))


def decide(lat: dict, *, l, comm, tol: float, dtype, precision=None,
           source: str = "measured") -> AutoDecision:
    """Solve the model over the admissible ``(l, comm)`` grid.

    ``l`` is ``"auto"`` or a pinned int (then only ``comm`` is searched);
    ``comm`` is ``"auto"``, a mode string or a ``CommPolicy`` (then only
    the depth is searched).  Admissibility: depths pass the
    :func:`depth_budget` precision clamp (pinned depths are the user's
    choice and bypass it), ``ring`` needs ``l >= hops + 1``, an explicit
    overlap staging depth needs ``l >= depth``, and non-blocking modes
    need the operator to have measured them (split-phase capability and
    more than one shard).
    """
    pp = as_precision_policy(precision)
    budget = DEPTH_LADDER[-1]
    if l == "auto":
        budget = depth_budget(tol, dtype, pp)
        if tol and tol > 0:
            sdt, _ = pp.resolve(dtype)
            if attainable_floor(1, sdt) > tol:
                warnings.warn(
                    f"tol={tol:g} is below the modeled depth-1 precision "
                    f"floor {attainable_floor(1, sdt):.1e} of storage "
                    f"dtype {sdt}; l='auto' clamps to l=1 but the solve "
                    "may stall above tol -- relax tol or raise the "
                    "storage precision", stacklevel=2)
        depths = tuple(d for d in DEPTH_LADDER if d <= budget) or (1,)
    else:
        depths = (int(l),)

    if comm == "auto":
        pinned = None
        modes = tuple(m for m in ("blocking", "overlap", "ring")
                      if m in lat["glred_us"])
    else:
        pinned = as_comm_policy(comm)
        modes = (pinned.mode,)
        if pinned.mode not in lat["glred_us"]:
            # pinned by the user: score it on the blocking measurement
            # rather than rejecting (capability errors stay with
            # build_comm_runtime, the one validation point)
            lat = dict(lat)
            lat["glred_us"] = dict(lat["glred_us"])
            lat["glred_us"][pinned.mode] = lat["glred_us"]["blocking"]

    hops = int(lat.get("ring_hops", 0))
    candidates = []
    for mode in modes:
        for d in depths:
            if mode == "ring" and d < hops + 1:
                continue
            if (pinned is not None and pinned.mode == "overlap"
                    and pinned.depth is not None and d < pinned.depth):
                continue
            candidates.append((model_score_us(lat, d, mode), d,
                               _MODE_RANK[mode], mode))
    if not candidates:
        raise ValueError(
            f"no admissible (l, comm) candidate: depths {depths} "
            f"(precision budget {budget}) cannot satisfy the pinned "
            f"comm={modes[0]!r} constraints (ring needs l >= {hops + 1} "
            "on this mesh); relax tol, raise the storage precision, or "
            "pin a compatible l")
    score, l_star, _, mode_star = min(candidates)
    policy = pinned if pinned is not None else CommPolicy(mode=mode_star)
    depth = policy.resolve_depth(l_star) if policy.mode == "overlap" else None
    return AutoDecision(l=l_star, comm=policy, depth=depth, budget=budget,
                        score_us=float(score), latencies=lat, source=source)


def resolve_auto(target, *, l="auto", comm="auto", tol: float = 1e-8,
                 precision=None, dtype=None, backend=None) -> AutoDecision:
    """Calibrate ``target`` (cached) and solve the model -- the one entry
    point the session layer calls when ``l`` and/or ``comm`` is
    ``"auto"``.

    ``dtype`` defaults to the session float dtype (f64 under
    ``jax_enable_x64``, else f32) -- a prepared solver has no right-hand
    side yet; the dtype only scales the probe fields and the precision
    clamp, both of which are conservative under the default.
    """
    import jax
    import jax.numpy as jnp
    if dtype is None:
        dtype = (jnp.float64 if jax.config.jax_enable_x64
                 else jnp.float32)
    lat, source = measured_latencies(target, dtype=dtype, backend=backend,
                                     precision=precision)
    return decide(lat, l=l, comm=comm, tol=tol, dtype=dtype,
                  precision=precision, source=source)
