"""Linear operator abstraction shared by every solver in the library.

A :class:`LinearOperator` is a thin, array-library-agnostic wrapper around a
``matvec`` callable.  The same object drives the numpy reference solvers, the
jitted JAX production solvers, and (through duck typing) the distributed
shard_map path -- the solvers only ever call ``A @ v`` / ``A.matvec(v)``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

Array = Any  # numpy or jax array


@dataclasses.dataclass(frozen=True)
class LinearOperator:
    """Matrix-free symmetric linear operator ``v -> A v``.

    Attributes:
      matvec: the operator application.
      n: problem dimension (vectors have shape ``(n,)``).
      diag: optional diagonal of A (used by Jacobi-type preconditioners).
      name: human-readable tag used in benchmark tables.
      stencil2d: optional (H, W) grid shape when the operator IS the
        unscaled 5-point Dirichlet Poisson stencil on that grid -- the
        structural hint that lets the ``backend="fused"`` scan engine fold
        the SPMV into its per-iteration Pallas megakernel.
    """

    matvec: Callable[[Array], Array]
    n: int
    diag: Optional[Array] = None
    name: str = "A"
    stencil2d: Optional[tuple] = None

    def __matmul__(self, v: Array) -> Array:
        return self.matvec(v)

    def __call__(self, v: Array) -> Array:
        return self.matvec(v)


@dataclasses.dataclass(eq=False)
class BindableOperator:
    """Matrix-free SPD operator whose matvec closes over a *rebindable*
    context pytree: ``matvec(v) = matvec_ctx(context, v)``.

    The point is zero-retrace outer loops (Newton–CG training): a plain
    ``LinearOperator`` closure would bake its captured arrays into the
    compiled sweep as trace-time constants, forcing a retrace whenever the
    operator data changes (new parameters, new batch).  Here the engine
    threads ``context`` through every prepared sweep as a TRACED leading
    operand and keys its compile caches on the *stable* ``matvec_ctx``
    callable, so ``bind()``-ing fresh same-shape data between solves reuses
    the one compiled program.

    ``matvec_ctx`` must be a stable callable (an instance attribute or
    module-level function, not a per-call lambda) with signature
    ``(context, v) -> Av``; ``context`` may be any pytree of arrays.

    ``eq=False`` keeps identity hashing -- instances are weak-cache keys.
    """

    matvec_ctx: Callable[[Any, Array], Array]
    n: int
    context: Any
    diag: Optional[Array] = None
    name: str = "A"
    stencil2d: Optional[tuple] = None

    def bind(self, context: Any) -> "BindableOperator":
        """Swap in fresh operator data (same pytree structure/shapes)."""
        self.context = context
        return self

    def matvec(self, v: Array) -> Array:
        return self.matvec_ctx(self.context, v)

    def __matmul__(self, v: Array) -> Array:
        return self.matvec(v)

    def __call__(self, v: Array) -> Array:
        return self.matvec(v)


def is_bindable(A: Any) -> bool:
    """True when ``A`` carries a rebindable ``(context, v)`` matvec."""
    return callable(getattr(A, "matvec_ctx", None)) and hasattr(A, "context")


@dataclasses.dataclass(frozen=True)
class Preconditioner:
    """SPD preconditioner; ``apply`` computes ``M^{-1} v``.

    Only the *inverse* application is ever required by the algorithms in this
    repo (the paper's preconditioned p(l)-CG never applies ``M`` itself --
    the unpreconditioned auxiliary basis removes that need, Sec. 2.3).
    """

    apply: Callable[[Array], Array]
    name: str = "M"

    def __call__(self, v: Array) -> Array:
        return self.apply(v)


def dense_operator(A: Array, name: str = "dense") -> LinearOperator:
    """Wrap a dense (n, n) symmetric matrix as a LinearOperator."""
    n = A.shape[0]
    if A.shape != (n, n):
        raise ValueError(f"dense_operator expects a square matrix, got {A.shape}")
    diag = A.diagonal()
    return LinearOperator(matvec=lambda v: A @ v, n=n, diag=diag, name=name)


def identity_preconditioner() -> Preconditioner:
    return Preconditioner(apply=lambda v: v, name="I")
