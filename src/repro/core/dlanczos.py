"""Direct Lanczos (D-Lanczos) -- Saad, 'Iterative methods', Sec. 6.7.1.

Mathematically equivalent to CG in exact arithmetic (paper Remark 7); the
p(l)-CG method of the paper is exactly a deep-pipelined reorganization of
this algorithm.  Kept as an exact-arithmetic cross-check: p(l)-CG with any
pipeline depth must reproduce the D-Lanczos iterates to rounding error.

Solution update via the LU factorization of the tridiagonal Lanczos matrix
T = L U (paper eqs. (21)-(26)) -- identical eta/lambda/zeta recurrences.
"""
from __future__ import annotations

from typing import Optional

from .linop import LinearOperator
from .precond import Preconditioner
from .results import SolveResult


def _dot(a, b):
    return (a * b).sum()


def d_lanczos(
    A: LinearOperator,
    b,
    x0=None,
    *,
    tol: float = 1e-8,
    maxiter: int = 1000,
    M: Optional[Preconditioner] = None,
) -> SolveResult:
    """D-Lanczos; with M, runs Lanczos for M^{-1}A in the M inner product
    (same convention as preconditioned p(l)-CG, Sec. 2.3). |zeta_k| then
    equals ||r_k||_M."""
    x = b * 0 if x0 is None else x0
    rhat = b - A @ x                      # unpreconditioned residual
    r = M(rhat) if M is not None else rhat
    # ||r0||_M = sqrt((rhat, M^{-1} rhat)) = sqrt((rhat, r))
    beta0 = float(_dot(rhat, r)) ** 0.5
    bnorm_ref = float(_dot(b, b)) ** 0.5 if M is None else float(_dot(b, M(b))) ** 0.5
    if beta0 == 0.0:
        return SolveResult(x=x, resnorms=[0.0], iters=0, converged=True,
                           info={"method": "dlanczos"})
    v = r / beta0          # v_0, M-orthonormal basis of K(M^{-1}A, r0)
    vhat = rhat / beta0    # M v_0 (kept so dot products avoid applying M)
    v_prev = v * 0
    vhat_prev = vhat * 0
    delta_prev = 0.0       # delta_{j-1}
    eta_prev = None
    zeta_prev = None
    p_prev = None
    resnorms = [beta0]
    converged = resnorms[-1] <= tol * bnorm_ref
    it = 0
    while not converged and it < maxiter:
        # Lanczos step for M^{-1}A in the M inner product.
        w_hat = A @ v                                # A v_j   (= M * (M^{-1}A v_j))
        w = M(w_hat) if M is not None else w_hat     # M^{-1}A v_j
        gamma = float(_dot(w_hat, v))                # (M^{-1}A v, v)_M
        w = w - gamma * v - delta_prev * v_prev
        w_hat = w_hat - gamma * vhat - delta_prev * vhat_prev
        delta = float(_dot(w_hat, w)) ** 0.5         # ||w||_M
        # LU-factorization driven solution update (eqs. 21-26).
        if it == 0:
            eta = gamma
            zeta = beta0
            p = v / eta
        else:
            lam = delta_prev / eta_prev
            eta = gamma - lam * delta_prev
            zeta = -lam * zeta_prev
            p = (v - delta_prev * p_prev) / eta
        x = x + zeta * p
        # zeta_{k+1} = -lambda_{k+1} zeta_k with lambda_{k+1}=delta/eta
        resnorms.append(abs(delta / eta * zeta))
        v_prev, vhat_prev = v, vhat
        if delta == 0.0:
            converged = True
            it += 1
            break
        v, vhat = w / delta, w_hat / delta
        delta_prev, eta_prev, zeta_prev, p_prev = delta, eta, zeta, p
        it += 1
        converged = resnorms[-1] <= tol * bnorm_ref
    return SolveResult(x=x, resnorms=resnorms, iters=it, converged=bool(converged),
                       info={"method": "dlanczos"})
