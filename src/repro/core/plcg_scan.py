"""Production p(l)-CG engine: jittable, windowed, pipeline-queued (JAX).

This is the TPU-native realization of paper Alg. 2 + Alg. 3:

* vectors live in fixed-size **sliding windows** (Appendix B), stored
  **lane-major**: ``Zw (n, l+1)`` holds the last l+1 auxiliary vectors,
  ``Vw (n, 2l+1)`` the last 2l+1 basis vectors (slot 0 newest), so the
  memory footprint is exactly the paper's 3l+2 vectors (3l+5
  preconditioned) and the 2l+1-entry band of one grid point is contiguous
  -- the layout the fused Pallas kernels stream block-by-block, and the
  layout under which a batched multi-RHS ``vmap`` lowers every kernel to
  ONE ``(B, n, window)`` launch instead of B replays;
* G is stored **banded by column** (Lemma 5): row c of ``Gb`` holds the
  2l+1-entry band of G's column c;
* the 2l+1 dot products of iteration i form one fused payload (the paper's
  single ``MPI_Iallreduce``) that is pushed into a depth-l **in-flight
  queue** carried through ``lax.scan`` state and *read l iterations later*
  (the ``MPI_Wait`` of Alg. 3).  Nothing in body i consumes the freshly
  reduced payload, so XLA's latency-hiding scheduler / collective pipeliner
  is free to overlap the all-reduce with the l interleaved SPMVs -- the
  compiler-scheduled equivalent of asynchronous MPI progress.

``dot_local`` and ``reduce_payload`` are injected so the same engine drives:
  - the single-device path (dot = full dot, reduce = identity),
  - the shard_map distributed path (dot = local partial, reduce = one psum),
  - the Newton-pCG parameter-space path (flat parameter vectors).
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .precision import as_precision_policy
from .solver_cache import WeakCallableCache, weakly_callable
from .solver_cache import clear_solver_cache  # noqa: F401  (re-export)

BACKENDS = (None, "pallas", "ref", "fused")


class PLCGState(NamedTuple):
    Zw: jax.Array          # (n, l+1)  z_{i}   .. z_{i-l}     (slot 0 newest)
    Vw: jax.Array          # (n, 2l+1) v_{i-l} .. v_{i-3l}    (slot 0 newest)
    Zhw: jax.Array         # (n, 3) zhat window (preconditioned) or (1,1) dummy
    Gb: jax.Array          # (ncols, 2l+1) banded G, row c = band of column c
    gam: jax.Array         # (ncols,)
    dlt: jax.Array         # (ncols,)
    inflight: tuple        # in-flight reduction queue: (l, 2l+1) array
    #                        (blocking) or the comm policy's slot pytree
    #                        (overlap: scattered shards [+ gathered tail];
    #                        ring: (acc, circ) hop buffers)
    x: jax.Array           # (n,) current solution x_{i-l}
    p: jax.Array           # (n,) search direction p_{i-l}
    eta: jax.Array         # scalar eta_{i-l}
    zeta: jax.Array        # scalar zeta_{i-l}
    k_done: jax.Array      # TOTAL solution updates committed minus one
    done: jax.Array        # bool: converged or broken down (frozen)
    converged: jax.Array   # bool
    breakdown: jax.Array   # bool
    # ---- stability autopilot (in-scan restart / residual replacement) ----
    # constants when the machinery is disabled (restart/rr_period unset)
    ph: jax.Array          # int32 phase-local body counter (== loop index i
    #                        until the first restart re-zeroes it)
    wait: jax.Array        # int32 restart micro-state: 0 active, l+1 reseed
    #                        body, l..2 waiting for the reseed reduction,
    #                        1 seed body
    beta: jax.Array        # beta0 of the CURRENT phase (||r0||_M at the
    #                        most recent (re)start)
    sig_c: jax.Array       # (l,) per-lane shifts, Ritz-refreshed at restart
    #                        (0-d dummy unless stab && ritz_refresh)
    restarts: jax.Array    # int32 per-lane in-scan restarts taken
    repl: jax.Array        # int32 per-lane residual replacements taken
    since_rr: jax.Array    # int32 committed updates since last (re)seed


class PLCGOut(NamedTuple):
    x: jax.Array
    resnorms: jax.Array    # (iters,) |zeta_k| per body (0 where not computed)
    k_done: jax.Array
    converged: jax.Array
    breakdown: jax.Array
    committed: jax.Array   # (iters,) bool: body committed a solution update
    #                        (resnorms[committed] is the residual history in
    #                        order; robust to restarts scattering the rows)
    restarts: jax.Array    # in-scan restarts taken (0 on the legacy path)
    replacements: jax.Array  # residual replacements taken


def _default_dot(a, b):
    return jnp.vdot(a, b)


def plcg_scan(
    matvec: Callable,
    b: jax.Array,
    x0: Optional[jax.Array] = None,
    *,
    l: int,
    iters: int,
    sigma: Sequence[float],
    tol: float = 0.0,
    prec: Optional[Callable] = None,
    prec_diag=None,
    dot_local: Optional[Callable] = None,
    reduce_scalars: Optional[Callable] = None,
    exploit_symmetry: bool = True,
    unroll: int = 1,
    backend: Optional[str] = None,
    stencil_hw: Optional[tuple] = None,
    k_budget: Optional[jax.Array] = None,
    comm=None,
    restart: Optional[int] = None,
    rr_period: Optional[int] = None,
    ritz_refresh: bool = True,
    precision=None,
) -> PLCGOut:
    """Run ``iters`` bodies of p(l)-CG (solution index reaches iters-l-1).

    All shapes are static; convergence/breakdown freeze the state.  Works
    under jit / inside shard_map.  ``reduce_scalars(payload)`` performs the
    global sum of a stacked scalar payload (identity on a single device,
    ``psum`` in the distributed runtime) -- exactly one call per iteration.

    ``k_budget`` (optional, may be a traced scalar) freezes the state --
    without setting ``converged`` or ``breakdown`` -- once that many
    solution updates have been committed: restart drivers with a global
    iteration budget pass the *remaining* budget per sweep instead of
    recompiling a differently-sized scan.

    ``comm`` (optional) is a resolved ``repro.core.comm.CommRuntime``
    selecting how the per-iteration reduction is realized inside the
    depth-l queue: ``None`` keeps the blocking form (one fused
    ``reduce_scalars`` call per iteration); ``"overlap"`` splits it into
    ``comm.start`` (psum_scatter) at push and ``comm.finish``
    (all_gather) ``comm.depth`` iterations later, carrying scattered
    shard slots in the queue; ``"ring"`` replaces the all-reduce with
    circulate-accumulate ``ppermute`` hops applied while the queue
    shifts.  The total consumption delay stays EXACTLY l in every mode
    -- the recurrences finalize column i-l+1 from the dots of body i-l
    -- so the policy changes only which collective runs and where inside
    the l-body window it completes.  Only meaningful on the distributed
    path (``reduce_scalars`` injected); collectives still execute
    unconditionally on frozen lanes, matching the blocking psum.

    ``backend`` selects the implementation of the iteration hot path:

      * ``None``      -- inline jnp math (bit-exact legacy path);
      * ``"ref"``     -- the fused jnp oracles from ``kernels.ref`` for the
        (K4) window AXPY and (K5) multi-dot (CPU reference fallback);
      * ``"pallas"``  -- the per-kernel Pallas tier: one launch each for
        the (K4) AXPY and the two (K5) multi-dots (interpret mode on CPU);
      * ``"fused"``   -- the single-launch Pallas megakernel fusing the
        whole steady-state body: (K4) v/z/zhat recurrences + (K5) payload,
        and additionally the (K1) SPMV when ``stencil_hw`` marks the
        operator as the 2-D Poisson stencil.  A *diagonal* preconditioner
        (``prec_diag`` set -- the ``inv_diag`` hint of a structured
        ``Preconditioner``) folds into the same single launch (SPMV +
        diag apply + zhat recurrence in-kernel); a general ``prec``
        callable falls back to a 2-launch split (Pallas stencil SPMV,
        then the megakernel) when the stencil hint is present, or streams
        the externally computed t/t_hat into one launch otherwise.  Each
        basis vector is read from HBM exactly once per iteration;
      * ``"auto"``    -- ``"pallas"`` on TPU, ``"ref"`` elsewhere.

    The kernel path is only taken on the single-device full-vector dots
    (``dot_local is None``); the distributed shard_map runtime keeps its
    injected local-partial dots and single psum, bypassing every kernel
    tier including ``"fused"``.

    ``restart`` (optional int >= 0) enables IN-SCAN restart-on-breakdown
    (paper Remark 8 executed in-trace): a lane hitting square-root
    breakdown re-seeds its Krylov window from the current iterate --
    ``r = b - A x`` recomputed with the body's own SPMV, its M-norm
    riding one extra slot of the fused reduction payload, the window
    re-normalized exactly one queue delay (l bodies) later -- up to
    ``restart`` times per lane, with zero host round-trips.  Every lane
    (batched vmap, mesh shard, pooled) restarts independently; the
    per-iteration collective signature is unchanged (the payload widens
    from 2l+1 to 2l+2 inside the SAME reduction).  ``restart=0`` turns
    on the machinery (NaN-safe freeze, widened payload) without taking
    restarts.  ``rr_period`` (optional int >= 1) adds periodic residual
    replacement: every ``rr_period`` committed updates the lane re-seeds
    from the explicitly recomputed true residual through the same
    mechanism, resetting the rounding-error gap between the recursive
    and true residuals (arXiv:1706.05988 / 1804.02962).
    ``ritz_refresh`` (default True, only meaningful with the above)
    re-derives the l shifts at each re-seed from the Ritz values of the
    committed gamma/delta tridiagonal (Leja-ordered, Remark 3) instead
    of reusing the initial shift choice.

    ``precision`` (optional; anything ``as_precision_policy`` accepts)
    splits the state into a *storage* dtype -- the window arrays
    ``Zw``/``Vw``/``Zhw`` and the SPMV input/output stream, where the
    HBM traffic lives -- and a *compute* dtype carrying ALL scalar
    state: the gamma/delta/eta/zeta recurrences, the banded ``Gb``
    rows, the dot-product payloads and in-flight queue (hence every
    mesh collective buffer), ``x``/``p``, and the convergence/breakdown
    tests.  Casts happen at the window-write boundary only; the kernel
    tiers already load storage, accumulate in
    ``promote_types(storage, f32)`` and store back storage.  The
    default policy is bit-identical to the pre-policy engine.
    """
    if l < 1:
        raise ValueError("l must be >= 1")
    if restart is not None and int(restart) < 0:
        raise ValueError(f"restart must be >= 0, got {restart}")
    if rr_period is not None and int(rr_period) < 1:
        raise ValueError(f"rr_period must be >= 1, got {rr_period}")
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend not in BACKENDS:
        raise ValueError(
            "backend must be None, 'auto', 'pallas', 'ref' or 'fused', "
            f"got {backend!r}")
    use_fused = backend == "fused" and dot_local is None
    use_kernels = backend in ("pallas", "ref") and dot_local is None
    if use_kernels:
        from ..kernels.ops import multidot_apply, window_axpy_apply
        _pl = backend == "pallas"

        def _mdot(Wm, zz):
            return multidot_apply(Wm, zz, use_pallas=_pl).astype(zz.dtype)

        def _waxpy(Vm, zz, gg, gcc):
            return window_axpy_apply(Vm, zz, gg, gcc,
                                     use_pallas=_pl).astype(zz.dtype)
    if use_fused:
        from ..kernels import ops as kops
    dot = dot_local or _default_dot
    red = reduce_scalars or (lambda p: p)
    W = 2 * l + 1
    # precision policy: sdt = window/stream storage dtype, cdt = scalar
    # compute dtype.  Under the default policy both equal b.dtype and
    # every astype below is a no-op -- the graph is bit-identical to the
    # single-dtype engine.
    sdt, cdt = as_precision_policy(precision).resolve(b.dtype)
    # stability autopilot: in-scan restart / residual replacement enabled?
    stab = restart is not None or rr_period is not None
    restart_cap = int(restart) if restart is not None else 0
    rp = int(rr_period) if rr_period is not None else 0
    # the reduction payload grows by ONE slot carrying ||r_new||_M^2 of
    # re-seeding lanes (0 elsewhere) -- same collective, one wider band
    P = W + 1 if stab else W

    # ---- in-flight reduction queue (comm policy) -------------------------
    # queue_pop reads the head (the payload produced exactly l bodies ago)
    # plus, for split policies, the auxiliary value that must transit the
    # queue this body (the freshly gathered payload); queue_push shifts the
    # queue and inserts this body's payload at the tail.  Collectives live
    # ONLY inside these two closures, run unconditionally every body (the
    # freeze/convergence select gates the state commit, never the
    # collective), and the head-to-tail distance is l in every mode.
    if comm is None or comm.mode == "blocking":
        inflight0 = jnp.zeros((l, P), cdt)

        def queue_pop(q):
            return q[0], None

        def queue_push(q, payload, aux):
            del aux
            return jnp.concatenate([q[1:], red(payload)[None]], axis=0)
    elif comm.mode == "overlap":
        # scattered shards ride d slots, then (d < l) the gathered full
        # payload rides the remaining l-d: scatter at push, gather when
        # leaving the scattered stage -- the reduction is structurally in
        # flight for d bodies of local work (arXiv:1905.06850)
        d = comm.depth
        C = -(-P // comm.nshards)          # zero-padded chunk per shard

        def queue_pop(q):
            if d == l:
                return comm.finish(q[0][0], P), None
            return q[1][0], comm.finish(q[0][0], P)

        def queue_push(q, payload, aux):
            scat2 = jnp.concatenate([q[0][1:], comm.start(payload)[None]],
                                    axis=0)
            if d == l:
                return (scat2,)
            return (scat2, jnp.concatenate([q[1][1:], aux[None]], axis=0))

        inflight0 = ((jnp.zeros((d, C), cdt),) if d == l else
                     (jnp.zeros((d, C), cdt),
                      jnp.zeros((l - d, P), cdt)))
    else:                                   # ring
        # circulate-accumulate all-reduce spread across the queue shifts:
        # the element landing in slot j has completed l-1-j neighbor hops,
        # so the head (slot 0) is fully reduced iff l-1 >= len(schedule)
        # (validated at runtime construction) -- pure ppermute traffic,
        # no all-reduce primitive at all
        from .comm import ring_hop
        sched = comm.schedule

        def queue_pop(q):
            return q[0][0], None

        def queue_push(q, payload, aux):
            del aux
            acc, circ = q
            new_a, new_c = [], []
            for j in range(l - 1):
                a, cc = acc[j + 1], circ[j + 1]
                h = l - 1 - j               # hops completed once in slot j
                if 1 <= h <= len(sched):
                    a, cc = ring_hop(sched[h - 1], a, cc)
                new_a.append(a)
                new_c.append(cc)
            new_a.append(payload)
            new_c.append(payload)
            return jnp.stack(new_a), jnp.stack(new_c)

        inflight0 = (jnp.zeros((l, P), cdt),
                     jnp.zeros((l, P), cdt))

    x0 = jnp.zeros_like(b) if x0 is None else x0
    x0 = x0.astype(cdt)
    bC = b.astype(cdt)       # scalar-side view of b (init/reseed residuals)
    sig = jnp.asarray(list(sigma), dtype=cdt)
    ncols = iters + 2 * l + 2
    n = b.shape[0]
    # fused-tier dispatch on the preconditioner structure:
    #   fuse_diag    -- M^{-1} is a diagonal multiply (the inv_diag hint):
    #                   apply it in-kernel, staying at ONE launch/iteration;
    #   fuse_stencil -- the (K1) SPMV also runs in-kernel (stencil hint and
    #                   either no prec or a fused diagonal one);
    #   split_stencil-- general prec with a stencil hint: Pallas stencil
    #                   SPMV + megakernel, a 2-launch split.
    # With the stability autopilot the re-seed needs t_hat/t OUTSIDE the
    # kernel (the SPMV input switches to x on re-seeding lanes and the
    # true residual is assembled from t), so the fully fused SPMV and the
    # in-kernel diag apply are disabled: stencil operators take the
    # 2-launch split (Pallas stencil SPMV + megakernel) for every prec.
    fuse_diag = (use_fused and prec is not None and prec_diag is not None
                 and not stab)
    fuse_stencil = (use_fused and stencil_hw is not None
                    and (prec is None or fuse_diag) and not stab)
    split_stencil = (use_fused and stencil_hw is not None
                     and not fuse_stencil)
    if (fuse_stencil or split_stencil) and stencil_hw[0] * stencil_hw[1] != n:
        raise ValueError(f"stencil_hw {stencil_hw} inconsistent with n={n}")
    invd = None
    if fuse_diag:
        # the fused diag apply rides the storage stream (t = invd * t_hat
        # inside the kernel, f32 accumulation) -- storage dtype
        invd = jnp.asarray(prec_diag, sdt)
        if invd.ndim not in (0, 1) or (invd.ndim == 1
                                       and invd.shape[0] != n):
            raise ValueError(
                f"prec_diag must be a scalar or ({n},), got {invd.shape}")

    # ---- initialization (Alg. 2 lines 1-3) -------------------------------
    rhat0 = bC - matvec(x0).astype(cdt)
    r0 = prec(rhat0) if prec is not None else rhat0
    Mb = prec(bC) if prec is not None else bC
    init_pay = jnp.stack([dot(rhat0, r0), dot(bC, Mb)]).astype(cdt)
    init_pay = red(init_pay)
    beta0 = jnp.sqrt(init_pay[0])
    bnorm = jnp.sqrt(init_pay[1])
    bnorm = jnp.where(bnorm == 0, 1.0, bnorm)
    v0 = r0 / beta0

    Zw = jnp.zeros((n, l + 1), sdt).at[:, 0].set(v0.astype(sdt))
    Vw = jnp.zeros((n, W), sdt).at[:, 0].set(v0.astype(sdt))
    Zhw = (jnp.zeros((n, 3), sdt).at[:, 0].set((rhat0 / beta0).astype(sdt))
           if prec is not None else jnp.zeros((1, 1), sdt))
    Gb0 = jnp.zeros((ncols, W), cdt).at[0, 2 * l].set(1.0)
    use_ritz = stab and ritz_refresh
    state = PLCGState(
        Zw=Zw, Vw=Vw, Zhw=Zhw, Gb=Gb0,
        gam=jnp.zeros(ncols, cdt), dlt=jnp.zeros(ncols, cdt),
        inflight=inflight0,
        x=x0, p=jnp.zeros_like(x0),
        eta=jnp.asarray(0.0, cdt), zeta=jnp.asarray(0.0, cdt),
        k_done=jnp.asarray(-1), done=jnp.asarray(False),
        converged=jnp.asarray(False), breakdown=jnp.asarray(False),
        ph=jnp.asarray(0, jnp.int32), wait=jnp.asarray(0, jnp.int32),
        beta=beta0,
        sig_c=(sig if use_ritz else jnp.zeros((), cdt)),
        restarts=jnp.asarray(0, jnp.int32),
        repl=jnp.asarray(0, jnp.int32),
        since_rr=jnp.asarray(0, jnp.int32),
    )

    def gb_row(Gb, r):
        """Safe banded-G row read (negative rows -> zeros)."""
        row = jax.lax.dynamic_slice_in_dim(Gb, jnp.maximum(r, 0), 1, 0)[0]
        return jnp.where(r >= 0, row, jnp.zeros_like(row))

    def scalar_block(st: PLCGState, ph, c, col_in, sig_arr):
        """(K2)+(K3): finalize column c of G from the arrived payload
        ``col_in`` (the queue head popped by the caller) and update the
        gamma/delta recurrences.  O(l^2) scalar work; values are garbage
        during warmup (ph < l) and discarded by the caller's select,
        exactly like the legacy evaluate-both-phases body."""
        # -------- arrived payload = raw band of column c ------------------
        col = col_in
        # symmetric fill (eq. 14): rows c-2l+k, k<l, from earlier columns
        if exploit_symmetry:
            filled = []
            for k in range(l):
                r = c - 2 * l + k
                src = gb_row(st.Gb, c - l + k)[2 * l - k]
                use_fill = (ph >= 3 * l - 1) & (r >= 0)
                filled.append(jnp.where(use_fill, src, col[k]))
            col = jnp.concatenate([jnp.stack(filled), col[l:]])
        # -------- (K2) Gram-Schmidt correction (lines 7-8) ----------------
        rows = [gb_row(st.Gb, c - 2 * l + k) for k in range(l + 1, 2 * l)]
        col_list = [col[k] for k in range(W)]
        for k in range(l + 1, 2 * l):          # z-rows r = c-2l+k
            r = c - 2 * l + k
            grow = rows[k - (l + 1)]
            s = sum(grow[k2 - k + 2 * l] * col_list[k2] for k2 in range(k))
            denom = jnp.where(r >= 0, grow[2 * l], 1.0)
            corrected = (col_list[k] - s) / denom
            col_list[k] = jnp.where(r >= 0, corrected, col_list[k])
        arg = col_list[2 * l] - sum(col_list[k2] ** 2 for k2 in range(2 * l))
        # non-finite arg (a NaN/Inf-poisoned lane) IS a breakdown: `arg <= 0`
        # alone is False for NaN, which used to leave the lane neither
        # converging nor breaking down until the budget ran out
        brk = (arg <= 0.0) | jnp.logical_not(jnp.isfinite(arg))
        gcc = jnp.sqrt(jnp.maximum(arg, jnp.finfo(cdt).tiny))
        col_list[2 * l] = gcc
        col = jnp.stack(col_list)
        Gb2 = jax.lax.dynamic_update_slice_in_dim(st.Gb, col[None], c, 0)
        # -------- (K3) gamma_{c-1}, delta_{c-1} (lines 10-16) -------------
        rowm1 = gb_row(Gb2, c - 1)
        gd = rowm1[2 * l]                       # g_{c-1,c-1}
        g_cm1_c = col[2 * l - 1]                # g_{c-1,c}
        sub = jnp.where(c >= 2, rowm1[2 * l - 1]
                        * st.dlt[jnp.maximum(c - 2, 0)], 0.0)
        sig_c = sig_arr[jnp.clip(c - 1, 0, l - 1)]
        gam_lo = (g_cm1_c + sig_c * gd - sub) / gd
        dlt_lo = gcc / gd
        idx = jnp.maximum(c - 1 - l, 0)
        gam_hi = (gd * st.gam[idx] + g_cm1_c * st.dlt[idx] - sub) / gd
        dlt_hi = gcc * st.dlt[idx] / gd
        early = ph < 2 * l
        gam_c1 = jnp.where(early, gam_lo, gam_hi)
        dlt_c1 = jnp.where(early, dlt_lo, dlt_hi)
        gam2 = st.gam.at[jnp.maximum(c - 1, 0)].set(gam_c1)
        dlt2 = st.dlt.at[jnp.maximum(c - 1, 0)].set(dlt_c1)
        dsub = jnp.where(c >= 2, st.dlt[jnp.maximum(c - 2, 0)], 0.0)
        return col, gcc, brk, Gb2, gam2, dlt2, gam_c1, dlt_c1, dsub

    def solution_update(st: PLCGState, ph, gam2, v_k):
        """(K6) solution update (lines 22-31).  ``k_done`` counts TOTAL
        committed updates (minus one) across restart phases, so the
        committed count -- and the ``k_budget`` contract -- is global
        while ``k`` indexes the phase-local gamma/delta arrays."""
        k = ph - l
        at_first = ph == l
        eta0 = gam2[0]
        lam = jnp.where(at_first, 0.0, st.dlt[jnp.maximum(k - 1, 0)]
                        / jnp.where(st.eta == 0, 1.0, st.eta))
        dkm1 = st.dlt[jnp.maximum(k - 1, 0)]
        eta_k = jnp.where(at_first, eta0, gam2[jnp.maximum(k, 0)] - lam * dkm1)
        zeta_k = jnp.where(at_first, st.beta if stab else beta0,
                           -lam * st.zeta)
        x2 = jnp.where(at_first, st.x, st.x + st.zeta * st.p)
        eta_safe = jnp.where(eta_k == 0, 1.0, eta_k)
        p2 = jnp.where(at_first, v_k / eta_safe,
                       (v_k - dkm1 * st.p) / eta_safe)
        return x2, p2, eta_k, zeta_k, st.k_done + 1

    def finalize(st: PLCGState, ph, payload, q_aux, brk, x2, p2, eta2, zeta2,
                 k2, Vw2, Zw2, Zhw2, Gb2, gam2, dlt2, *, reseed_now=None,
                 seed_now=None, beta_new=None, seed_ok=None, beta2=None):
        """Queue push + convergence/freeze commit, shared by both bodies.

        With the stability autopilot the classical commit select is
        followed by explicit per-lane overlays that drive the restart
        micro-state machine: a scheduled lane runs one RESEED body (SPMV
        redirected to x, true residual stashed into the zeroed windows,
        its M-norm pushed in the extra payload slot), waits l-1 bodies
        for that reduction to transit the queue, then runs one SEED body
        (windows normalized by the arrived beta, phase counter back to
        1) -- after which the lane is bit-for-bit a fresh solve started
        at x, sharing every collective with its still-active neighbors.
        """
        inflight2 = queue_push(st.inflight, payload, q_aux)
        # NaN/Inf-safe breakdown: a non-finite zeta fails BOTH the old
        # convergence and breakdown predicates, silently spending the
        # whole budget -- treat it as a breakdown of this body
        brk2 = brk | ((ph >= l) & jnp.logical_not(jnp.isfinite(zeta2)))
        if stab:
            active = (st.wait == 0) & jnp.logical_not(st.done)
        else:
            active = jnp.logical_not(st.done)
        commit = active & jnp.logical_not(brk2)
        conv_now = commit & (ph >= l) & (jnp.abs(zeta2) <= tol * bnorm)
        # budget freeze: k2 + 1 updates are committed after this body
        spent = (jnp.asarray(False) if k_budget is None
                 else k2 + 1 >= k_budget)
        if stab:
            can_restart = st.restarts < restart_cap
            want_restart = brk2 & active & can_restart & ~spent
            committed_update = commit & (ph >= l)
            rr_due = (committed_update & (st.since_rr + 1 >= rp)
                      & ~conv_now & ~spent) if rp > 0 else jnp.asarray(False)
            schedule = want_restart | rr_due
            # the seed body's re-seeded residual norm doubles as a
            # convergence / hard-failure probe: beta == 0 at tolerance
            # means x is (numerically) exact, non-finite beta means the
            # lane is unrecoverable
            seed_conv = (seed_now & jnp.isfinite(beta2)
                         & (jnp.sqrt(jnp.maximum(beta2, 0.0)) <= tol * bnorm))
            seed_fail = seed_now & ~seed_ok & ~seed_conv
            brk_term = brk2 & active & ~want_restart
            conv_now = conv_now | seed_conv
        else:
            want_restart = rr_due = seed_fail = jnp.asarray(False)
            brk_term = brk2 & active
            committed_update = commit & (ph >= l)
        done_o = st.done | brk_term | conv_now | (spent & active) | seed_fail
        converged_o = st.converged | conv_now
        breakdown_o = st.breakdown | brk_term | seed_fail
        new = PLCGState(
            Zw=Zw2, Vw=Vw2, Zhw=Zhw2, Gb=Gb2, gam=gam2, dlt=dlt2,
            inflight=inflight2, x=x2, p=p2, eta=eta2, zeta=zeta2,
            k_done=k2, done=done_o, converged=converged_o,
            breakdown=breakdown_o,
            # stab fields pass through the commit select untouched (same
            # value on both sides); their real updates are overlaid below
            ph=st.ph, wait=st.wait, beta=st.beta, sig_c=st.sig_c,
            restarts=st.restarts, repl=st.repl, since_rr=st.since_rr,
        )
        out = jax.tree.map(
            lambda a_new, a_old: jnp.where(commit, a_new, a_old), new,
            st._replace(done=done_o, converged=converged_o,
                        breakdown=breakdown_o))
        if stab:
            reseed_or_seed = reseed_now | seed_now
            zcol = jnp.zeros(ncols, cdt)
            out = out._replace(
                # re-seeding lanes bypass the commit mask: the stashed /
                # seeded windows (already selected in the body) land, the
                # banded G and the recurrences reset to the init state
                Zw=jnp.where(reseed_or_seed, Zw2, out.Zw),
                Vw=jnp.where(reseed_or_seed, Vw2, out.Vw),
                Zhw=(jnp.where(reseed_or_seed, Zhw2, out.Zhw)
                     if prec is not None else out.Zhw),
                Gb=jnp.where(reseed_now, Gb0, out.Gb),
                gam=jnp.where(reseed_now, zcol, out.gam),
                dlt=jnp.where(reseed_now, zcol, out.dlt),
                p=jnp.where(reseed_now, jnp.zeros_like(st.p), out.p),
                eta=jnp.where(reseed_now, 0.0, out.eta),
                zeta=jnp.where(reseed_now, 0.0, out.zeta),
                # the queue ALWAYS shifts: the re-seed reduction must
                # transit it, and frozen lanes only ever push into it
                inflight=inflight2,
                wait=jnp.where(reseed_now, l,
                               jnp.where(seed_now, 0,
                                         jnp.where(st.wait > 1, st.wait - 1,
                                                   jnp.where(schedule, l + 1,
                                                             0)))
                               ).astype(st.wait.dtype),
                # the seed body IS body 0 of the new phase
                ph=jnp.where(seed_now, 1,
                             jnp.where(commit, ph + 1, ph)
                             ).astype(st.ph.dtype),
                beta=jnp.where(seed_now, beta_new, st.beta),
                restarts=st.restarts + want_restart.astype(st.restarts.dtype),
                repl=st.repl + rr_due.astype(st.repl.dtype),
                since_rr=jnp.where(seed_now, 0,
                                   st.since_rr
                                   + committed_update.astype(st.since_rr.dtype)
                                   ).astype(st.since_rr.dtype),
            )
            if use_ritz:
                # Ritz-refresh the shifts from the tail of the COMMITTED
                # tridiagonal of the phase that just ended (harvested at
                # the reseed body, before gamma/delta reset): Leja-ordered
                # eigenvalues of the MR x MR trailing block (Remark 3)
                from .shifts import leja_order, ritz_values_from_tridiag
                MR = min(max(4, 2 * l), ncols)
                m = ph - l                    # committed columns this phase
                lo = jnp.clip(m - MR, 0, ncols - MR)
                gw = jax.lax.dynamic_slice_in_dim(st.gam, lo, MR)
                dw = jax.lax.dynamic_slice_in_dim(st.dlt, lo, MR)
                okr = (reseed_now & (m >= MR)
                       & jnp.all(jnp.isfinite(gw)) & jnp.all(jnp.isfinite(dw)))
                gw = jnp.where(okr, gw, 1.0)   # sanitized -> T = I
                dw = jnp.where(okr, dw, 0.0)
                sig_new = leja_order(ritz_values_from_tridiag(gw, dw), l)
                out = out._replace(
                    sig_c=jnp.where(okr, sig_new.astype(cdt), st.sig_c))
        res = jnp.where(committed_update, jnp.abs(zeta2), 0.0)
        return out, (res, committed_update)

    def stab_ctx(st: PLCGState, i):
        """Per-body restart micro-state: phase counter, reseed/seed masks,
        and the SPMV input (redirected to x on the reseed body so the
        body's ONE operator apply recomputes the true residual)."""
        if not stab:
            return (i, jnp.asarray(False), jnp.asarray(False), st.Zw[:, 0],
                    sig)
        reseed_now = st.wait == l + 1
        seed_now = st.wait == 1
        spmv_in = jnp.where(reseed_now, st.x, st.Zw[:, 0])
        sig_arr = st.sig_c if use_ritz else sig
        return st.ph, reseed_now, seed_now, spmv_in, sig_arr

    def stab_seed(st: PLCGState, t, t_hat, col_in_full, reseed_now, seed_now,
                  sig_arr):
        """Reseed stash + seed re-normalization values (stab only).

        Reseed body: t_hat = A x, so the true residual is rhat = b - t_hat
        and its preconditioned twin r = M b - t by linearity -- zero extra
        operator/preconditioner applies.  The windows are stashed with the
        UN-normalized residual; its M-norm^2 rides payload slot W through
        the same reduction as every other dot and arrives -- like any
        payload -- exactly l bodies later, at the seed body, which
        normalizes the stash into the init-state windows of a fresh solve
        started at x.
        """
        rhat_new = bC - t_hat.astype(cdt)
        r_new = (Mb - t.astype(cdt)) if prec is not None else rhat_new
        slotW = jnp.where(reseed_now, dot(rhat_new, r_new).astype(cdt),
                          jnp.asarray(0.0, cdt))
        beta2 = col_in_full[W]
        seed_ok = (beta2 > 0) & jnp.isfinite(beta2)
        beta_new = jnp.sqrt(jnp.where(seed_ok, beta2, 1.0))
        inv_b = 1.0 / beta_new
        # seed body: the stash held r_new in Zw slot 0 (rhat_new in Zhw),
        # and this body's SPMV ran on it, so t/t_hat are beta * (M)A v0
        v0n = st.Zw[:, 0] * inv_b
        s0 = sig_arr[0]
        zn_seed = t * inv_b - s0 * v0n
        Zw_sd = (jnp.zeros_like(st.Zw).at[:, 0].set(zn_seed.astype(sdt))
                 .at[:, 1].set(v0n.astype(sdt)))
        Vw_sd = jnp.zeros_like(st.Vw).at[:, 0].set(v0n.astype(sdt))
        Zw_st = jnp.zeros_like(st.Zw).at[:, 0].set(r_new.astype(sdt))
        Vw_st = jnp.zeros_like(st.Vw)
        if prec is not None:
            zh0n = st.Zhw[:, 0] * inv_b
            zhn_seed = t_hat * inv_b - s0 * zh0n
            Zhw_sd = (jnp.zeros_like(st.Zhw).at[:, 0]
                      .set(zhn_seed.astype(sdt))
                      .at[:, 1].set(zh0n.astype(sdt)))
            Zhw_st = jnp.zeros_like(st.Zhw).at[:, 0].set(rhat_new.astype(sdt))
        else:
            Zhw_sd = Zhw_st = None

        def sel3(seeded, stash, normal):
            return jnp.where(seed_now, seeded,
                             jnp.where(reseed_now, stash, normal))

        return (slotW, beta2, seed_ok, beta_new, sel3,
                (Vw_sd, Zw_sd, Zhw_sd), (Vw_st, Zw_st, Zhw_st))

    def body(st: PLCGState, i):
        ph, reseed_now, seed_now, spmv_in, sig_arr = stab_ctx(st, i)
        # ---------------- (K1) SPMV --------------------------------------
        # SPMV arithmetic runs in the compute dtype (on a mesh this keeps
        # halo-exchange payloads cdt); the resulting t / t_hat STREAMS
        # are storage-dtype, rounded once -- exactly what the fused
        # megakernel tier stores.  Identity casts under the default policy.
        t_hat = matvec(spmv_in.astype(cdt)).astype(sdt)
        t = prec(t_hat).astype(sdt) if prec is not None else t_hat
        # pop AFTER the SPMV + shard-local preconditioner apply in trace
        # order: with a split comm policy the head-of-queue gather is
        # issued here with no data dependence on t, so the prec apply is
        # free to overlap the in-flight reduction (paper Remark 13)
        col_in, q_aux = queue_pop(st.inflight)
        col_in_full, col_in = col_in, (col_in[:W] if stab else col_in)

        c = ph - l + 1                      # column being finalized

        def warmup(_):
            s = sig_arr[jnp.minimum(ph, l - 1)]
            znew = t - s * st.Zw[:, 0]
            zhnew = (t_hat - s * st.Zhw[:, 0]) if prec is not None else None
            return (st.Vw, st.Gb, st.gam, st.dlt, znew, zhnew,
                    jnp.asarray(False), st.x, st.p, st.eta, st.zeta,
                    st.k_done)

        def steady(_):
            (col, gcc, brk, Gb2, gam2, dlt2, gam_c1, dlt_c1,
             dsub) = scalar_block(st, ph, c, col_in, sig_arr)
            # -------- (K4) v recurrence (line 17) -------------------------
            # v_c = (z_c - sum_k col[k] v_{c-2l+k}) / gcc ;
            # v_{c-2l+k} = Vw[:, 2l-1-k]
            if use_kernels:
                vnew = _waxpy(st.Vw[:, :2 * l], st.Zw[:, l - 1],
                              col[:2 * l][::-1], gcc)
            else:
                vsum = st.Vw[:, :2 * l] @ col[:2 * l][::-1]
                vnew = (st.Zw[:, l - 1] - vsum) / gcc
            Vw2 = jnp.concatenate([vnew.astype(sdt)[:, None],
                                   st.Vw[:, :-1]], axis=1)
            # -------- (K4) z recurrence (line 18) -------------------------
            znew = (t - gam_c1 * st.Zw[:, 0] - dsub * st.Zw[:, 1]) / dlt_c1
            zhnew = ((t_hat - gam_c1 * st.Zhw[:, 0] - dsub * st.Zhw[:, 1])
                     / dlt_c1 if prec is not None else None)
            # -------- (K6) solution update (lines 22-31) ------------------
            x2, p2, eta_k, zeta_k, k2 = solution_update(st, ph, gam2,
                                                        Vw2[:, 1])
            return (Vw2, Gb2, gam2, dlt2, znew, zhnew, brk,
                    x2, p2, eta_k, zeta_k, k2)

        # compute both phases and select on the (scalar) iteration index:
        # an actual lax.cond here lowers to an XLA Conditional whose branch
        # layouts clash with the matvec dot on the CPU thunk runtime when
        # the engine runs under vmap (batched multi-RHS); warmup is two
        # AXPYs so evaluating it alongside steady costs nothing, and the
        # discarded branch's values (incl. div-by-zero garbage during the
        # first l iterations) are dropped by the select
        (Vw2, Gb2, gam2, dlt2, znew, zhnew, brk, x2, p2, eta2, zeta2,
         k2) = jax.tree.map(
            functools.partial(jnp.where, ph >= l), steady(None), warmup(None))

        Zw2 = jnp.concatenate([znew.astype(sdt)[:, None],
                               st.Zw[:, :-1]], axis=1)
        Zhw2 = (jnp.concatenate([zhnew.astype(sdt)[:, None],
                                 st.Zhw[:, :-1]], axis=1)
                if prec is not None else st.Zhw)
        # payload dots consume the pre-rounding compute-dtype lhs; only
        # the stored window is quantized to sdt
        lhs = zhnew if prec is not None else znew
        seed_kw = {}
        ph_pay = ph
        if stab:
            (slotW, beta2, seed_ok, beta_new, sel3, seeded,
             stash) = stab_seed(st, t, t_hat, col_in_full, reseed_now,
                                seed_now, sig_arr)
            # window selection BEFORE the payload dots so re-seeding lanes
            # push dots of the stashed/seeded windows through the shared
            # reduction (the seed body's payload IS fresh body 0's)
            Vw2 = sel3(seeded[0], stash[0], Vw2)
            Zw2 = sel3(seeded[1], stash[1], Zw2)
            if prec is not None:
                Zhw2 = sel3(seeded[2], stash[2], Zhw2)
            lhs = (Zhw2[:, 0] if prec is not None else Zw2[:, 0]).astype(cdt)
            ph_pay = jnp.where(seed_now, 0, ph)
            seed_kw = dict(reseed_now=reseed_now, seed_now=seed_now,
                           beta_new=beta_new, seed_ok=seed_ok, beta2=beta2)
        # ---------------- (K5) dot-product payload for column i+1 --------
        if exploit_symmetry:
            def vdots_full(_):
                if use_kernels:
                    return _mdot(Vw2[:, :l + 1], lhs)
                return lhs @ Vw2[:, :l + 1]

            def vdots_one(_):
                out = jnp.zeros(l + 1, cdt)
                return out.at[0].set(dot(Vw2[:, 0], lhs).astype(cdt))

            vd = jax.lax.cond(ph_pay < 2 * l - 1, vdots_full, vdots_one, None)
        elif use_kernels:
            vd = _mdot(Vw2[:, :l + 1], lhs)
        else:
            vd = jnp.stack([dot(Vw2[:, j], lhs) for j in range(l + 1)])
        if use_kernels:
            zd = _mdot(Zw2[:, :l], lhs)
        else:
            zd = jnp.stack([dot(Zw2[:, j], lhs) for j in range(l)])
        # mask payload slots whose row index i+1-2l+k is negative (the v
        # window is zero-initialized except v_0, which must not leak into
        # nonexistent rows during warmup)
        vmask = jnp.arange(l + 1) + (ph_pay + 1 - 2 * l) >= 0
        payload = jnp.concatenate([vd[::-1] * vmask, zd[::-1]])  # band layout
        if stab:
            payload = jnp.concatenate([payload, slotW[None]])
        return finalize(st, ph, payload, q_aux, brk, x2, p2, eta2, zeta2, k2,
                        Vw2, Zw2, Zhw2, Gb2, gam2, dlt2, **seed_kw)

    def body_fused(st: PLCGState, i):
        """One launch per iteration: the fused_body megakernel computes
        (K1 when the stencil is fused) + (K4) + (K5); only the O(l^2)
        scalar recurrences (K2/K3/K6) stay in jnp.  With the stability
        autopilot the SPMV and preconditioner run OUTSIDE the kernel (the
        re-seed needs t/t_hat to assemble the true residual) and the
        payload dots are recomputed from the re-seed-selected windows --
        a documented small overhead of restart-enabled fused sweeps."""
        ph, reseed_now, seed_now, spmv_in, sig_arr = stab_ctx(st, i)
        c = ph - l + 1
        col_in, q_aux = queue_pop(st.inflight)
        col_in_full, col_in = col_in, (col_in[:W] if stab else col_in)
        (col, gcc, brk, Gb2, gam2, dlt2, gam_c1, dlt_c1,
         dsub) = scalar_block(st, ph, c, col_in, sig_arr)
        if fuse_stencil:
            # in-kernel SPMV (+ in-kernel diag apply when preconditioned)
            t = t_hat = None
        elif split_stencil:
            # stencil hint without full fusion: (K1) as the Pallas stencil
            # kernel (launch 1 of the 2-launch split), prec applied
            # between the launches
            H2d, W2d = stencil_hw
            z2d = spmv_in.reshape(H2d, W2d)
            zr = jnp.zeros_like
            t_hat = kops.stencil2d_apply(
                z2d, zr(z2d[0]), zr(z2d[0]), zr(z2d[:, 0]), zr(z2d[:, 0]),
                use_pallas=True).reshape(-1)
            t = prec(t_hat).astype(sdt) if prec is not None else t_hat
        else:
            # compute-dtype SPMV, storage-dtype streams (see body())
            t_hat = matvec(spmv_in.astype(cdt)).astype(sdt)
            if prec is None:
                t = t_hat
            elif fuse_diag:
                t = None            # the kernel applies invd to t_hat
            else:
                t = prec(t_hat).astype(sdt)
        Vw2, Zw2, Zhw2k, dots = kops.fused_body_apply(
            st.Vw, st.Zw, st.Zhw if prec is not None else None,
            t, t_hat if prec is not None else None,
            l=l, steady=ph >= l, s_warm=sig_arr[jnp.minimum(ph, l - 1)],
            gam=gam_c1, dlt=dlt_c1, dsub=dsub, gcc=gcc,
            g=col[:2 * l][::-1], invd=invd,
            stencil_hw=stencil_hw if fuse_stencil else None,
            use_pallas=True)
        Zhw2 = Zhw2k if prec is not None else st.Zhw
        dots = dots.astype(cdt)
        vd_full, zd = dots[:l + 1], dots[l + 1:]
        x2, p2, eta_k, zeta_k, k2 = solution_update(st, ph, gam2, Vw2[:, 1])
        # warmup select for the scalar state only -- the vector windows
        # were already phase-selected inside the kernel
        (Gb2, gam2, dlt2, brk, x2, p2, eta2, zeta2, k2) = jax.tree.map(
            functools.partial(jnp.where, ph >= l),
            (Gb2, gam2, dlt2, brk, x2, p2, eta_k, zeta_k, k2),
            (st.Gb, st.gam, st.dlt, jnp.asarray(False), st.x, st.p,
             st.eta, st.zeta, st.k_done))
        seed_kw = {}
        ph_pay = ph
        if stab:
            (slotW, beta2, seed_ok, beta_new, sel3, seeded,
             stash) = stab_seed(st, t, t_hat, col_in_full, reseed_now,
                                seed_now, sig_arr)
            Vw2 = sel3(seeded[0], stash[0], Vw2)
            Zw2 = sel3(seeded[1], stash[1], Zw2)
            if prec is not None:
                Zhw2 = sel3(seeded[2], stash[2], Zhw2)
            # recompute the payload from the selected windows: the
            # in-kernel dots saw the pre-selection windows
            lhs = (Zhw2[:, 0] if prec is not None else Zw2[:, 0]).astype(cdt)
            vd_full = lhs @ Vw2[:, :l + 1]
            zd = lhs @ Zw2[:, :l]
            ph_pay = jnp.where(seed_now, 0, ph)
            seed_kw = dict(reseed_now=reseed_now, seed_now=seed_now,
                           beta_new=beta_new, seed_ok=seed_ok, beta2=beta2)
        if exploit_symmetry:
            # mirror the legacy single-dot branch: beyond the startup
            # phase only <v_{i+1-2l}, z> is new, the rest comes from the
            # symmetric fill of (K2)
            vd = jnp.where(ph_pay < 2 * l - 1, vd_full,
                           jnp.zeros_like(vd_full).at[0].set(vd_full[0]))
        else:
            vd = vd_full
        vmask = jnp.arange(l + 1) + (ph_pay + 1 - 2 * l) >= 0
        payload = jnp.concatenate([vd[::-1] * vmask, zd[::-1]])
        if stab:
            payload = jnp.concatenate([payload, slotW[None]])
        return finalize(st, ph, payload, q_aux, brk, x2, p2, eta2, zeta2, k2,
                        Vw2, Zw2, Zhw2, Gb2, gam2, dlt2, **seed_kw)

    final, (resnorms, committed) = jax.lax.scan(
        body_fused if use_fused else body, state,
        jnp.arange(iters), unroll=unroll)
    return PLCGOut(x=final.x, resnorms=resnorms, k_done=final.k_done,
                   converged=final.converged, breakdown=final.breakdown,
                   committed=committed, restarts=final.restarts,
                   replacements=final.repl)


def plcg_jit(matvec, b, x0=None, *, l, iters, sigma, tol=0.0, prec=None,
             prec_diag=None, exploit_symmetry: bool = True, unroll: int = 1,
             backend: Optional[str] = None,
             stencil_hw: Optional[tuple] = None,
             restart: Optional[int] = None,
             rr_period: Optional[int] = None,
             ritz_refresh: bool = True, precision=None) -> PLCGOut:
    """Convenience jitted single-device entry point."""
    fn = functools.partial(
        plcg_scan, matvec, l=l, iters=iters, sigma=tuple(sigma), tol=tol,
        prec=prec, prec_diag=prec_diag,
        exploit_symmetry=exploit_symmetry, unroll=unroll,
        backend=backend, stencil_hw=stencil_hw,
        restart=restart, rr_period=rr_period, ritz_refresh=ritz_refresh,
        precision=precision)
    return jax.jit(lambda bb, xx: fn(bb, xx))(b, x0 if x0 is not None
                                              else jnp.zeros_like(b))


def stab_iter_slack(l: int, restart=None, rr_period=None,
                    maxiter: int = 0) -> int:
    """Extra scan bodies needed so a ``maxiter``-update budget stays
    spendable despite re-seed dead bodies: each restart / residual
    replacement event costs at most 2l+2 bodies that commit nothing
    (the triggering body, the reseed body, l-1 waiting bodies, the seed
    body, and the l-1 new warmup bodies overlap this bound)."""
    slack = 0
    if restart:
        slack += int(restart) * (2 * l + 2)
    if rr_period and maxiter:
        slack += (int(maxiter) // int(rr_period)) * (2 * l + 2)
    return slack


#: Jitted single-RHS sweeps, keyed weakly on the operator/preconditioner
#: callables (see solver_cache): dropping the operator releases the
#: compiled sweep instead of pinning it until 16 other configs evict it.
_SWEEP_CACHE = WeakCallableCache(maxsize=16)


def _jitted_sweep(matvec, l, iters, sigma, tol, prec, exploit_symmetry,
                  unroll, backend, stencil_hw, restart=None, rr_period=None,
                  ritz_refresh=True, precision=None, bindable=False):
    """Cached jitted single sweep so repeated solves with the same
    operator/settings compile once.  Keyed on ``matvec``/``prec`` object
    identity through weak references: reuse the same callable across calls
    to benefit (a fresh closure per call compiles, is cached until its
    closure dies, then is evicted -- no unbounded retention).

    The returned callable takes ``(b, x0, k_budget)``: the budget is a
    traced operand, so restart sweeps with shrinking budgets reuse the
    one compiled program.

    ``bindable=True`` interprets ``matvec`` as a two-argument
    ``matvec_ctx(context, v)`` (see :class:`~repro.core.linop.
    BindableOperator`) and the returned callable takes
    ``(context, b, x0, k_budget)``: the context pytree is a TRACED
    leading operand, so rebinding operator data (new parameters, new
    batch) between outer steps reuses the one compiled program.
    """

    def build():
        mv = weakly_callable(matvec)
        kwargs = dict(
            l=l, iters=iters, sigma=sigma, tol=tol,
            prec=weakly_callable(prec),
            # fusion hint of a structured Preconditioner (None for bare
            # callables); the captured array does not pin the object
            prec_diag=getattr(prec, "inv_diag", None),
            exploit_symmetry=exploit_symmetry, unroll=unroll,
            backend=backend, stencil_hw=stencil_hw,
            restart=restart, rr_period=rr_period, ritz_refresh=ritz_refresh,
            precision=precision)
        if bindable:
            return jax.jit(lambda ctx, bb, xx, kb: plcg_scan(
                lambda v: mv(ctx, v), bb, xx, k_budget=kb, **kwargs))
        fn = functools.partial(plcg_scan, mv, **kwargs)
        return jax.jit(lambda bb, xx, kb: fn(bb, xx, k_budget=kb))

    return _SWEEP_CACHE.get_or_build(
        (matvec, prec),
        (l, iters, sigma, tol, exploit_symmetry, unroll, backend,
         stencil_hw, restart, rr_period, ritz_refresh,
         as_precision_policy(precision), bindable),
        build)


def run_restart_driver(sweep, b, x0, *, tol: float, maxiter: int,
                       max_restarts: int, bnorm: float,
                       in_scan: bool = False):
    """Restart-on-breakdown with a global iteration budget (paper
    Remark 8), shared by the single-device and mesh drivers -- the ONE
    place restart semantics (budget accounting, happy breakdown,
    info packaging) is defined.

    ``in_scan=True`` (the default execution mode of the engine front
    ends) runs ONE sweep that was built with ``restart=``/``rr_period=``
    -- breakdown recovery happens per lane inside the compiled scan
    (Ritz-refreshed shifts, zero host round-trips) and this wrapper only
    unpacks the result.  ``sweep(b, x, budget)`` must then return
    ``(x, resnorms, converged, breakdown, k_done, committed, restarts,
    replacements)``.

    ``in_scan=False`` is the legacy host loop retained for parity
    testing and as a compatibility escape hatch: the sweep is re-entered
    from the host after each breakdown with the *remaining* budget.
    .. deprecated:: its shift-free re-init (the restarted sweep reuses
       the original sigma instead of Ritz-refreshing) and its
       single-RHS-only reach are superseded by the in-scan path.
    ``sweep`` returns at least ``(x, resnorms, converged, breakdown,
    k_done)``; extra trailing outputs are ignored.

    Either way a breakdown-looping system performs at most ``maxiter``
    updates in total (not ``max_restarts x maxiter``); happy breakdown
    at tolerance counts as convergence.  Returns
    ``(x, resnorms list, info dict)``.
    """
    if in_scan:
        (x, resn, conv, brk, k_done, committed, n_restarts,
         n_repl) = sweep(b, x0, maxiter)
        mask = np.asarray(committed, dtype=bool)
        resnorms = [float(r) for r in np.asarray(resn)[mask]]
        converged = bool(conv)
        breakdown = bool(brk)
        if (not converged and breakdown and resnorms
                and resnorms[-1] <= 4 * tol * bnorm):
            converged = True              # happy breakdown at tolerance
        return x, resnorms, {
            "converged": converged,
            "breakdowns": int(n_restarts) + int(breakdown),
            "restarts": int(n_restarts),
            "replacements": int(n_repl),
            "iterations": int(k_done) + 1,
        }
    x = x0
    # every (re-)entry must present the SAME placement to hit one
    # compiled program: a restart re-enters with the previous sweep's
    # OUTPUT -- committed, and on a mesh operator-sharded -- while x0's
    # placement is whatever the caller chose, and both committedness
    # and sharding key the jit cache.  Pin every entry to x0's sharding,
    # but ONLY when x0 is itself committed: an uncommitted x0 (host-
    # built zeros) has a default single-device sharding that is not an
    # intended placement, and committing x to it would conflict with a
    # mesh sweep's shard_map
    x0_sharding = (getattr(x0, "sharding", None)
                   if getattr(x0, "_committed", False) else None)
    resnorms: list[float] = []
    restarts = breakdowns = 0
    total_k = 0
    converged = False
    while total_k < maxiter:
        remaining = maxiter - total_k
        if x0_sharding is not None:
            import jax
            x = jax.device_put(x, x0_sharding)
        x, resn, conv, brk, k_done = sweep(b, x, remaining)[:5]
        resnorms.extend(float(r) for r in np.asarray(resn) if r > 0)
        total_k += max(int(k_done) + 1, 1)
        if bool(conv):
            converged = True
            break
        if bool(brk):
            breakdowns += 1
            if resnorms and resnorms[-1] <= 4 * tol * bnorm:
                converged = True          # happy breakdown at tolerance
                break
            if restarts >= max_restarts:
                break
            restarts += 1
            continue
        break                             # iteration budget exhausted
    return x, resnorms, {
        "converged": converged, "breakdowns": breakdowns,
        "restarts": restarts, "replacements": 0, "iterations": total_k,
    }


def plcg_solve(matvec, b, x0=None, *, l, sigma, tol=1e-8, maxiter=1000,
               prec=None, exploit_symmetry: bool = True, max_restarts: int = 5,
               unroll: int = 1, backend: Optional[str] = None,
               stencil_hw: Optional[tuple] = None, sweep=None,
               restart: Optional[int] = None,
               residual_replacement: Optional[int] = None,
               ritz_refresh: bool = True, precision=None, context=None):
    """Driver around the jitted engine: explicit restart on square-root
    breakdown (paper Remark 8), happy-breakdown detection, and a GLOBAL
    iteration budget across restart sweeps (via the sweep's ``k_budget``
    operand -- one compiled program regardless of restarts).

    ``restart``/``residual_replacement`` (either not None) switch to the
    IN-SCAN stability path: one sweep whose lanes re-seed themselves on
    breakdown (up to ``restart`` times, shifts Ritz-refreshed unless
    ``ritz_refresh=False``) and/or every ``residual_replacement``
    committed updates; ``max_restarts`` is ignored there.  With both
    None the legacy host restart loop runs (see ``run_restart_driver``).

    ``sweep`` (optional) is a pre-built jitted ``(b, x0, k_budget)``
    sweep -- a prepared ``repro.core.session.Solver`` passes the one it
    holds strongly, so the per-call weak-cache lookup (and any rebuild)
    is skipped; it must have been built with the same
    tol/sigma/backend/restart configuration and enough ``iters``
    (``maxiter + l + 1`` plus ``stab_iter_slack`` on the in-scan path).

    ``context`` (optional) switches to the bindable-operator protocol:
    ``matvec`` is then a two-argument ``matvec_ctx(context, v)`` and the
    context pytree is threaded through the jitted sweep as a traced
    operand (no retrace when it is rebound between solves).

    Returns (x, resnorms, info dict).
    """
    x0 = jnp.zeros_like(b) if x0 is None else x0
    bnorm = float(jnp.linalg.norm(b))
    if bnorm == 0:
        bnorm = 1.0
    in_scan = restart is not None or residual_replacement is not None
    iters = maxiter + l + 1 + stab_iter_slack(
        l, restart, residual_replacement, maxiter)
    fn = sweep if sweep is not None else _jitted_sweep(
        matvec, l, iters, tuple(sigma), tol, prec,
        exploit_symmetry, unroll, backend, stencil_hw,
        restart=restart, rr_period=residual_replacement,
        ritz_refresh=ritz_refresh, precision=precision,
        bindable=context is not None)
    if context is not None and sweep is None:
        raw = fn
        fn = lambda bb, xx, kb: raw(context, bb, xx, kb)  # noqa: E731

    def run_sweep(bb, xx, remaining):
        out = fn(bb, xx, remaining)
        return (out.x, out.resnorms, out.converged, out.breakdown,
                out.k_done, out.committed, out.restarts, out.replacements)

    return run_restart_driver(run_sweep, b, x0, tol=tol, maxiter=maxiter,
                              max_restarts=max_restarts, bnorm=bnorm,
                              in_scan=in_scan)
