"""Shift choices for the auxiliary Krylov basis Z (paper Remark 3, eq. (8)).

The auxiliary basis vectors are ``z_j = P_l(A) v_{j-l}`` with
``P_l(t) = prod_{j<l} (t - sigma_j)``.  The conditioning of the basis
transformation matrix G -- and hence the attainable accuracy of p(l)-CG
(Sec. 4.2, Lemma 15) -- is governed by ``||P_l(A)||``, which is minimized
over intervals ``[lmin, lmax]`` containing the spectrum by the roots of the
degree-l Chebyshev polynomial.
"""
from __future__ import annotations

import math
from typing import Sequence


def chebyshev_shifts(lmin: float, lmax: float, l: int) -> list[float]:
    """Roots of the degree-l Chebyshev polynomial on [lmin, lmax] (eq. (8)).

    sigma_i = (lmax+lmin)/2 + (lmax-lmin)/2 * cos((2i+1) pi / (2 l)).
    """
    if l < 1:
        raise ValueError("pipeline depth l must be >= 1")
    mid = 0.5 * (lmax + lmin)
    rad = 0.5 * (lmax - lmin)
    return [mid + rad * math.cos((2 * i + 1) * math.pi / (2 * l)) for i in range(l)]


def monomial_shifts(l: int) -> list[float]:
    """All-zero shifts => monomial basis [v0, A v0, ...]; ill-conditioned
    quickly (Remark 3).  Exposed for the stability ablations."""
    return [0.0] * l


def ritz_shifts(ritz_values: Sequence[float], l: int) -> list[float]:
    """Use (a subset of) precomputed Ritz values of A as shifts (Remark 3).

    If more than ``l`` Ritz values are supplied the l extremal-spread
    Leja-ordered values are used, which is the standard choice for Newton
    bases (Hoemmen 2010).

    This is the host-side twin of the traced pair
    :func:`ritz_values_from_tridiag` + :func:`leja_order` that the scan
    engine's in-scan restart path runs on the committed ``gam``/``dlt``
    tridiagonal (``repro.core.plcg_scan``, ``restart=``): same Leja rule,
    plain floats instead of traced arrays.
    """
    vals = sorted(float(v) for v in ritz_values)
    if len(vals) < l:
        raise ValueError(f"need at least l={l} Ritz values, got {len(vals)}")
    # Leja ordering: greedily maximize the product of distances.
    chosen: list[float] = [max(vals, key=abs)]
    remaining = [v for v in vals if v is not chosen[0]]
    while len(chosen) < l:
        nxt = max(remaining, key=lambda v: math.prod(abs(v - c) for c in chosen))
        chosen.append(nxt)
        remaining.remove(nxt)
    return chosen


# --------------------------------------------------------------------------
# traced variants -- consumed inside the scan engine (restart shift refresh)
# --------------------------------------------------------------------------

def ritz_values_from_tridiag(gam, dlt):
    """Ritz values of the (preconditioned) operator from ``m`` committed
    Lanczos coefficients: eigenvalues of the symmetric tridiagonal
    ``T = tridiag(dlt, gam, dlt)`` (paper eq. (4) -- the banded T the
    p(l)-CG recurrences build column by column).

    ``gam`` is the ``(m,)`` diagonal, ``dlt`` the matching ``(m,)``
    slice whose first ``m-1`` entries are the off-diagonal.  Fully
    traceable (jittable, vmappable, runs inside ``lax.scan`` bodies).
    """
    import jax.numpy as jnp

    gam = jnp.asarray(gam)
    dlt = jnp.asarray(dlt)
    T = (jnp.diag(gam) + jnp.diag(dlt[:-1], 1) + jnp.diag(dlt[:-1], -1))
    return jnp.linalg.eigvalsh(T)


def leja_order(vals, l: int):
    """Traced Leja selection: the ``l`` extremal-spread values of
    ``vals``, greedily maximizing the product of pairwise distances
    (log-sum form for stability) -- the same rule as :func:`ritz_shifts`
    but expressed in jnp so the scan engine can refresh its shifts
    in-trace at restart time.  Requires ``len(vals) >= l`` (static).
    """
    import jax.numpy as jnp

    vals = jnp.asarray(vals)
    m = vals.shape[0]
    if m < l:
        raise ValueError(f"need at least l={l} values, got {m}")
    tiny = jnp.finfo(vals.dtype).tiny
    i0 = jnp.argmax(jnp.abs(vals))
    chosen = jnp.zeros((l,), vals.dtype).at[0].set(vals[i0])
    taken = jnp.zeros((m,), bool).at[i0].set(True)
    # running sum of log-distances to every chosen point; a duplicate of
    # a chosen value scores -inf and is naturally never picked again
    score = jnp.log(jnp.abs(vals - vals[i0]) + tiny)
    for j in range(1, l):
        idx = jnp.argmax(jnp.where(taken, -jnp.inf, score))
        chosen = chosen.at[j].set(vals[idx])
        taken = taken.at[idx].set(True)
        score = score + jnp.log(jnp.abs(vals - vals[idx]) + tiny)
    return chosen
