"""Shift choices for the auxiliary Krylov basis Z (paper Remark 3, eq. (8)).

The auxiliary basis vectors are ``z_j = P_l(A) v_{j-l}`` with
``P_l(t) = prod_{j<l} (t - sigma_j)``.  The conditioning of the basis
transformation matrix G -- and hence the attainable accuracy of p(l)-CG
(Sec. 4.2, Lemma 15) -- is governed by ``||P_l(A)||``, which is minimized
over intervals ``[lmin, lmax]`` containing the spectrum by the roots of the
degree-l Chebyshev polynomial.
"""
from __future__ import annotations

import math
from typing import Sequence


def chebyshev_shifts(lmin: float, lmax: float, l: int) -> list[float]:
    """Roots of the degree-l Chebyshev polynomial on [lmin, lmax] (eq. (8)).

    sigma_i = (lmax+lmin)/2 + (lmax-lmin)/2 * cos((2i+1) pi / (2 l)).
    """
    if l < 1:
        raise ValueError("pipeline depth l must be >= 1")
    mid = 0.5 * (lmax + lmin)
    rad = 0.5 * (lmax - lmin)
    return [mid + rad * math.cos((2 * i + 1) * math.pi / (2 * l)) for i in range(l)]


def monomial_shifts(l: int) -> list[float]:
    """All-zero shifts => monomial basis [v0, A v0, ...]; ill-conditioned
    quickly (Remark 3).  Exposed for the stability ablations."""
    return [0.0] * l


def ritz_shifts(ritz_values: Sequence[float], l: int) -> list[float]:
    """Use (a subset of) precomputed Ritz values of A as shifts (Remark 3).

    If more than ``l`` Ritz values are supplied the l extremal-spread
    Leja-ordered values are used, which is the standard choice for Newton
    bases (Hoemmen 2010).
    """
    vals = sorted(float(v) for v in ritz_values)
    if len(vals) < l:
        raise ValueError(f"need at least l={l} Ritz values, got {len(vals)}")
    # Leja ordering: greedily maximize the product of distances.
    chosen: list[float] = [max(vals, key=abs)]
    remaining = [v for v in vals if v is not chosen[0]]
    while len(chosen) < l:
        nxt = max(remaining, key=lambda v: math.prod(abs(v - c) for c in chosen))
        chosen.append(nxt)
        remaining.remove(nxt)
    return chosen
