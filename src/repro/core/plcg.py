"""Deep-pipelined Conjugate Gradients p(l)-CG -- paper Alg. 2 (+ Sec. 2.3).

This is the *reference* implementation: a faithful, python-loop transcription
of Alg. 2 with exact index bookkeeping, used as the oracle for the jitted
scan/shard_map production engines (``plcg_scan.py``, ``distributed/``) and as
the workhorse for the paper's accuracy experiments (Figs. 1, 6, 9, 10,
Table 2).  It is array-library agnostic (numpy fp64 for the stability
studies, JAX arrays elsewhere).

Structure of one iteration i (kernel map of Alg. 3):
  (K1) SPMV            z_{i+1} <- A z_i (and M^{-1} A z_i when preconditioned)
  (K2) SCALAR          finalize column c = i-l+1 of G   (lines 7-8)
  (K3) SCALAR          gamma_{c-1}, delta_{c-1}         (lines 10-16)
  (K4) AXPY            v_c (line 17), z_{i+1} correction (line 18)
  (K5) DOTPR           column i+1 dot products -> *arrive at iteration i+l*
  (K6) AXPY            eta/lambda/zeta/p/x solution update (lines 22-31)

The dot products stored into column i+1 at iteration i are only read at
iteration i+l (lines 7-8 with c = i+1): the algorithm's data flow itself
realizes the paper's MPI_Iallreduce/MPI_Wait pair with l-deep overlap.

Storage faithfulness: vectors are kept in pruned dicts holding exactly the
paper's sliding windows (Sec. 3.2 / Appendix B): l+1 z-vectors, 2l+1
v-vectors, 3 zhat-vectors, p and x -- i.e. 3l+2 basis vectors (3l+5
preconditioned).  ``record_G=True`` retains the full G matrix for the
stability diagnostics of Sec. 4 (Fig. 10).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional, Sequence

from .linop import LinearOperator
from .precond import Preconditioner
from .results import SolveResult
from .shifts import chebyshev_shifts

Array = Any


def _dot(a, b):
    return float((a * b).sum())


@dataclasses.dataclass
class PLCGTrace:
    """Optional finite-precision diagnostics (Sec. 4 experiments)."""
    true_resnorms: list = dataclasses.field(default_factory=list)    # ||b - A x_k||
    implicit_resnorms: list = dataclasses.field(default_factory=list)  # |zeta_k|
    basis_gap_norms: list = dataclasses.field(default_factory=list)  # ||vbar_k - v_k||
    residual_gap_norms: list = dataclasses.field(default_factory=list)
    G: Optional[Any] = None          # full G matrix (record_G=True)
    breakdown_iters: list = dataclasses.field(default_factory=list)


class _Pruned(dict):
    """Dict of index -> vector with explicit window pruning."""

    def prune_below(self, j0: int) -> None:
        for j in [j for j in self if j < j0]:
            del self[j]


def _plcg_single(
    A: LinearOperator,
    b: Array,
    x0: Array,
    *,
    l: int,
    sigma: Sequence[float],
    tol: float,
    maxiter: int,
    M: Optional[Preconditioner],
    exploit_symmetry: bool,
    record_G: bool,
    trace_gaps: bool,
    prune: bool,
    dot: Callable = _dot,
):
    """One p(l)-CG sweep (no restarts).  Returns (x, resnorms, k, status, trace).

    status: 'converged' | 'maxiter' | 'breakdown'
    """
    import numpy as np

    N = maxiter + 2 * l + 3          # scalar table size
    # --- initialization (Alg. 2 lines 1-3) --------------------------------
    x = x0
    rhat0 = b - A @ x                 # unpreconditioned residual
    r0 = M(rhat0) if M is not None else rhat0
    beta0 = dot(rhat0, r0) ** 0.5 if M is not None else dot(rhat0, rhat0) ** 0.5
    bnorm = dot(b, M(b)) ** 0.5 if M is not None else dot(b, b) ** 0.5
    if bnorm == 0.0:
        bnorm = 1.0
    trace = PLCGTrace()
    if record_G:
        trace.G = np.zeros((N, N))
    if beta0 == 0.0:
        return x, [0.0], 0, "converged", trace

    z = _Pruned(); v = _Pruned(); zh = _Pruned()
    v[0] = r0 / beta0
    z[0] = v[0]
    if M is not None:
        zh[0] = rhat0 / beta0         # zhat_0 = M z_0

    # scalar tables; out-of-range reads must see exact zeros
    G = np.zeros((N, N))
    gam = np.zeros(N); dlt = np.zeros(N)
    eta = np.zeros(N); zet = np.zeros(N)
    G[0, 0] = 1.0
    p_prev = None                     # p_{k-1}
    resnorms: list[float] = []
    status = "maxiter"
    k_done = -1                       # highest solution index k with x_k computed

    i = 0
    while True:
        # ----- (K1) SPMV: raw z_{i+1} (line 5) ----------------------------
        t_hat = A @ z[i]
        t = M(t_hat) if M is not None else t_hat
        if i < l:
            znew = t - sigma[i] * z[i]
            if M is not None:
                zhnew = t_hat - sigma[i] * zh[i]
        else:
            znew = t                  # corrected at line 18 below
            if M is not None:
                zhnew = t_hat

        breakdown = False
        if i >= l:
            c = i - l + 1             # column being finalized == new v index
            # ----- symmetric fill (Lemma 5 / eq. (14), Sec. 3.1) ----------
            if exploit_symmetry:
                for j in range(max(0, c - 2 * l), c - l):
                    G[j, c] = G[c - l, j + l]
            # ----- (K2) finalize column c of G (lines 7-8) ----------------
            for j in range(max(0, c - l + 1), c):
                s = sum(G[kk, j] * G[kk, c] for kk in range(max(0, c - 2 * l), j))
                G[j, c] = (G[j, c] - s) / G[j, j]
            arg = G[c, c] - sum(G[kk, c] ** 2 for kk in range(max(0, c - 2 * l), c))
            if arg <= 0.0 or not math.isfinite(arg):
                # square-root breakdown (Remark 8); a non-finite arg is a
                # NaN/Inf-poisoned recurrence and must break down too --
                # `arg <= 0.0` alone is False for NaN and would let the
                # poisoned solve run to maxiter
                trace.breakdown_iters.append(i)
                breakdown = True
            else:
                G[c, c] = math.sqrt(arg)
                if record_G:
                    trace.G[: c + 1, c] = G[: c + 1, c]
                # ----- (K3) gamma_{c-1}, delta_{c-1} (lines 10-16) --------
                gdiag = G[c - 1, c - 1]
                sub = G[c - 2, c - 1] * dlt[c - 2] if c >= 2 else 0.0
                if i < 2 * l:         # c <= l
                    gam[c - 1] = (G[c - 1, c] + sigma[c - 1] * gdiag - sub) / gdiag
                    dlt[c - 1] = G[c, c] / gdiag
                else:                 # c > l
                    gam[c - 1] = (gdiag * gam[c - 1 - l] + G[c - 1, c] * dlt[c - 1 - l]
                                  - sub) / gdiag
                    dlt[c - 1] = G[c, c] * dlt[c - 1 - l] / gdiag
                # ----- (K4) basis recurrences (lines 17-18) ---------------
                acc = z[c]
                for j in range(max(0, c - 2 * l), c):
                    if G[j, c] != 0.0:
                        acc = acc - G[j, c] * v[j]
                v[c] = acc / G[c, c]
                zim1 = z[i - 1] if i >= 1 else None
                znew = znew - gam[c - 1] * z[i]
                if c >= 2:
                    znew = znew - dlt[c - 2] * zim1
                znew = znew / dlt[c - 1]
                if M is not None:
                    zhnew = zhnew - gam[c - 1] * zh[i]
                    if c >= 2:
                        zhnew = zhnew - dlt[c - 2] * zh[i - 1]
                    zhnew = zhnew / dlt[c - 1]
                if trace_gaps and c >= 1:
                    # actual basis vector via the exact Lanczos relation (39)
                    kk = c - 1
                    vm1 = v[kk - 1] if kk >= 1 else 0.0 * v[kk]
                    vbar = (A @ v[kk] - gam[kk] * v[kk] - (dlt[kk - 1] if kk >= 1 else 0.0) * vm1) / dlt[kk]
                    gapv = vbar - v[c]
                    trace.basis_gap_norms.append(dot(gapv, gapv) ** 0.5)

        if breakdown:
            status = "breakdown"
            break

        z[i + 1] = znew
        if M is not None:
            zh[i + 1] = zhnew

        # ----- (K5) dot products for column i+1 (line 20) -----------------
        # these values are *read* for the first time at iteration i+l:
        # the payload of the paper's single MPI_Iallreduce per iteration.
        lhs = zh[i + 1] if M is not None else z[i + 1]
        lo_v = max(0, i - 2 * l + 1)
        hi_v = i - l + 1
        if hi_v >= 0:
            start = hi_v if (exploit_symmetry and i >= 2 * l - 1) else lo_v
            for j in range(start, hi_v + 1):
                G[j, i + 1] = dot(lhs, v[j])
        for j in range(max(0, i - l + 2), i + 2):
            G[j, i + 1] = dot(lhs, z[j])

        # ----- (K6) solution update (lines 22-31) --------------------------
        if i == l:
            eta[0] = gam[0]
            zet[0] = beta0
            p_prev = v[0] / eta[0]
            resnorms.append(abs(zet[0]))
            k_done = 0
        elif i >= l + 1:
            k = i - l
            lam = dlt[k - 1] / eta[k - 1]
            eta[k] = gam[k] - lam * dlt[k - 1]
            zet[k] = -lam * zet[k - 1]
            x = x + zet[k - 1] * p_prev
            p_prev = (v[k] - dlt[k - 1] * p_prev) / eta[k]
            resnorms.append(abs(zet[k]))
            k_done = k
            if trace_gaps:
                tr = b - A @ x
                trace.true_resnorms.append(dot(tr, tr) ** 0.5)
                trace.implicit_resnorms.append(abs(zet[k]))
                # residual gap (b - A x_k) - zeta_k v_k   (eq. 41/42)
                gap = tr - zet[k] * v[k]
                trace.residual_gap_norms.append(dot(gap, gap) ** 0.5)
            # stopping criterion (Remark 11): |zeta_{i-l}| available together
            # with x_{i-l}; a non-finite zeta is a poisoned lane, not a
            # non-converged one -- fail fast as breakdown
            if not math.isfinite(zet[k]):
                trace.breakdown_iters.append(i)
                status = "breakdown"
                break
            if abs(zet[k]) <= tol * bnorm:
                status = "converged"
                break
            if k >= maxiter:
                status = "maxiter"
                break

        # ----- sliding-window pruning (Sec. 3.2 / Appendix B) --------------
        if prune:
            z.prune_below(i - l + 1)          # keep z_{i-l+1} .. z_{i+1}
            v.prune_below(i - 3 * l + 2)      # keep v_{i-3l+2} .. v_{i-l+1}
            zh.prune_below(i - 1)             # keep zhat_{i-1} .. zhat_{i+1}
        i += 1

    return x, resnorms, max(k_done, 0), status, trace


def plcg(
    A: LinearOperator,
    b: Array,
    x0: Optional[Array] = None,
    *,
    l: int = 1,
    tol: float = 1e-8,
    maxiter: int = 1000,
    M: Optional[Preconditioner] = None,
    sigma: Optional[Sequence[float]] = None,
    spectrum: Optional[tuple] = None,
    exploit_symmetry: bool = True,
    record_G: bool = False,
    trace_gaps: bool = False,
    prune: bool = True,
    max_restarts: int = 5,
) -> SolveResult:
    """l-length pipelined CG (paper Alg. 2) with breakdown restarts.

    Args:
      l: pipeline depth (>= 1).
      sigma: l basis shifts; default Chebyshev roots on ``spectrum``
        (= (lmin, lmax)); ``spectrum`` defaults to a crude Gershgorin bound
        when the operator exposes a diagonal, else (0, 8) (the paper's
        Poisson interval).
      exploit_symmetry: use eq. (14) to compute only l+1 (instead of 2l+1)
        dot products per iteration (Sec. 3.1, Table 1 FLOPS count).
      record_G / trace_gaps: stability-analysis instrumentation (Sec. 4).
      max_restarts: explicit restart budget on square-root breakdown
        (Remark 8).
    """
    if l < 1:
        raise ValueError("pipeline depth l must be >= 1")
    if sigma is None:
        lmin, lmax = spectrum if spectrum is not None else (0.0, 8.0)
        sigma = chebyshev_shifts(lmin, lmax, l)
    sigma = list(sigma)
    if len(sigma) != l:
        raise ValueError(f"need exactly l={l} shifts, got {len(sigma)}")

    x = b * 0 if x0 is None else x0
    all_resnorms: list[float] = []
    traces: list[PLCGTrace] = []
    restarts = 0
    breakdowns = 0
    total_k = 0
    converged = False
    remaining = maxiter
    while remaining > 0:
        x, resnorms, k, status, trace = _plcg_single(
            A, b, x,
            l=l, sigma=sigma, tol=tol, maxiter=remaining, M=M,
            exploit_symmetry=exploit_symmetry, record_G=record_G,
            trace_gaps=trace_gaps, prune=prune,
        )
        all_resnorms.extend(resnorms)
        traces.append(trace)
        total_k += k
        remaining -= max(k, 1)
        if status == "converged":
            converged = True
            break
        if status == "maxiter":
            break
        # square-root breakdown: restart from the last computed solution
        breakdowns += 1
        if all_resnorms and all_resnorms[-1] <= tol * max(1e-300, float((b * b).sum()) ** 0.5):
            converged = True       # happy breakdown: already at tolerance
            break
        if restarts >= max_restarts:
            break
        restarts += 1

    trace0 = traces[0] if len(traces) == 1 else None
    return SolveResult(
        x=x, resnorms=all_resnorms, iters=total_k, converged=converged,
        breakdowns=breakdowns, restarts=restarts,
        true_resnorms=(trace0.true_resnorms if trace0 and trace_gaps else None),
        info={
            "method": f"p({l})-CG",
            "l": l,
            "sigma": sigma,
            "traces": traces,
        },
    )
