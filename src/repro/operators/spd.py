"""Synthetic SPD test matrices (Matrix-Market-style suite, paper Table 2).

The container has no network access, so the paper's Matrix Market selection
is replaced by a reproducible generator sweeping the properties that matter
for the attainable-accuracy study: condition number, spectrum shape, and
bandwidth/sparsity.
"""
from __future__ import annotations

import numpy as np

from ..core.linop import LinearOperator, dense_operator


def spd_with_spectrum(eigs: np.ndarray, seed: int = 0) -> np.ndarray:
    """Dense SPD matrix with the prescribed spectrum (random orthogonal Q)."""
    n = len(eigs)
    rng = np.random.default_rng(seed)
    Q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    return (Q * eigs) @ Q.T


def random_spd_dense(
    n: int,
    cond: float = 1e4,
    spectrum: str = "geometric",
    seed: int = 0,
) -> LinearOperator:
    """Random dense SPD operator with condition number ``cond``.

    spectrum:
      'geometric' -- log-uniform eigenvalues in [1/cond, 1] (hard for CG);
      'uniform'   -- uniform eigenvalues (easy);
      'clustered' -- one small outlier + cluster at 1 (classic CG showcase).
    """
    if spectrum == "geometric":
        eigs = np.geomspace(1.0 / cond, 1.0, n)
    elif spectrum == "uniform":
        eigs = np.linspace(1.0 / cond, 1.0, n)
    elif spectrum == "clustered":
        eigs = np.concatenate([[1.0 / cond], np.linspace(0.9, 1.1, n - 1)])
    else:
        raise ValueError(f"unknown spectrum {spectrum!r}")
    A = spd_with_spectrum(eigs, seed=seed)
    op = dense_operator(A, name=f"spd-{spectrum}-n{n}-k{cond:.0e}")
    return op


#: the Table-2-style accuracy suite: (name, n, cond, spectrum, seed)
TABLE2_SUITE = [
    ("spd-uni-1e2", 240, 1e2, "uniform", 1),
    ("spd-uni-1e4", 240, 1e4, "uniform", 2),
    ("spd-geo-1e4", 240, 1e4, "geometric", 3),
    ("spd-geo-1e6", 240, 1e6, "geometric", 4),
    ("spd-geo-1e8", 240, 1e8, "geometric", 5),
    ("spd-clu-1e6", 240, 1e6, "clustered", 6),
]
