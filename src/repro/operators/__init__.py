from .poisson import poisson2d, poisson3d, poisson2d_dense, poisson_eig_interval
from .spd import random_spd_dense, spd_with_spectrum
from .precond import jacobi, block_jacobi_ssor

__all__ = [
    "poisson2d", "poisson3d", "poisson2d_dense", "poisson_eig_interval",
    "random_spd_dense", "spd_with_spectrum",
    "jacobi", "block_jacobi_ssor",
]
