"""Matrix-free Poisson stencil operators (the paper's benchmark problem).

The paper validates on a 2D Laplacian with homogeneous Dirichlet boundary
conditions, discretized with second-order finite differences on a uniform
``nx x ny`` grid of the unit square -- the *unscaled* 5-point stencil
(diagonal 4, neighbors -1), whose spectrum lies in (0, 8); the paper's
Chebyshev shift interval is exactly [0, 8] (Sec. 5, test setup 1).

Works on both numpy and JAX arrays: the stencil is expressed with pad/slice
arithmetic only.
"""
from __future__ import annotations

from typing import Any

from ..core.linop import LinearOperator

Array = Any


def _stencil2d_apply(u: Array, nx: int, ny: int) -> Array:
    g = u.reshape(nx, ny)
    out = 4.0 * g
    # numpy/jax agnostic shifted-neighbor subtraction with Dirichlet BCs
    out = _sub_shift(out, g, axis=0, up=True)
    out = _sub_shift(out, g, axis=0, up=False)
    out = _sub_shift(out, g, axis=1, up=True)
    out = _sub_shift(out, g, axis=1, up=False)
    return out.reshape(-1)


def _sub_shift(out: Array, g: Array, axis: int, up: bool) -> Array:
    # out -= shift(g); implemented with slicing so it traces under jit
    if axis == 0:
        if up:
            return out.at[1:, :].add(-g[:-1, :]) if hasattr(out, "at") else _np_sub(out, g, 0, up)
        return out.at[:-1, :].add(-g[1:, :]) if hasattr(out, "at") else _np_sub(out, g, 0, up)
    if up:
        return out.at[:, 1:].add(-g[:, :-1]) if hasattr(out, "at") else _np_sub(out, g, 1, up)
    return out.at[:, :-1].add(-g[:, 1:]) if hasattr(out, "at") else _np_sub(out, g, 1, up)


def _np_sub(out, g, axis, up):
    if axis == 0 and up:
        out[1:, :] -= g[:-1, :]
    elif axis == 0:
        out[:-1, :] -= g[1:, :]
    elif up:
        out[:, 1:] -= g[:, :-1]
    else:
        out[:, :-1] -= g[:, 1:]
    return out


def poisson2d(nx: int, ny: int | None = None) -> LinearOperator:
    """Unscaled 5-point stencil 2D Poisson operator on an nx x ny grid."""
    ny = nx if ny is None else ny
    n = nx * ny

    def matvec(u):
        import numpy as np
        if isinstance(u, np.ndarray):
            g = u.reshape(nx, ny)
            out = 4.0 * g
            out[1:, :] -= g[:-1, :]
            out[:-1, :] -= g[1:, :]
            out[:, 1:] -= g[:, :-1]
            out[:, :-1] -= g[:, 1:]
            return out.reshape(-1)
        return _stencil2d_apply(u, nx, ny)

    import numpy as np
    return LinearOperator(matvec=matvec, n=n, diag=np.full(n, 4.0),
                          name=f"poisson2d-{nx}x{ny}", stencil2d=(nx, ny))


def poisson3d(nx: int, ny: int | None = None, nz: int | None = None) -> LinearOperator:
    """Unscaled 7-point stencil 3D Poisson operator (diag 6, neighbors -1)."""
    ny = nx if ny is None else ny
    nz = nx if nz is None else nz
    n = nx * ny * nz

    def matvec(u):
        import numpy as np
        g = u.reshape(nx, ny, nz)
        if isinstance(u, np.ndarray):
            out = 6.0 * g
            out[1:] -= g[:-1]; out[:-1] -= g[1:]
            out[:, 1:] -= g[:, :-1]; out[:, :-1] -= g[:, 1:]
            out[:, :, 1:] -= g[:, :, :-1]; out[:, :, :-1] -= g[:, :, 1:]
            return out.reshape(-1)
        out = 6.0 * g
        out = out.at[1:].add(-g[:-1]); out = out.at[:-1].add(-g[1:])
        out = out.at[:, 1:].add(-g[:, :-1]); out = out.at[:, :-1].add(-g[:, 1:])
        out = out.at[:, :, 1:].add(-g[:, :, :-1]); out = out.at[:, :, :-1].add(-g[:, :, 1:])
        return out.reshape(-1)

    import numpy as np
    return LinearOperator(matvec=matvec, n=n, diag=np.full(n, 6.0),
                          name=f"poisson3d-{nx}x{ny}x{nz}")


def poisson2d_dense(nx: int, ny: int | None = None):
    """Dense (n, n) matrix of the same operator, for small-n oracle tests."""
    import numpy as np
    ny = nx if ny is None else ny
    n = nx * ny
    A = np.zeros((n, n))
    for i in range(nx):
        for j in range(ny):
            k = i * ny + j
            A[k, k] = 4.0
            if i > 0:
                A[k, k - ny] = -1.0
            if i < nx - 1:
                A[k, k + ny] = -1.0
            if j > 0:
                A[k, k - 1] = -1.0
            if j < ny - 1:
                A[k, k + 1] = -1.0
    return A


def poisson_eig_interval(dim: int = 2) -> tuple:
    """Spectral inclusion interval used for the Chebyshev shifts (paper: [0,8])."""
    return (0.0, 4.0 * dim)
