"""SPD preconditioners for the preconditioned p(l)-CG of Sec. 2.3.

The paper's parallel experiments use block Jacobi with local ILU (Fig. 5).
ILU's sequential triangular solves map poorly onto the TPU VPU, so the
block-local approximate inverse here is a symmetric SSOR sweep (SPD-
preserving, communication-free, expressible as stencil sweeps) -- see
DESIGN.md 'hardware adaptation'.  Jacobi (diagonal) is also provided.

Both preconditioners are *block-local by construction*: they never touch
data outside one worker's partition, so their application overlaps with the
global reduction exactly like the SPMV (paper Remark 13).
"""
from __future__ import annotations

import numpy as np
from scipy.linalg import solve_triangular

from ..core.linop import LinearOperator, Preconditioner
from ..core.precond import Jacobi


def jacobi(A: LinearOperator) -> Jacobi:
    """Diagonal (Jacobi) preconditioner M = diag(A).

    Returns the structured ``repro.core.precond.Jacobi``: it carries the
    ``inv_diag`` fusion hint (the fused scan backend keeps ONE Pallas
    launch per iteration) and, for a constant diagonal, the shard-local
    apply that makes it mesh-capable.
    """
    return Jacobi.from_operator(A)


def block_jacobi_ssor(
    A_dense_block_fn,
    nblocks: int,
    n: int,
    omega: float = 1.0,
    sweeps: int = 1,
) -> Preconditioner:
    """Block-Jacobi preconditioner; each contiguous block is approximately
    inverted with ``sweeps`` symmetric SOR sweeps of the local block matrix.

    ``A_dense_block_fn(b) -> (nb, nb) ndarray`` returns the dense diagonal
    block for block index b.  The SSOR application
        M^{-1} = omega (2-omega) (D/omega + U)^{-1} D (D/omega + L)^{-1}
    is SPD for SPD blocks and 0 < omega < 2.
    """
    bounds = np.linspace(0, n, nblocks + 1).astype(int)
    facs = []
    for b in range(nblocks):
        Ab = np.asarray(A_dense_block_fn(b), dtype=float)
        d = np.diag(Ab).copy()
        lower = np.tril(Ab, -1) + np.diag(d / omega)   # D/omega + L
        upper = np.triu(Ab, 1) + np.diag(d / omega)    # D/omega + U
        facs.append((d, lower, upper))
    scale = omega * (2.0 - omega)

    def apply(v):
        vv = np.asarray(v, dtype=float)
        out = np.empty_like(vv)
        for b in range(nblocks):
            s, e = bounds[b], bounds[b + 1]
            d, lower, upper = facs[b]
            t = solve_triangular(lower, vv[s:e], lower=True)
            t = d * t
            t = solve_triangular(upper, t, lower=False)
            out[s:e] = scale * t
        return out

    return Preconditioner(apply=apply, name=f"bj-ssor-{nblocks}x")


def block_jacobi_for(A: LinearOperator, dense: np.ndarray, nblocks: int,
                     omega: float = 1.0) -> Preconditioner:
    """Convenience: block-Jacobi SSOR from an explicit dense matrix."""
    n = A.n
    bounds = np.linspace(0, n, nblocks + 1).astype(int)

    def block(b):
        s, e = bounds[b], bounds[b + 1]
        return dense[s:e, s:e]

    return block_jacobi_ssor(block, nblocks, n, omega=omega)
