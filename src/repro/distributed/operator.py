"""Distributed operator protocol + the 2-D Poisson realization.

A :class:`DistributedOperator` is the mesh-side counterpart of
:class:`repro.core.linop.LinearOperator`: it is bound to a
``jax.sharding.Mesh`` and exposes the four ingredients the mesh-aware
solver engine injects into ``plcg_scan`` /  the distributed CG body:

  * ``matvec_local``   -- the *local* SPMV (halo exchange + local stencil),
    valid only inside the engine's ``shard_map`` region;
  * ``spec()``         -- the :class:`PartitionSpec` of one global field;
  * ``dot_local``      -- a local partial inner product (no collective);
  * ``reduce_scalars`` -- the global sum of a stacked scalar payload (ONE
    ``psum`` per call; the engine calls it exactly once per iteration);
  * ``reduce_scalars_start`` / ``reduce_scalars_finish`` -- (optional)
    the SPLIT-PHASE form of the reduction backing ``comm="overlap"``:
    ``start`` issues a ``psum_scatter`` of the (zero-padded) payload and
    returns the local shard of the partial sums, ``finish(shard, width)``
    completes it with an ``all_gather`` and unpads -- the engine carries
    the shard in its scan-state queue and calls ``finish`` d iterations
    later, so the reduction is structurally in flight across d bodies of
    local compute;
  * ``ring_schedule`` -- (optional) the static hop list backing
    ``comm="ring"``: ``(axis_name, perm, reset)`` neighbor exchanges of a
    circulate-accumulate all-reduce, applied one per queue shift;
  * ``prec_local``     -- (optional) resolve a structured
    ``repro.core.precond.Preconditioner`` into its shard-local apply, or
    None when that preconditioner has no communication-free form on this
    operator.  :func:`resolve_prec_local` is the engine-side entry point
    that falls back to ``M.local_apply(op)`` and raises the uniform
    error; a resolved apply must never issue a global collective, which
    is what keeps preconditioned mesh p(l)-CG at one psum per iteration.

Anything implementing the protocol -- a 3-D stencil, an unstructured-grid
operator with gather-based halos, a parameter-space Newton operator --
drives the same ``solve(A, b, mesh=...)`` front-end as
:class:`DistPoisson`.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..core.linop import LinearOperator
from ..core.solver_cache import WeakCallableCache
from ..kernels import ops as kops


@runtime_checkable
class DistributedOperator(Protocol):
    """Structural protocol for mesh-bound operators (see module docstring).

    ``local_shape`` / ``global_shape`` describe one field as an ndarray
    (the engine flattens blocks before handing them to the scan engine and
    restores the shape on the way out); ``spec()`` must shard exactly the
    axes of ``global_shape``.
    """

    mesh: Mesh

    @property
    def local_shape(self) -> tuple: ...

    @property
    def global_shape(self) -> tuple: ...

    def spec(self) -> P: ...

    def matvec_local(self, xflat): ...

    def dot_local(self, u, v): ...

    def reduce_scalars(self, payload): ...


@dataclasses.dataclass(frozen=True, eq=False)
class DistPoisson:
    """Distributed 2-D Poisson operator bound to a 2-axis mesh.

    Domain decomposition: the (nx, ny) grid is split into
    (nx/Px, ny/Py) local blocks over the (row_axis, col_axis) mesh axes --
    a 2-D decomposition (strictly lower surface/volume than the paper's
    1-D contiguous rows).  ``matvec_local`` exchanges 4 halo strips via
    ``ppermute`` (unpaired edges receive zeros == homogeneous Dirichlet)
    and applies the local 5-point Pallas stencil kernel.
    """

    nx: int
    ny: int
    mesh: Mesh
    row_axis: str = "data"
    col_axis: str = "model"

    @property
    def n(self) -> int:
        return self.nx * self.ny

    @property
    def px(self) -> int:
        return self.mesh.shape[self.row_axis]

    @property
    def py(self) -> int:
        return self.mesh.shape[self.col_axis]

    @property
    def axes(self) -> tuple:
        return (self.row_axis, self.col_axis)

    @property
    def global_shape(self) -> tuple:
        return (self.nx, self.ny)

    @property
    def local_shape(self) -> tuple:
        assert self.nx % self.px == 0 and self.ny % self.py == 0, (
            "grid must divide the processor grid")
        return (self.nx // self.px, self.ny // self.py)

    # ppermute pair lists are static trace-time metadata; build them once
    # per operator instead of once per matvec_local trace (cached_property
    # writes straight into __dict__, which the frozen dataclass allows)
    @functools.cached_property
    def _row_perms(self) -> tuple:
        fwd = tuple((i, i + 1) for i in range(self.px - 1))
        bwd = tuple((i + 1, i) for i in range(self.px - 1))
        return fwd, bwd

    @functools.cached_property
    def _col_perms(self) -> tuple:
        fwd = tuple((i, i + 1) for i in range(self.py - 1))
        bwd = tuple((i + 1, i) for i in range(self.py - 1))
        return fwd, bwd

    def spec(self) -> P:
        return P(self.row_axis, self.col_axis)

    def matvec_local(self, xflat: jax.Array) -> jax.Array:
        """Local SPMV with halo exchange; runs inside shard_map."""
        H, W = self.local_shape
        x = xflat.reshape(H, W)
        fwd_r, bwd_r = self._row_perms
        fwd_c, bwd_c = self._col_perms
        # unpaired edges receive zeros (Dirichlet)
        halo_n = jax.lax.ppermute(x[-1:, :], self.row_axis, fwd_r)[0]
        halo_s = jax.lax.ppermute(x[:1, :], self.row_axis, bwd_r)[0]
        halo_w = jax.lax.ppermute(x[:, -1:], self.col_axis, fwd_c)[:, 0]
        halo_e = jax.lax.ppermute(x[:, :1], self.col_axis, bwd_c)[:, 0]
        y = kops.stencil2d_apply(x, halo_n, halo_s, halo_w, halo_e)
        return y.reshape(-1)

    def dot_local(self, u: jax.Array, v: jax.Array) -> jax.Array:
        return jnp.sum(u * v)

    def reduce_scalars(self, payload: jax.Array) -> jax.Array:
        return jax.lax.psum(payload, self.axes)

    @property
    def nshards(self) -> int:
        return self.px * self.py

    def reduce_scalars_start(self, payload: jax.Array) -> jax.Array:
        """Issue the reduction: one ``psum_scatter`` of the zero-padded
        payload over the full device grid; returns this shard's chunk of
        the partial sums (``ceil(W/nshards)`` entries).  The matching
        ``reduce_scalars_finish`` may run any number of iterations later
        -- the scatter+gather pair composes to exactly the ``psum``."""
        w = payload.shape[-1]
        wp = -(-w // self.nshards) * self.nshards
        if wp != w:
            pad = [(0, 0)] * (payload.ndim - 1) + [(0, wp - w)]
            payload = jnp.pad(payload, pad)
        return jax.lax.psum_scatter(payload, self.axes,
                                    scatter_dimension=payload.ndim - 1,
                                    tiled=True)

    def reduce_scalars_finish(self, shard: jax.Array, width: int) -> jax.Array:
        """Complete a split reduction: ``all_gather`` the partial-sum
        chunks and drop the zero padding back to ``width`` entries."""
        full = jax.lax.all_gather(shard, self.axes, axis=shard.ndim - 1,
                                  tiled=True)
        return full[..., :width]

    def ring_schedule(self) -> tuple:
        """Hop list of the circulate-accumulate all-reduce on the 2-D
        torus: ``px - 1`` wraparound hops along the row ring (each rank
        accumulates every row partner), then ``py - 1`` along the column
        ring circulating the row-complete partials (``reset`` re-seeds
        the circulating buffer from the accumulator at the phase entry).
        ``(px-1) + (py-1)`` neighbor exchanges total; composes to the
        full ``psum`` over ``axes``."""
        ring_r = tuple((i, (i + 1) % self.px) for i in range(self.px))
        ring_c = tuple((i, (i + 1) % self.py) for i in range(self.py))
        hops = [(self.row_axis, ring_r, False) for _ in range(self.px - 1)]
        hops += [(self.col_axis, ring_c, h == 0)
                 for h in range(self.py - 1)]
        return tuple(hops)

    def prec_local(self, M):
        """Shard-local apply of a structured preconditioner, or None.

        Delegates to ``M.local_apply(self)`` -- BlockJacobi blocks must
        match this operator's processor grid (validated there), Jacobi
        shard-splits a full ``(n,)`` diagonal through the ``axes`` /
        ``local_shape`` metadata (a constant diagonal is trivially
        local), Chebyshev runs through ``matvec_local`` (neighbor halos
        only).
        """
        return M.local_apply(self)


def resolve_prec_local(op, M):
    """Resolve ``M`` into a shard-local apply on ``op`` (engine entry).

    ``None`` passes through.  Prefers the operator's ``prec_local`` hook,
    falls back to ``M.local_apply(op)``; raises the uniform error when
    neither yields a communication-free local apply (e.g. a bare ``M=``
    callable, whose sharding the engine cannot know).
    """
    if M is None:
        return None
    hook = getattr(op, "prec_local", None)
    fn = hook(M) if hook is not None else M.local_apply(op)
    if fn is None:
        raise ValueError(
            f"preconditioner {getattr(M, 'name', M)!r} cannot be applied "
            "shard-locally, so it has no mesh execution path; mesh-capable "
            "preconditioners: repro.core.precond.BlockJacobi, Jacobi "
            "(scalar, or a full diagonal matching the operator's 2-D "
            "grid), Chebyshev (a bare M= callable is opaque to the mesh "
            "layer)")
    return fn


#: Canonical promotions, keyed weakly on the LinearOperator's matvec
#: (the operator itself hashes by value, incl. its ndarray diag):
#: repeated ``solve(A, b, mesh=mesh)`` calls with the same ``A`` must
#: yield the SAME DistPoisson instance so the mesh-sweep cache (keyed on
#: operator identity) hits instead of recompiling the shard_map program
#: per call.
_PROMOTE_CACHE = WeakCallableCache(maxsize=32)


def as_dist_operator(A, mesh: Mesh | None) -> DistributedOperator:
    """Coerce ``A`` into a :class:`DistributedOperator` on ``mesh``.

    Accepts an object already implementing the protocol (``mesh`` must
    then be ``None`` or the operator's own mesh), or a
    :class:`LinearOperator` carrying the ``stencil2d`` structural hint
    (e.g. ``repro.operators.poisson2d``), which is promoted to a
    :class:`DistPoisson` decomposed over the first two mesh axes.  The
    promotion is cached per ``(A, mesh)`` (weakly in ``A``), so the same
    front-end call always reaches the same compiled sweep.
    """
    if isinstance(A, DistributedOperator):
        if mesh is not None and mesh is not A.mesh and mesh != A.mesh:
            raise ValueError(
                "operator is already bound to a different mesh; pass "
                "mesh=None or rebuild the operator on the target mesh")
        return A
    if mesh is None:
        raise ValueError("mesh-aware dispatch needs mesh=... when A is not "
                         "already a DistributedOperator")
    if isinstance(A, LinearOperator) and A.stencil2d is not None:
        names = tuple(mesh.axis_names)
        if len(names) != 2:
            raise ValueError(
                f"DistPoisson needs a 2-axis processor grid, got mesh axes "
                f"{names}; fold extra axes first (see launch.mesh)")
        nx, ny = A.stencil2d
        return _PROMOTE_CACHE.get_or_build(
            (A.matvec,), (mesh, nx, ny),
            lambda: DistPoisson(nx, ny, mesh, row_axis=names[0],
                                col_axis=names[1]))
    raise TypeError(
        f"cannot run {type(A).__name__} on a mesh: pass a "
        "DistributedOperator, or a LinearOperator with a stencil2d hint "
        "(repro.operators.poisson2d)")
