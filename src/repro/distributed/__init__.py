"""Mesh execution layer of the unified solver engine.

There is no standalone distributed driver anymore: distributed solves go
through the registry front-end, ``repro.core.solve(A, b, mesh=...)``
(methods ``plcg`` / ``plcg_scan`` for the one-psum pipelined engine,
``cg`` for the two-psum baseline).  This package exports the operator
protocol plus the jittable sweep builders used for lowering, jaxpr
introspection and benchmarking.
"""
from .operator import (DistPoisson, DistributedOperator, as_dist_operator,
                       resolve_prec_local)
from .plcg_dist import (cg_mesh_sweep, mesh_methods, plcg_mesh_sweep,
                        solve_on_mesh)

__all__ = [
    "DistPoisson",
    "DistributedOperator",
    "as_dist_operator",
    "cg_mesh_sweep",
    "mesh_methods",
    "plcg_mesh_sweep",
    "resolve_prec_local",
    "solve_on_mesh",
]
