from .plcg_dist import dist_plcg, dist_plcg_solve, dist_cg, DistPoisson

__all__ = ["dist_plcg", "dist_plcg_solve", "dist_cg", "DistPoisson"]
