"""Distributed p(l)-CG on a 2-D processor grid (the paper's Sec. 5 setup,
TPU-native).

Domain decomposition: the (nx, ny) Poisson grid is split into
(nx/Px, ny/Py) local blocks over the ("data","model") mesh axes -- a 2-D
decomposition (vs the paper's 1-D contiguous rows; strictly lower
surface/volume, noted in DESIGN.md).  Per iteration:

  * SPMV: halo exchange with 4 ``ppermute``s (neighbor ICI traffic;
    unpaired edges receive zeros == homogeneous Dirichlet) + the local
    Pallas 5-point stencil kernel;
  * dot products: local partials only; ONE fused ``psum`` of the stacked
    (2l+1)-scalar payload per iteration -- the paper's single
    MPI_Iallreduce (Alg. 3 line 11);
  * the psum result lands in the depth-l in-flight queue of
    ``plcg_scan`` and is consumed l iterations later -- the MPI_Wait of
    Alg. 3 line 5, giving XLA's scheduler l SPMVs of slack to hide the
    reduction.

Everything runs inside one ``jax.shard_map`` region, so the lowered HLO
exhibits exactly the collective schedule described in the paper.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map_compat
from repro.core.plcg_scan import plcg_scan
from repro.kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class DistPoisson:
    """Distributed 2-D Poisson operator bound to a mesh."""
    nx: int
    ny: int
    mesh: Mesh
    row_axis: str = "data"
    col_axis: str = "model"

    @property
    def px(self) -> int:
        return self.mesh.shape[self.row_axis]

    @property
    def py(self) -> int:
        return self.mesh.shape[self.col_axis]

    @property
    def local_shape(self):
        assert self.nx % self.px == 0 and self.ny % self.py == 0, (
            "grid must divide the processor grid")
        return (self.nx // self.px, self.ny // self.py)

    def spec(self) -> P:
        return P(self.row_axis, self.col_axis)

    def matvec_local(self, xflat: jax.Array) -> jax.Array:
        """Local SPMV with halo exchange; runs inside shard_map."""
        H, W = self.local_shape
        x = xflat.reshape(H, W)
        ra, ca = self.row_axis, self.col_axis
        fwd_r = [(i, i + 1) for i in range(self.px - 1)]
        bwd_r = [(i + 1, i) for i in range(self.px - 1)]
        fwd_c = [(i, i + 1) for i in range(self.py - 1)]
        bwd_c = [(i + 1, i) for i in range(self.py - 1)]
        # unpaired edges receive zeros (Dirichlet)
        halo_n = jax.lax.ppermute(x[-1:, :], ra, fwd_r)[0]
        halo_s = jax.lax.ppermute(x[:1, :], ra, bwd_r)[0]
        halo_w = jax.lax.ppermute(x[:, -1:], ca, fwd_c)[:, 0]
        halo_e = jax.lax.ppermute(x[:, :1], ca, bwd_c)[:, 0]
        y = kops.stencil2d_apply(x, halo_n, halo_s, halo_w, halo_e)
        return y.reshape(-1)


def dist_plcg(op: DistPoisson, b_global: jax.Array, x0=None, *, l: int,
              iters: int, sigma: Sequence[float], tol: float = 0.0,
              exploit_symmetry: bool = True):
    """Run the pipelined solver on the full mesh.

    b_global: (nx, ny) right-hand side (sharded or shardable).
    Returns (x (nx, ny) sharded, resnorms (iters,), converged, breakdown,
    k_done).

    The engine runs with injected local-partial dots and a single fused
    psum per iteration, which bypasses every kernel ``backend`` tier
    (including ``"fused"``) by construction -- the distributed hot path is
    the halo-exchange stencil kernel plus the collective schedule, not the
    single-device megakernel.
    """
    mesh = op.mesh
    axes = (op.row_axis, op.col_axis)

    def local_run(b_blk, x_blk):
        bflat = b_blk.reshape(-1)
        out = plcg_scan(
            op.matvec_local, bflat, x_blk.reshape(-1),
            l=l, iters=iters, sigma=tuple(sigma), tol=tol,
            dot_local=lambda u, v: jnp.sum(u * v),
            reduce_scalars=lambda p: jax.lax.psum(p, axes),
            exploit_symmetry=exploit_symmetry,
        )
        return (out.x.reshape(b_blk.shape), out.resnorms, out.converged,
                out.breakdown, out.k_done)

    fn = shard_map_compat(
        local_run, mesh=mesh,
        in_specs=(op.spec(), op.spec()),
        out_specs=(op.spec(), P(), P(), P(), P()),
        check=False,
    )
    if x0 is None:
        x0 = jnp.zeros_like(b_global)
    return jax.jit(fn)(b_global, x0)


def dist_plcg_solve(op: DistPoisson, b_global: jax.Array, *, l: int,
                    sigma: Sequence[float], tol: float = 1e-8,
                    maxiter: int = 2000, max_restarts: int = 5):
    """Driver with explicit restart on square-root breakdown (Remark 8).

    The iteration budget is global: every restart sweep runs with the
    *remaining* budget (``maxiter`` minus iterations already spent), so a
    breakdown-looping system performs at most ``maxiter`` solution updates
    in total rather than ``max_restarts * maxiter``.  Mirrors the
    single-device ``plcg_solve`` contract, including ``iterations`` /
    ``breakdowns`` in the info dict.
    """
    import numpy as np
    x = jnp.zeros_like(b_global)
    all_res: list = []
    restarts = breakdowns = 0
    total_k = 0
    converged = False
    while total_k < maxiter:
        remaining = maxiter - total_k
        # iters bodies perform exactly iters - l solution updates, so the
        # sweep can never overrun the remaining budget
        x, resn, conv, brk, k_done = dist_plcg(
            op, b_global, x, l=l, iters=remaining + l, sigma=sigma,
            tol=tol)
        all_res.extend([float(r) for r in np.asarray(resn) if r > 0])
        total_k += max(int(k_done) + 1, 1)
        if bool(conv):
            converged = True
            break
        if bool(brk):
            breakdowns += 1
            if restarts >= max_restarts:
                break
            restarts += 1
            continue
        break                             # iteration budget exhausted
    return x, all_res, {"converged": converged, "restarts": restarts,
                        "breakdowns": breakdowns, "iterations": total_k}


def dist_cg(op: DistPoisson, b_global: jax.Array, *, iters: int,
            tol: float = 0.0):
    """Distributed classic CG baseline: TWO synchronous psums per iteration
    (gamma and the step dot), each consumed immediately -- zero overlap.
    Used for the strong-scaling comparisons (paper Figs. 3-5)."""
    mesh = op.mesh
    axes = (op.row_axis, op.col_axis)

    def local_run(b_blk):
        bflat = b_blk.reshape(-1)
        bnorm2 = jax.lax.psum(jnp.sum(bflat * bflat), axes)

        def body(st, _):
            x, r, p, gamma, done = st
            s = op.matvec_local(p)
            sp = jax.lax.psum(jnp.sum(s * p), axes)      # sync reduction 1
            alpha = gamma / sp
            x2 = x + alpha * p
            r2 = r - alpha * s
            gamma2 = jax.lax.psum(jnp.sum(r2 * r2), axes)  # sync reduction 2
            beta = gamma2 / gamma
            p2 = r2 + beta * p
            conv = gamma2 <= (tol ** 2) * bnorm2
            new = (x2, r2, p2, gamma2, done | conv)
            out = jax.tree.map(lambda a, o: jnp.where(done, o, a), new, st)
            return out, jnp.sqrt(jnp.where(done, gamma, gamma2))

        x0 = jnp.zeros_like(bflat)
        gamma0 = bnorm2
        st, resn = jax.lax.scan(
            body, (x0, bflat, bflat, gamma0, jnp.asarray(False)),
            jnp.arange(iters))
        return st[0].reshape(b_blk.shape), resn, st[4]

    fn = shard_map_compat(
        local_run, mesh=mesh,
        in_specs=(op.spec(),),
        out_specs=(op.spec(), P(), P()),
        check=False,
    )
    return jax.jit(fn)(b_global)
