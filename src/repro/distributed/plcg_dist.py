"""Mesh execution layer of the unified solver engine (paper Sec. 5 setup).

This module used to be a standalone distributed driver; it is now the
layer ``repro.core.solve(A, b, mesh=...)`` dispatches onto.  Any
:class:`~repro.distributed.operator.DistributedOperator` (or a
``LinearOperator`` with a ``stencil2d`` hint, auto-promoted to
:class:`DistPoisson`) runs a registry method on the mesh:

  ============  =========================================================
  ``plcg``      deep-pipelined p(l)-CG: ``jit(shard_map(vmap(plcg_scan)))``
  ``plcg_scan`` alias of the same mesh engine (one scan engine everywhere)
  ``cg``        classic CG baseline: TWO synchronous psums per iteration
  ============  =========================================================

Per iteration of the pipelined engine:

  * SPMV: halo exchange (4 ``ppermute``; neighbor ICI traffic) + the
    local Pallas 5-point stencil kernel;
  * dot products: local partials only; ONE fused ``psum`` of the stacked
    (2l+1)-scalar payload per iteration -- the paper's single
    ``MPI_Iallreduce`` (Alg. 3 line 11);
  * the psum result lands in the depth-l in-flight queue of
    ``plcg_scan`` and is consumed l iterations later -- the ``MPI_Wait``
    of Alg. 3 line 5, giving XLA's scheduler l SPMVs of slack to hide
    the reduction.

Batched multi-RHS: a ``(nrhs, nx, ny)`` right-hand side runs domain
decomposition *inside* (``shard_map`` over the grid axes) and RHS
batching *outside* (``vmap`` over lanes), so the per-iteration payload
stacks to ``(nrhs, 2l+1)`` and the batched collective is STILL one psum
-- all lanes' reductions ride one fused all-reduce, the strong-scaling
multi-solve workload of arXiv:1905.06850.  Convergence is masked per
lane by the scan engine's commit select, identically to the
single-device batched path.

Preconditioning composes: a structured ``repro.core.precond``
preconditioner with a shard-local apply (``BlockJacobi`` -- zero
communication; ``Chebyshev`` -- neighbor halos only; constant-diagonal
``Jacobi``) is resolved via ``operator.resolve_prec_local`` and applied
inside the shard_map body, so preconditioned p(l)-CG keeps exactly ONE
stacked psum per iteration (and preconditioned CG its two, by stacking
``<r,u>``/``<r,r>`` into one payload).

The injected local-partial dots bypass every kernel ``backend`` tier
(including ``"fused"``) by construction -- the distributed hot path is
the halo-exchange stencil kernel plus the collective schedule, not the
single-device megakernel.
"""
from __future__ import annotations

import weakref
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map_compat
from repro.core import engine as _engine
from repro.core.comm import as_comm_policy, build_comm_runtime
from repro.core.precision import as_precision_policy
from repro.core.plcg_scan import (plcg_scan, run_restart_driver,
                                  stab_iter_slack)
from repro.core.results import SolveResult
from repro.core.solver_cache import WeakCallableCache

from .operator import (DistributedOperator, as_dist_operator,
                       resolve_prec_local)

#: Jitted mesh sweeps, keyed weakly on the operator (dropping the operator
#: releases the compiled shard_map program).
_MESH_SWEEP_CACHE = WeakCallableCache(maxsize=16)


def _batch_spec(spec: P) -> P:
    """Prepend an unsharded lane axis to a field PartitionSpec."""
    return P(*((None,) + tuple(spec)))


def _is_bindable_dist(op) -> bool:
    """True for a distributed operator carrying a rebindable context:
    ``matvec_local_ctx(context, v_local)`` plus ``context`` /
    ``context_specs()`` (the mesh twin of ``repro.core.linop.
    BindableOperator``)."""
    return (callable(getattr(op, "matvec_local_ctx", None))
            and hasattr(op, "context")
            and callable(getattr(op, "context_specs", None)))


def _shard_jit(op: DistributedOperator, one, *, batched: bool,
               n_extra: int = 0, n_out: int = 4, trace_event=None,
               ctx_specs=None):
    """Wrap a per-shard local body into the jitted shard_map program.

    ``one(b_blk, x_blk, *extra)`` maps one local field block (plus
    ``n_extra`` replicated scalar operands, e.g. an iteration budget) to
    ``(x_blk, *n_out replicated scalar/trace outputs)``; with
    ``batched`` the RHS lanes are vmapped OUTSIDE the domain
    decomposition (extras are shared across lanes) and
    ``trace_event(shape)``, when given, logs a compile event like the
    single-device batched engine.

    ``ctx_specs`` (a pytree of ``PartitionSpec`` from a bindable
    operator's ``context_specs()``) prepends a traced context operand:
    ``one(ctx, b_blk, x_blk, *extra)``, shared across vmapped lanes, so
    rebinding the operator data between solves reuses the one compiled
    shard_map program.
    """
    spec = op.spec()
    n_ctx = 0 if ctx_specs is None else 1
    if batched:
        def local_run(*args):
            b_blk = args[n_ctx]
            if (trace_event is not None
                    and len(_engine.BATCH_TRACE_EVENTS) < 4096):
                _engine.BATCH_TRACE_EVENTS.append(
                    trace_event(tuple(b_blk.shape)))
            in_axes = (None,) * n_ctx + (0, 0) + (None,) * n_extra
            return jax.vmap(one, in_axes=in_axes)(*args)
        io_spec = _batch_spec(spec)
    else:
        local_run, io_spec = one, spec
    in_specs = ((ctx_specs,) if n_ctx else ()) \
        + (io_spec, io_spec) + (P(),) * n_extra
    fn = shard_map_compat(
        local_run, mesh=op.mesh,
        in_specs=in_specs,
        out_specs=(io_spec,) + (P(),) * n_out,
        check=False,
    )
    return jax.jit(fn)


def _weak_prec_resolver(op, prec):
    """Trace-time shard-local resolution of ``prec`` on ``op`` (pass the
    operator's ``weakref.proxy`` so neither object is pinned).

    The returned thunk runs INSIDE the traced ``one`` body, so the
    shard-local closure (which binds the preconditioner's arrays) lives
    only for the duration of the trace -- the cached compiled program
    never pins the Preconditioner object, exactly like the operator's
    ``weakref.proxy``.  When the preconditioner died and a retrace is
    attempted, this raises ``ReferenceError`` (and the weak cache key has
    already evicted the entry).
    """
    if prec is None:
        return lambda: None
    mref = weakref.ref(prec)

    def resolve():
        M = mref()
        if M is None:
            raise ReferenceError(
                "mesh preconditioner was garbage-collected; rebuild the "
                "sweep (see repro.core.clear_solver_cache)")
        return resolve_prec_local(op, M)

    return resolve


def plcg_mesh_sweep(op: DistributedOperator, *, l: int, iters: int,
                    sigma: Sequence[float], tol: float = 0.0,
                    exploit_symmetry: bool = True, batched: bool = False,
                    prec=None, comm=None, restart=None, rr_period=None,
                    ritz_refresh: bool = True, precision=None):
    """Build (cached) the jitted p(l)-CG mesh sweep.

    Returns a jitted callable ``(b, x0, k_budget) -> (x, resnorms,
    converged, breakdown, k_done, committed, restarts, replacements)``
    where ``b``/``x0`` are global fields
    of shape ``op.global_shape`` (``(nrhs, *global_shape)`` when
    ``batched``) and ``k_budget`` is the (traced) solution-update budget
    -- the restart driver passes the *remaining* global budget per sweep
    so every sweep reuses ONE compiled program.  ``restart`` /
    ``rr_period`` enable the scan engine's in-scan stability path
    (per-lane re-seed on breakdown / periodic true-residual replacement;
    see ``plcg_scan``); the widened reduction payload still rides the
    one per-iteration collective of the selected ``comm`` policy, so the
    per-iteration collective signature is unchanged.  ``prec`` is a
    structured
    ``repro.core.precond.Preconditioner`` resolved shard-locally via
    :func:`resolve_prec_local`; its apply is communication-free (or
    neighbor-halo only), so the traced program STILL contains exactly ONE
    reduction per scan body -- with the default blocking ``comm`` policy
    a single ``psum``, the structural acceptance gate verified by
    ``repro.kernels.introspect.count_primitive_in_scan_bodies``.

    ``precision`` (a ``repro.core.precision.PrecisionPolicy`` or spec
    accepted by ``as_precision_policy``) splits window *storage* dtype
    from scalar *compute* dtype inside the scan engine.  Every dot
    payload, in-flight queue slot and therefore every collective buffer
    (psum / psum_scatter / all_gather / ring ppermute) stays in the
    compute dtype -- a bf16-storage policy changes the bytes each shard
    streams locally, never the collective signature or its f32/f64
    payload dtype (gated structurally by
    ``collective_payload_dtypes_in_scan_bodies``).

    ``comm`` (a ``repro.core.comm.CommPolicy`` or mode string) selects
    how that reduction is realized: ``"overlap"`` splits it into a
    ``psum_scatter`` at issue and an ``all_gather`` ``depth`` iterations
    later (zero bare psums in the scan body -- the reduction is
    structurally in flight); ``"ring"`` stages circulate-accumulate
    ``ppermute`` hops across the queue shifts.  The policy is part of the
    sweep cache key; its operator capabilities are validated here via
    ``build_comm_runtime`` (prepared sessions validate earlier, at
    construction).
    """
    sig = tuple(sigma)
    policy = as_comm_policy(comm)
    pp = as_precision_policy(precision)
    bind = _is_bindable_dist(op)

    def build():
        # the cached jitted program must not pin the operator (the cache
        # key holds it weakly and evicts on death): trace through a weak
        # proxy, like the single-device sweep's weakly_callable closures
        opref = weakref.proxy(op)
        resolve = _weak_prec_resolver(opref, prec)
        runtime = build_comm_runtime(policy, opref, l)

        def scan_body(matvec_local, b_blk, x_blk, k_budget):
            out = plcg_scan(
                matvec_local, b_blk.reshape(-1), x_blk.reshape(-1),
                l=l, iters=iters, sigma=sig, tol=tol,
                prec=resolve(),
                dot_local=opref.dot_local,
                reduce_scalars=opref.reduce_scalars,
                exploit_symmetry=exploit_symmetry, k_budget=k_budget,
                comm=runtime,
                restart=restart, rr_period=rr_period,
                ritz_refresh=ritz_refresh, precision=pp,
            )
            return (out.x.reshape(b_blk.shape), out.resnorms, out.converged,
                    out.breakdown, out.k_done, out.committed, out.restarts,
                    out.replacements)

        if bind:
            # the context is a traced leading operand of the shard_map
            # program (sharded per the operator's context_specs), so
            # rebinding operator data never retraces
            def one(ctx, b_blk, x_blk, k_budget):
                return scan_body(lambda v: opref.matvec_local_ctx(ctx, v),
                                 b_blk, x_blk, k_budget)
            ctx_specs = op.context_specs()
        else:
            def one(b_blk, x_blk, k_budget):
                return scan_body(opref.matvec_local, b_blk, x_blk, k_budget)
            ctx_specs = None

        return _shard_jit(op, one, batched=batched, n_extra=1, n_out=7,
                          trace_event=lambda shape: ("plcg@mesh", shape, l),
                          ctx_specs=ctx_specs)

    return _MESH_SWEEP_CACHE.get_or_build(
        (op, prec),
        ("plcg", l, iters, sig, tol, exploit_symmetry, batched, policy,
         restart, rr_period, ritz_refresh, pp, bind),
        build)


def cg_mesh_sweep(op: DistributedOperator, *, iters: int, tol: float = 0.0,
                  batched: bool = False, prec=None):
    """Build (cached) the jitted classic-CG mesh sweep (the two-psum
    baseline for the strong-scaling comparisons, paper Figs. 3-5).

    Same ``x0``/early-stop contract as the pipelined sweep: the initial
    guess seeds ``r0 = b - A x0``, converged state freezes through the
    ``done`` select, and the committed-update count ``k_done`` is
    reported.  With ``prec`` (shard-local, see :func:`resolve_prec_local`)
    this is preconditioned CG; the ``<r, u>`` and ``<r, r>`` reductions
    ride ONE stacked psum so the per-iteration collective count stays at
    the baseline's two.  Returns a jitted callable ``(b, x0) -> (x,
    resnorms, resnorm0, converged, k_done)``.
    """

    bind = _is_bindable_dist(op)

    def build():
        opref = weakref.proxy(op)       # see plcg_mesh_sweep
        resolve = _weak_prec_resolver(opref, prec)

        def cg_body(matvec_local, b_blk, x_blk):
            plocal = resolve()
            bflat = b_blk.reshape(-1)
            bnorm2 = opref.reduce_scalars(opref.dot_local(bflat, bflat))
            bnorm2 = jnp.where(bnorm2 == 0, 1.0, bnorm2)
            r0 = bflat - matvec_local(x_blk.reshape(-1))
            if plocal is None:
                gamma0 = opref.reduce_scalars(opref.dot_local(r0, r0))
                rr0 = gamma0
                u0 = r0
            else:
                u0 = plocal(r0)
                pay0 = opref.reduce_scalars(jnp.stack(
                    [opref.dot_local(r0, u0), opref.dot_local(r0, r0)]))
                gamma0, rr0 = pay0[0], pay0[1]
            done0 = rr0 <= (tol ** 2) * bnorm2

            # the preconditioned carry adds rr = <r, r> (for the stopping
            # test); the unpreconditioned carry stays identical to the
            # two-psum baseline (there rr IS gamma)
            def body(st, _):
                if plocal is None:
                    x, r, p, gamma, k, done = st
                    rr = gamma
                else:
                    x, r, p, gamma, rr, k, done = st
                s = matvec_local(p)
                sp = opref.reduce_scalars(
                    opref.dot_local(s, p))                  # sync psum 1
                alpha = gamma / sp
                x2 = x + alpha * p
                r2 = r - alpha * s
                if plocal is None:
                    gamma2 = opref.reduce_scalars(
                        opref.dot_local(r2, r2))            # sync psum 2
                    rr2 = gamma2
                    u2 = r2
                else:
                    u2 = plocal(r2)
                    pay = opref.reduce_scalars(jnp.stack(
                        [opref.dot_local(r2, u2),
                         opref.dot_local(r2, r2)]))         # sync psum 2
                    gamma2, rr2 = pay[0], pay[1]
                p2 = u2 + (gamma2 / gamma) * p
                conv = rr2 <= (tol ** 2) * bnorm2
                if plocal is None:
                    new = (x2, r2, p2, gamma2, k + 1, done | conv)
                else:
                    new = (x2, r2, p2, gamma2, rr2, k + 1, done | conv)
                out = jax.tree.map(lambda a, o: jnp.where(done, o, a),
                                   new, st)
                return out, jnp.sqrt(jnp.where(done, rr, rr2))

            st0 = ((x_blk.reshape(-1), r0, u0, gamma0, jnp.asarray(0),
                    done0) if plocal is None else
                   (x_blk.reshape(-1), r0, u0, gamma0, rr0,
                    jnp.asarray(0), done0))
            st, resn = jax.lax.scan(body, st0, jnp.arange(iters))
            return (st[0].reshape(b_blk.shape), resn, jnp.sqrt(rr0),
                    st[-1], st[-2])

        if bind:
            def one(ctx, b_blk, x_blk):
                return cg_body(lambda v: opref.matvec_local_ctx(ctx, v),
                               b_blk, x_blk)
            ctx_specs = op.context_specs()
        else:
            def one(b_blk, x_blk):
                return cg_body(opref.matvec_local, b_blk, x_blk)
            ctx_specs = None

        return _shard_jit(op, one, batched=batched, ctx_specs=ctx_specs)

    return _MESH_SWEEP_CACHE.get_or_build(
        (op, prec), ("cg", iters, tol, batched, bind), build)


# --------------------------------------------------------------------------
# front-end dispatch (called by repro.core.solve when mesh= is given)
# --------------------------------------------------------------------------

def _canonicalize_b(op: DistributedOperator, b, x0):
    """Reshape flat inputs to the operator's global field shape.

    Accepts ``global_shape``, ``(nrhs, *global_shape)``, flat ``(n,)`` and
    stacked-flat ``(nrhs, n)``.  Returns (b, x0, batched, orig_shape).
    """
    gshape = tuple(op.global_shape)
    n = int(np.prod(gshape))
    b = jnp.asarray(b)
    orig_shape = b.shape
    if b.shape == (n,):
        b = b.reshape(gshape)
    elif b.ndim == 2 and b.shape[-1] == n and b.shape != gshape:
        b = b.reshape((b.shape[0],) + gshape)
    batched = b.ndim == len(gshape) + 1 and b.shape[1:] == gshape
    if not batched and b.shape != gshape:
        raise ValueError(
            f"b of shape {orig_shape} does not match the operator's global "
            f"field {gshape} (or (nrhs, *{gshape}) / flat ({n},))")
    x0 = jnp.zeros_like(b) if x0 is None else jnp.asarray(x0).reshape(b.shape)
    return b, x0, batched, orig_shape


def _mesh_plcg(op, b, x0, *, tol, maxiter, l, sigma, prec=None,
               exploit_symmetry: bool = True,
               max_restarts=None, comm=None, restart=None,
               residual_replacement=None, ritz_refresh: bool = True,
               precision=None, get_sweep=None) -> SolveResult:
    b, x0, batched, orig_shape = _canonicalize_b(op, b, x0)
    sig = tuple(sigma)
    policy = as_comm_policy(comm)
    pp = as_precision_policy(precision)
    # the in-scan stability path (restart= / residual_replacement=,
    # normalized by engine._prepare_restart) runs ONE sweep whose lanes
    # re-seed themselves in-trace; the sweep needs stab_iter_slack extra
    # bodies so the update budget stays spendable through re-seeds
    stab = restart is not None or residual_replacement is not None
    slack = stab_iter_slack(l, restart, residual_replacement, maxiter)
    if get_sweep is None:
        def get_sweep(*, iters, batched):
            return plcg_mesh_sweep(op, l=l, iters=iters, sigma=sig,
                                   tol=tol,
                                   exploit_symmetry=exploit_symmetry,
                                   batched=batched, prec=prec, comm=policy,
                                   restart=restart,
                                   rr_period=residual_replacement,
                                   ritz_refresh=ritz_refresh, precision=pp)
    if _is_bindable_dist(op):
        # bind the CURRENT context at call time; the raw sweep (cached /
        # strongly held by a session) takes it as a traced operand
        raw_get = get_sweep

        def get_sweep(*, iters, batched):
            raw, ctx = raw_get(iters=iters, batched=batched), op.context
            return lambda bb, xx, kb: raw(ctx, bb, xx, kb)
    base_info = {"l": l, "sigma": list(sig), "backend": None,
                 "mesh": dict(op.mesh.shape), "comm": policy.mode,
                 "precision": None if pp.is_default else pp,
                 # a split/ring policy leaves ZERO blocking psums in the
                 # scan body (the init reduction outside it stays a psum)
                 "psums_per_iter": 1 if policy.is_blocking else 0,
                 "restart": restart,
                 "residual_replacement": residual_replacement,
                 "prec": getattr(prec, "name", None)}
    if policy.mode == "overlap":
        base_info["overlap_depth"] = policy.resolve_depth(l)

    if batched:
        if max_restarts is not None:
            # mirror the single-device batched engine: don't silently
            # drop a flag the caller believes is active (the in-scan
            # restart= knob is the batched-capable replacement)
            raise ValueError(
                "options ['max_restarts'] are not supported by the "
                "batched mesh engine (the host restart loop is "
                "single-RHS; use the in-scan restart= knob for per-lane "
                "recovery)")
        # one sweep, per-lane convergence masking inside the scan; with
        # restart=/residual_replacement= lanes also re-seed themselves
        # in-trace (still ONE compiled sweep, zero host round-trips)
        fn = get_sweep(iters=maxiter + l + 1 + slack, batched=True)
        out = fn(b, x0, maxiter + 1)
        x, resn, conv, brk, k_done, committed, restarts, repl = out
        resn = np.asarray(resn)                         # (nrhs, iters)
        conv = np.asarray(conv)
        brk = np.asarray(brk)
        k_done = np.asarray(k_done)
        if stab:
            committed = np.asarray(committed, dtype=bool)
            resnorms = [[float(r) for r in row[m]]
                        for row, m in zip(resn, committed)]
            restarts_pl = np.asarray(restarts)
            repl_pl = np.asarray(repl)
        else:
            # lane j commits |zeta_k| for k = 0..k_done[j] at trace
            # indices l..l+k_done[j] (count-sliced, as the vmap engine)
            resnorms = [[float(r) for r in row[l: l + int(k) + 1]]
                        for row, k in zip(resn, k_done)]
            restarts_pl = np.zeros(int(b.shape[0]), dtype=int)
            repl_pl = np.zeros(int(b.shape[0]), dtype=int)
        return SolveResult(
            x=x.reshape(orig_shape),
            resnorms=resnorms,
            iters=int(k_done.max()) + 1,
            converged=bool(conv.all()),
            breakdowns=int(brk.sum()) + int(restarts_pl.sum()),
            restarts=int(restarts_pl.sum()),
            replacements=int(repl_pl.sum()),
            info={**base_info, "method": f"p({l})-CG[scan,mesh+vmap]",
                  "batched": "shard_map+vmap", "nrhs": int(b.shape[0]),
                  "per_rhs_converged": conv,
                  "per_rhs_iters": k_done + 1,
                  "per_rhs_breakdown": brk,
                  "per_rhs_restarts": restarts_pl,
                  "per_rhs_replacements": repl_pl},
        )

    # single RHS: ONE restart semantics, shared with the single-device
    # plcg_solve via run_restart_driver.  In-scan mode (restart= /
    # residual_replacement=) runs one compiled sweep whose re-seeds
    # happen in-trace; the legacy host loop (deprecated, shift-free
    # re-init) re-enters the sweep with the remaining budget when only
    # the max_restarts escape hatch is given.  Either way the budget is
    # a traced operand of ONE fixed-size compiled program, so restarts
    # never retrace/recompile the shard_map sweep.
    if stab:
        fn = get_sweep(iters=maxiter + l + 1 + slack, batched=False)
    else:
        fn = get_sweep(iters=maxiter + l, batched=False)
    x, resnorms, info = run_restart_driver(
        fn, b, x0, tol=tol, maxiter=maxiter,
        max_restarts=5 if max_restarts is None else max_restarts,
        bnorm=float(jnp.linalg.norm(b)) or 1.0, in_scan=stab)
    return SolveResult(
        x=x.reshape(orig_shape), resnorms=resnorms,
        iters=info["iterations"], converged=info["converged"],
        breakdowns=info["breakdowns"], restarts=info["restarts"],
        replacements=info.get("replacements", 0),
        info={**base_info, "method": f"p({l})-CG[scan,mesh]"},
    )


def _mesh_cg(op, b, x0, *, tol, maxiter, prec=None,
             get_sweep=None) -> SolveResult:
    b, x0, batched, orig_shape = _canonicalize_b(op, b, x0)
    if get_sweep is None:
        def get_sweep(*, iters, batched):
            return cg_mesh_sweep(op, iters=iters, tol=tol, batched=batched,
                                 prec=prec)
    fn = get_sweep(iters=maxiter, batched=batched)
    if _is_bindable_dist(op):
        raw, ctx = fn, op.context
        fn = lambda bb, xx: raw(ctx, bb, xx)  # noqa: E731
    x, resn, resn0, conv, k_done = fn(b, x0)
    base_info = {"method": "cg[mesh]", "mesh": dict(op.mesh.shape),
                 "psums_per_iter": 2,
                 "prec": getattr(prec, "name", None)}
    if batched:
        resn = np.asarray(resn)
        resn0 = np.asarray(resn0)
        conv = np.asarray(conv)
        k_done = np.asarray(k_done)
        return SolveResult(
            x=x.reshape(orig_shape),
            resnorms=[[float(r0)] + [float(r) for r in row[:int(k)]]
                      for row, r0, k in zip(resn, resn0, k_done)],
            iters=int(k_done.max()), converged=bool(conv.all()),
            info={**base_info, "batched": "shard_map+vmap",
                  "nrhs": int(b.shape[0]),
                  "per_rhs_converged": conv, "per_rhs_iters": k_done},
        )
    k = int(k_done)
    return SolveResult(
        x=x.reshape(orig_shape),
        resnorms=[float(resn0)] + [float(r) for r in np.asarray(resn)[:k]],
        iters=k, converged=bool(conv), info=base_info,
    )


#: method name -> mesh adapter.  The CAPABILITY lives in the registry
#: (``MethodSpec.supports_mesh``, checked by ``solve()``); this dict is
#: only the dispatch table, and a skew between the two raises loudly in
#: :func:`solve_on_mesh` instead of producing a second error message.
_MESH_METHODS = {
    "cg": _mesh_cg,
    "plcg": _mesh_plcg,
    "plcg_scan": _mesh_plcg,
}


def mesh_methods() -> tuple:
    """Registry methods with a mesh-aware execution path (derived from
    the ``supports_mesh`` capability flags -- single source of truth)."""
    return _engine.methods_supporting("mesh")


class PreparedMeshSolver:
    """One-time-validated mesh solver session (``repro.core.session``'s
    mesh back-end).

    Construction performs everything ``solve(..., mesh=...)`` used to
    redo per call: method/adaptor dispatch, operator promotion
    (:func:`as_dist_operator`), early shard-local preconditioner
    resolution, option validation and sigma resolution.  The jitted
    shard_map sweeps are built through the same weak-key cache as the
    one-shot path (so the two entry points share compilations) but are
    additionally held **strongly** in ``self._sweeps`` -- a live session
    keeps its compiled programs through ``clear_solver_cache()`` and
    weak-cache eviction, and ``solve()`` never re-derives them through
    the cache lookup.

    ``backend`` is ignored on this path (the front-end already warned):
    the injected local-partial dots bypass every kernel tier by
    construction.
    """

    def __init__(self, spec, A, mesh, *, M, l, sigma, spectrum,
                 comm=None, restart=None, residual_replacement=None,
                 precision=None, **options):
        if l == "auto" or comm == "auto":
            # the sentinels are resolved by prepare_on_mesh (which owns
            # the tol the calibration clamps against); reaching this
            # constructor with one is a wiring error, not a user error
            raise ValueError(
                "l='auto' / comm='auto' must be resolved before "
                "PreparedMeshSolver construction; build the session via "
                "prepare_on_mesh(...) (or session.Solver), which "
                "calibrates and passes the concrete depth/policy")
        if spec.name not in _MESH_METHODS:
            if getattr(spec, "supports_mesh", False):
                raise RuntimeError(
                    f"method {spec.name!r} declares supports_mesh=True but "
                    "has no adapter in distributed.plcg_dist._MESH_METHODS; "
                    "register one (the registry flag and the dispatch table "
                    "must move together)")
            raise ValueError(
                f"method {spec.name!r} has no mesh-aware execution path; "
                f"methods available on a mesh: {', '.join(mesh_methods())}")
        self.spec = spec
        self.op = as_dist_operator(A, mesh)
        self.prec = M
        if M is not None:
            resolve_prec_local(self.op, M)      # early, uniform validation
        # mesh-path option restriction + comm policy: both validated once
        # here through the engine's declarative tables (MethodSpec.
        # mesh_options / supports_comm) -- the adapters carry no
        # allow-lists of their own anymore
        _engine._prepare_mesh_options(spec, options)
        self.comm = _engine._prepare_comm(spec, comm, on_mesh=True)
        if spec.name == "cg":
            # same contract as the single-device cg adapter: l/sigma/
            # spectrum are pipelined-method knobs and are ignored
            self.sig = None
        else:
            self.sig = tuple(_engine._resolve_sigma(sigma, spectrum, l))
            # early, uniform validation of the operator's split-phase /
            # ring capability and the depth/hop constraints against l --
            # a prepared session never fails at first solve
            build_comm_runtime(self.comm, self.op, l)
        self.l = l
        # normalized stability knobs (engine._prepare_restart ran in the
        # session front end); baked into every prepared plcg sweep
        self.restart = restart
        self.residual_replacement = residual_replacement
        # normalized precision policy (engine._prepare_precision gated it
        # on the capability flag); collective payloads stay in its
        # compute dtype by construction of the scan engine
        self.precision = as_precision_policy(precision)
        self.auto = None            # AutoDecision when prepare_on_mesh
        self.options = dict(options)    # calibrated l/comm
        self._sweeps: dict = {}         # strong refs to jitted sweeps

    @property
    def builds(self) -> int:
        """Number of distinct jitted sweeps this session holds."""
        return len(self._sweeps)

    def _get_sweep(self, kind: str, tol: float):
        """Memoizing sweep getter bound to one (kind, tol); the returned
        callable has the ``get_sweep(iters=, batched=)`` signature of the
        ``_mesh_plcg`` / ``_mesh_cg`` runners."""

        def get(*, iters, batched):
            key = (kind, float(tol), int(iters), bool(batched))
            if key not in self._sweeps:
                if kind == "plcg":
                    self._sweeps[key] = plcg_mesh_sweep(
                        self.op, l=self.l, iters=iters, sigma=self.sig,
                        tol=tol, batched=batched, prec=self.prec,
                        comm=self.comm,
                        restart=self.restart,
                        rr_period=self.residual_replacement,
                        ritz_refresh=self.options.get("ritz_refresh", True),
                        precision=self.precision,
                        exploit_symmetry=self.options.get(
                            "exploit_symmetry", True))
                else:
                    self._sweeps[key] = cg_mesh_sweep(
                        self.op, iters=iters, tol=tol, batched=batched,
                        prec=self.prec)
            return self._sweeps[key]

        return get

    def prepare(self, *, tol: float, maxiter: int,
                batched: bool = False) -> None:
        """Eagerly build (and strongly hold) the sweep for one
        (tol, maxiter, batched) configuration -- jit wrapping only, the
        XLA compile itself still happens at the first real call."""
        if self.spec.name == "cg":
            self._get_sweep("cg", tol)(iters=maxiter, batched=batched)
        else:
            stab = (self.restart is not None
                    or self.residual_replacement is not None)
            if stab:
                iters = maxiter + self.l + 1 + stab_iter_slack(
                    self.l, self.restart, self.residual_replacement,
                    maxiter)
            else:
                iters = maxiter + self.l + (1 if batched else 0)
            self._get_sweep("plcg", tol)(iters=iters, batched=batched)

    def solve(self, b, x0=None, *, tol: float, maxiter: int) -> SolveResult:
        if self.spec.name == "cg":
            return _mesh_cg(self.op, b, x0, tol=tol, maxiter=maxiter,
                            prec=self.prec,
                            get_sweep=self._get_sweep("cg", tol))
        return _MESH_METHODS[self.spec.name](
            self.op, b, x0, tol=tol, maxiter=maxiter, l=self.l,
            sigma=self.sig, prec=self.prec, comm=self.comm,
            restart=self.restart,
            residual_replacement=self.residual_replacement,
            precision=self.precision,
            get_sweep=self._get_sweep("plcg", tol), **self.options)


def prepare_on_mesh(spec, A, mesh, *, M, l, sigma, spectrum, backend=None,
                    comm=None, restart=None, residual_replacement=None,
                    precision=None, tol: float = 1e-8,
                    **options) -> PreparedMeshSolver:
    """Build the prepared mesh session behind ``session.Solver(mesh=...)``
    (validation / promotion / resolution once; see
    :class:`PreparedMeshSolver`).  ``comm`` selects the reduction policy
    (``repro.core.comm.CommPolicy`` or mode string); ``restart`` /
    ``residual_replacement`` are the engine-normalized in-scan stability
    knobs baked into every prepared pipelined sweep.

    ``l="auto"`` / ``comm="auto"`` (the sentinels ``engine._prepare_depth``
    / ``engine._prepare_comm`` pass through) are resolved HERE, once: the
    operator is promoted early and ``repro.core.autotune.resolve_auto``
    measures its SPMV / per-mode reduction / per-depth sweep latencies on
    the live mesh (cached weakly per operator+config, so same-shape
    sessions re-measure nothing), then solves the paper's latency model
    for the fastest ``(l, comm, d)`` whose precision floor still reaches
    ``tol`` -- which is why this entry point takes the session ``tol``.
    The decision lands on ``session.auto`` (reported per solve as
    ``SolveResult.info["auto"]``)."""
    del backend     # front-end warned; bypassed by construction here
    decision = None
    if l == "auto" or comm == "auto":
        from repro.core.autotune import resolve_auto
        op = as_dist_operator(A, mesh)      # cached; the session reuses it
        decision = resolve_auto(op, l=l, comm=comm, tol=tol,
                                precision=precision)
        l, comm = decision.l, decision.comm
        A, mesh = op, None                  # already bound to its mesh
    sess = PreparedMeshSolver(spec, A, mesh, M=M, l=l, sigma=sigma,
                              spectrum=spectrum, comm=comm, restart=restart,
                              residual_replacement=residual_replacement,
                              precision=precision, **options)
    sess.auto = decision
    return sess


def solve_on_mesh(spec, A, b, *, mesh, x0, tol, maxiter, M, l, sigma,
                  spectrum, backend, comm=None, restart=None,
                  residual_replacement=None, precision=None,
                  **options) -> SolveResult:
    """One-shot mesh-aware dispatch behind ``repro.core.solve(mesh=...)``:
    a thin wrapper preparing a :class:`PreparedMeshSolver` and running it
    on ``b`` (the session API is the primary entry point; this keeps the
    legacy call-per-solve contract)."""
    return prepare_on_mesh(spec, A, mesh, M=M, l=l, sigma=sigma,
                           spectrum=spectrum, backend=backend, comm=comm,
                           restart=restart,
                           residual_replacement=residual_replacement,
                           precision=precision, tol=tol,
                           **options).solve(b, x0, tol=tol, maxiter=maxiter)
