from .config import ModelConfig, MoEConfig, SSMConfig, HybridConfig, reduced
from .schema import abstract_params, init_params, param_shardings, model_schema
from .transformer import (forward, loss_fn, prefill, decode_step, init_caches,
                          abstract_caches, cache_shardings, cache_spec)

__all__ = [
    "ModelConfig", "MoEConfig", "SSMConfig", "HybridConfig", "reduced",
    "abstract_params", "init_params", "param_shardings", "model_schema",
    "forward", "loss_fn", "prefill", "decode_step", "init_caches",
    "abstract_caches", "cache_shardings", "cache_spec",
]
