"""Layer forward math: norms, RoPE variants, attention (direct + blocked
flash-style), SwiGLU/GeLU MLPs and capacity-based top-k MoE.

Everything is a pure function over param dicts produced by ``schema.py``;
activations are annotated through ``sharding.constrain`` so the same code
lowers on one device or on the production mesh.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .flash import flash_attention
from .sharding import constrain

F32 = jnp.float32


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(F32)).astype(x.dtype)


def _head_rms(scale: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(F32)).astype(x.dtype)


# --------------------------------------------------------------------------
# rotary embeddings: full / partial (chatglm "2d") / M-RoPE (qwen2-vl)
# --------------------------------------------------------------------------

def apply_rope(cfg: ModelConfig, x: jax.Array, pos: jax.Array) -> jax.Array:
    """x: (B, S, Hx, hd); pos: (B, S) int32 or (3, B, S) for mrope."""
    if cfg.rope_style == "none":
        return x
    hd = x.shape[-1]
    rot = int(hd * (cfg.rotary_pct if cfg.rope_style == "partial" else 1.0))
    rot -= rot % 2
    half = rot // 2
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(half, dtype=F32) / half))
    if cfg.rope_style == "mrope":
        secs = cfg.mrope_sections
        assert sum(secs) == half, (secs, half)
        parts, off = [], 0
        for comp, sec in enumerate(secs):
            parts.append(pos[comp].astype(F32)[..., None] * inv[off:off + sec])
            off += sec
        ang = jnp.concatenate(parts, axis=-1)            # (B, S, half)
    else:
        ang = pos.astype(F32)[..., None] * inv           # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    xr, xp = x[..., :rot].astype(F32), x[..., rot:]
    x1, x2 = xr[..., :half], xr[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


# --------------------------------------------------------------------------
# scaled dot-product attention
# --------------------------------------------------------------------------

def _direct_sdpa(q, k, v, *, causal, window, q_offset, kv_pos=None,
                 kv_len=None):
    """q: (B,S,K,G,hd), k/v: (B,T,K,hd).  Small-S/T path with explicit mask."""
    B, S, K, G, hd = q.shape
    T = k.shape[1]
    scale = hd ** -0.5
    # preferred_element_type avoids materializing an f32 copy of the whole
    # KV cache (2x decode HBM in the dry-run)
    s = jnp.einsum("bskgh,btkh->bkgst", q, k,
                   preferred_element_type=F32) * scale
    qpos = q_offset + jnp.arange(S)
    kpos = kv_pos if kv_pos is not None else jnp.arange(T)
    mask = jnp.ones((S, T) if kpos.ndim == 1 else (B, S, T), bool)
    if causal:
        mask = mask & (kpos[..., None, :] <= qpos[:, None])
    if window:
        mask = mask & (kpos[..., None, :] > qpos[:, None] - window)
    if kv_len is not None:
        mask = mask & (kpos[..., None, :] < kv_len) & (kpos[..., None, :] >= 0)
    mask = mask if mask.ndim == 3 else mask[None]
    s = jnp.where(mask[:, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)          # fully-masked rows
    out = jnp.einsum("bkgst,btkh->bskgh", p.astype(v.dtype), v,
                     preferred_element_type=F32)
    return out.astype(q.dtype)


def _blocked_sdpa(q, k, v, *, causal, window, q_block=512, kv_block=1024,
                  block_skip=False):
    """Flash-style online-softmax attention, O(q_block*kv_block) memory.

    ``block_skip``: runtime-skip fully-masked kv blocks (beyond-paper perf
    knob -- removes the 2x causal flop waste; see EXPERIMENTS.md SPerf).
    """
    B, S, K, G, hd = q.shape
    T = k.shape[1]
    qb = min(q_block, S)
    kb = min(kv_block, T)
    nq, nk = S // qb, T // kb
    assert S % qb == 0 and T % kb == 0, (S, qb, T, kb)
    scale = hd ** -0.5
    qs = q.reshape(B, nq, qb, K, G, hd)
    ks = k.reshape(B, nk, kb, K, hd)
    vs = v.reshape(B, nk, kb, K, hd)

    def q_step(_, qi):
        qblk = qs[:, qi].astype(F32) * scale      # (B,qb,K,G,hd)
        qpos = qi * qb + jnp.arange(qb)
        m0 = jnp.full((B, K, G, qb), -jnp.inf, F32)
        l0 = jnp.zeros((B, K, G, qb), F32)
        a0 = jnp.zeros((B, qb, K, G, hd), F32)

        def kv_step(carry, kj):
            m, l, acc = carry

            def compute(_):
                kblk = ks[:, kj].astype(F32)
                vblk = vs[:, kj].astype(F32)
                kpos = kj * kb + jnp.arange(kb)
                s = jnp.einsum("bskgh,btkh->bkgst", qblk, kblk)
                msk = jnp.ones((qb, kb), bool)
                if causal:
                    msk = msk & (kpos[None, :] <= qpos[:, None])
                if window:
                    msk = msk & (kpos[None, :] > qpos[:, None] - window)
                s = jnp.where(msk, s, -jnp.inf)
                m_new = jnp.maximum(m, s.max(axis=-1))
                corr = jnp.exp(m - m_new)
                pexp = jnp.exp(s - m_new[..., None])
                pexp = jnp.where(jnp.isinf(m_new)[..., None], 0.0, pexp)
                corr = jnp.where(jnp.isinf(m_new), 0.0, corr)
                l_new = l * corr + pexp.sum(axis=-1)
                a_new = (acc * corr.transpose(0, 3, 1, 2)[..., None]
                         + jnp.einsum("bkgst,btkh->bskgh", pexp, vblk))
                return m_new, l_new, a_new

            if block_skip and (causal or window):
                lo_ok = (kj * kb <= qpos[-1]) if causal else True
                hi_ok = ((kj + 1) * kb - 1 > qpos[0] - window) if window else True
                live = jnp.logical_and(lo_ok, hi_ok) if window else lo_ok
                m2, l2, a2 = jax.lax.cond(live, compute,
                                          lambda _: (m, l, acc), None)
            else:
                m2, l2, a2 = compute(None)
            return (m2, l2, a2), None

        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        lt = l.transpose(0, 3, 1, 2)[..., None]
        out = acc / jnp.where(lt == 0, 1.0, lt)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq))   # (nq,B,qb,K,G,hd)
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, K, G, hd)


def attention(cfg: ModelConfig, p: dict, x: jax.Array, *, pos: jax.Array,
              mode: str = "train", cache: Optional[dict] = None,
              window: int = 0, kv_states: Optional[jax.Array] = None,
              causal: Optional[bool] = None, block_skip: bool = False):
    """Returns (out, new_cache).  modes: train | prefill | decode | cross."""
    B, S, d = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    G = H // K
    causal = cfg.causal if causal is None else causal

    q = x @ p["wq"]
    if "bq" in p:
        q = q + p["bq"]
    q = q.reshape(B, S, H, hd)
    if mode == "cross" and cache is not None and "k" in cache:
        k, v = cache["k"], cache["v"]        # precomputed encoder K/V
        new_cache = cache
    else:
        src = kv_states if kv_states is not None else x
        Tk = src.shape[1]
        k = src @ p["wk"]
        v = src @ p["wv"]
        if "bk" in p:
            k, v = k + p["bk"], v + p["bv"]
        k = k.reshape(B, Tk, K, hd)
        v = v.reshape(B, Tk, K, hd)
        if cfg.qk_norm:
            k = _head_rms(p["k_norm"], k)
        if mode != "cross":
            k = apply_rope(cfg, k, pos)
        new_cache = {"k": k, "v": v} if mode == "cross" else None
    if cfg.qk_norm:
        q = _head_rms(p["q_norm"], q)
    if mode != "cross":
        q = apply_rope(cfg, q, pos)
    q = constrain(q.reshape(B, S, H * hd), ("batch", None, "tp")).reshape(B, S, H, hd)
    qg = q.reshape(B, S, K, G, hd)

    kv_pos = None
    kv_len = None
    if mode == "decode":
        assert cache is not None and S == 1
        ln = cache["len"]
        if "pos" in cache:                    # rolling local-attention window
            W = cache["k"].shape[1]
            slot = ln % W
            knew = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, 1)
            vnew = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, 1)
            posn = cache["pos"].at[slot].set(ln)
            new_cache = {"k": knew, "v": vnew, "pos": posn, "len": ln + 1}
            k, v, kv_pos = knew, vnew, posn
            kv_len = ln + 1
        else:
            knew = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, ln, 1)
            vnew = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, ln, 1)
            new_cache = {"k": knew, "v": vnew, "len": ln + 1}
            k, v = knew, vnew
            kv_len = ln + 1
        out = _direct_sdpa(qg, k, v, causal=causal, window=window,
                           q_offset=ln, kv_pos=kv_pos, kv_len=kv_len)
    elif S * k.shape[1] <= 1 << 22:
        out = _direct_sdpa(qg, k, v, causal=(causal and mode != "cross"),
                           window=window, q_offset=0)
    else:
        # custom-VJP flash attention: O(block) live memory in fwd AND bwd.
        # Gather the sequence dimension ONCE here -- seq-sharded inputs
        # would make GSPMD re-gather k/v inside every (q,kv) block step.
        qg = constrain(qg, ("batch", None, "heads", None, None))
        k = constrain(k, ("batch", None, "heads", None))
        v = constrain(v, ("batch", None, "heads", None))
        from .flash import get_blocks
        qb, kb = get_blocks()
        out = flash_attention(qg, k, v, causal and mode != "cross", window,
                              qb, kb)

    if mode == "prefill" and new_cache is None:
        if window:
            # rolling buffer: position p lives at slot p % W so that decode
            # (slot = len % W) continues seamlessly
            W = cache["k"].shape[1] if cache is not None else min(window, S)
            m = min(S, W)
            pos_keep = jnp.arange(S - m, S)
            slots = pos_keep % W
            kb = jnp.zeros((B, W) + k.shape[2:], k.dtype).at[:, slots].set(k[:, S - m:])
            vb = jnp.zeros((B, W) + v.shape[2:], v.dtype).at[:, slots].set(v[:, S - m:])
            posarr = jnp.full((W,), -1, jnp.int32).at[slots].set(pos_keep)
            new_cache = {"k": kb, "v": vb, "pos": posarr, "len": jnp.int32(S)}
        elif cache is not None and "k" in cache:
            # write into the pre-allocated decode buffer (may exceed S)
            kb = jax.lax.dynamic_update_slice_in_dim(
                cache["k"].astype(k.dtype), k, 0, 1)
            vb = jax.lax.dynamic_update_slice_in_dim(
                cache["v"].astype(v.dtype), v, 0, 1)
            new_cache = {"k": kb, "v": vb, "len": jnp.int32(S)}
        else:
            new_cache = {"k": k, "v": v, "len": jnp.int32(S)}

    out = out.reshape(B, S, H * hd)
    out = constrain(out, ("batch", None, "tp"))
    return out @ p["wo"], new_cache


# --------------------------------------------------------------------------
# MLPs and MoE
# --------------------------------------------------------------------------

def mlp_swiglu(p: dict, x: jax.Array) -> jax.Array:
    gu = x @ p["w_in"]
    gu = constrain(gu, ("batch", None, "tp"))
    gate, up = jnp.split(gu, 2, axis=-1)
    return (jax.nn.silu(gate.astype(F32)).astype(x.dtype) * up) @ p["w_out"]


def mlp_gelu(p: dict, x: jax.Array) -> jax.Array:
    h = x @ p["w_in"] + p["b_in"]
    h = constrain(h, ("batch", None, "tp"))
    h = jax.nn.gelu(h.astype(F32)).astype(x.dtype)
    return h @ p["w_out"] + p["b_out"]


def _moe_local(cfg: ModelConfig, router, w_in, w_out, x, n_local: int,
               e_offset) -> jax.Array:
    """Token-choice top-k dispatch/compute/combine over ``n_local`` experts
    whose global ids start at ``e_offset``.  Pure local math (runs on one
    device inside shard_map, or standalone when unsharded)."""
    mc = cfg.moe
    B, S, d = x.shape
    E, k = mc.num_experts, mc.top_k
    T = B * S
    C = max(int(T * k / E * mc.capacity_factor), 1)
    xt = x.reshape(T, d)

    logits = (xt.astype(F32) @ router.astype(F32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                      # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    e_flat = idx.reshape(-1)                                 # (T*k,)
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)
    pos_in_e = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1
    local_e = e_flat - e_offset
    in_local = (local_e >= 0) & (local_e < n_local)
    keep = in_local & (pos_in_e < C)
    slot = jnp.where(keep, pos_in_e, C)                      # C = overflow bin
    eidx = jnp.where(in_local, local_e, 0)

    xrep = jnp.repeat(xt, k, axis=0)                         # (T*k, d)
    disp = jnp.zeros((n_local, C + 1, d), x.dtype)
    disp = disp.at[eidx, slot].add(xrep * keep[:, None].astype(x.dtype))

    gu = jnp.einsum("ecd,edf->ecf", disp[:, :C], w_in)
    g, u = jnp.split(gu, 2, axis=-1)
    h = jax.nn.silu(g.astype(F32)).astype(x.dtype) * u
    eout = jnp.einsum("ecf,efd->ecd", h, w_out)

    eout = jnp.concatenate([eout, jnp.zeros((n_local, 1, d), x.dtype)], axis=1)
    back = eout[eidx, slot]                                  # (T*k, d)
    back = back * (keep * gate.reshape(-1)).astype(x.dtype)[:, None]
    y = back.reshape(T, k, d).sum(axis=1)
    return y.reshape(B, S, d)


def moe_layer(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    """Top-k token-choice MoE with static per-expert capacity (dropping).

    Expert parallelism is expressed with an explicit ``shard_map`` over the
    "model" axis: each device routes its (data-sharded) tokens to its E/ep
    local experts and the partial outputs are combined with one
    ``psum_scatter`` (sequence-sharded output, matching the seq_act residual
    boundary).  A GSPMD scatter formulation replicated the (E, C, d)
    dispatch buffers -- 7.6 TB/device for arctic train_4k in the dry-run.
    """
    from . import sharding as shd
    import jax as _jax
    from ..compat import shard_map_compat
    from jax.sharding import PartitionSpec as P

    mesh = shd.get_mesh()
    E = cfg.moe.num_experts
    if (mesh is None or "model" not in mesh.shape
            or E % mesh.shape["model"]):
        return _moe_local(cfg, p["router"], p["w_in"], p["w_out"], x, E, 0)

    ep = mesh.shape["model"]
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    B, S, d = x.shape
    scatter_ok = S % ep == 0

    def local_fn(xb, router, w_in, w_out):
        j = _jax.lax.axis_index("model")
        y = _moe_local(cfg, router, w_in, w_out, xb, E // ep, j * (E // ep))
        if scatter_ok:
            return _jax.lax.psum_scatter(y, "model", scatter_dimension=1,
                                         tiled=True)
        return _jax.lax.psum(y, "model")

    bspec = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)
    out_spec = P(bspec, "model" if scatter_ok else None, None)
    fn = shard_map_compat(
        local_fn, mesh=mesh,
        in_specs=(P(bspec, None, None), P(None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=out_spec,
        check=False,
    )
    return fn(x, p["router"], p["w_in"], p["w_out"])


def lm_logits(cfg: ModelConfig, params: dict, h: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"])
    else:
        logits = h @ params["lm_head"]
    return constrain(logits, ("batch", None, "vocab"))


def xent_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    lg = logits.astype(F32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    ll = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    return (lse - ll).mean()
