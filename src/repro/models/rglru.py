"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Real-gated linear recurrent unit:
    r_t = sigmoid(W_a h_t + b_a)          (recurrence gate, block-diagonal)
    i_t = sigmoid(W_i h_t + b_i)          (input gate, block-diagonal)
    log a_t = -c * softplus(Lambda) * r_t
    y_t = a_t * y_{t-1} + sqrt(1 - a_t^2) * (i_t * h_t)
computed with an associative scan over the sequence (train/prefill) or a
single state update (decode) -- O(1) state is why recurrentgemma runs the
long_500k shape.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .ssm import causal_conv
from .sharding import constrain

F32 = jnp.float32
_C = 8.0


def _blockdiag(h: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """h (B,S,lw), w (nb,bw,bw) block-diagonal matmul."""
    B, S, lw = h.shape
    nb, bw, _ = w.shape
    hb = h.reshape(B, S, nb, bw)
    out = jnp.einsum("bsnw,nwv->bsnv", hb, w)
    return out.reshape(B, S, lw) + b


_CHUNK = 256


def _chunked_linear_scan(a, inp, h0, chunk: int = _CHUNK):
    """y_t = a_t y_{t-1} + inp_t via lax.scan over chunks with an
    associative scan inside each (checkpointed) chunk.

    A single full-length associative scan keeps O(S log S) backward
    residuals alive -- 32 GB/device for recurrentgemma train_4k in the
    dry-run; chunking bounds the live set to one chunk's levels.
    Returns (y (B,S,lw), h_last (B,lw))."""
    B, S, lw = a.shape
    if S <= chunk:
        inp = inp.at[:, 0].add(a[:, 0] * h0)

        def comb(l, r_):
            return (l[0] * r_[0], r_[0] * l[1] + r_[1])
        _, y = jax.lax.associative_scan(comb, (a, inp), axis=1)
        return y, y[:, -1]
    pad = (-S) % chunk
    if pad:
        # a=1, inp=0 preserves the state through padded steps
        a = jnp.concatenate([a, jnp.ones((B, pad, lw), a.dtype)], axis=1)
        inp = jnp.concatenate([inp, jnp.zeros((B, pad, lw), inp.dtype)], axis=1)
    c = a.shape[1] // chunk
    ac = a.reshape(B, c, chunk, lw).transpose(1, 0, 2, 3)
    ic = inp.reshape(B, c, chunk, lw).transpose(1, 0, 2, 3)

    def body(h, xs):
        aq, iq = xs
        iq = iq.at[:, 0].add(aq[:, 0] * h)

        def comb(l, r_):
            return (l[0] * r_[0], r_[0] * l[1] + r_[1])
        _, y = jax.lax.associative_scan(comb, (aq, iq), axis=1)
        return y[:, -1], y

    h_last, ys = jax.lax.scan(jax.checkpoint(body), h0, (ac, ic))
    y = ys.transpose(1, 0, 2, 3).reshape(B, c * chunk, lw)[:, :S]
    return y, h_last    # padding preserves the state, so h_last == y[:, -1]


def rglru_block(cfg: ModelConfig, p: dict, x: jax.Array, *,
                cache: Optional[dict] = None, mode: str = "train"):
    """Returns (out (B,S,d), new_cache {h, conv} or None)."""
    B, S, d = x.shape
    gate = jax.nn.gelu((x @ p["w_gate"]).astype(F32)).astype(x.dtype)
    h = x @ p["w_x"]
    h = constrain(h, ("batch", None, "tp"))
    conv_cache = cache["conv"] if cache is not None else None
    h, conv_tail = causal_conv(h, p["conv_w"], p["conv_b"], conv_cache)

    r = jax.nn.sigmoid(_blockdiag(h, p["wa"], p["ba"]).astype(F32))
    i = jax.nn.sigmoid(_blockdiag(h, p["wi"], p["bi"]).astype(F32))
    log_a = -_C * jax.nn.softplus(p["a_param"].astype(F32)) * r  # (B,S,lw)
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    inp = mult * i * h.astype(F32)

    if mode == "decode":
        assert S == 1 and cache is not None
        y = a[:, 0] * cache["h"].astype(F32) + inp[:, 0]
        ynew = y[:, None]
        new_cache = {"h": y, "conv": conv_tail}
    else:
        h0 = (cache["h"].astype(F32) if cache is not None
              else jnp.zeros(a.shape[::2], F32))
        ynew, h_last = _chunked_linear_scan(a, inp, h0)
        new_cache = ({"h": h_last, "conv": conv_tail}
                     if mode == "prefill" else None)

    y = (ynew.astype(x.dtype) * gate) @ p["w_out"]
    return y, new_cache
