"""Logical-axis sharding: one rule table drives params + activations.

Every parameter/activation dimension carries a *logical* name; the rule
table maps logical names to mesh axes.  ``resolve`` silently drops a mesh
axis whose size does not divide the dimension (jit arguments must be
exactly divisible -- see DESIGN.md), which makes one scheme work across all
10 architectures (40-head models on a 16-way model axis fall back per-dim).

The context is process-global and set by the launch layer; with no mesh set
all helpers are no-ops, so model code runs unchanged on a single device.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

#: logical axis -> tuple of mesh axis names (in sharding order)
DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "seq": (),                  # sequence unsharded by default
    "seq_act": ("model",),      # Megatron-style sequence-parallel residual
    "cache_seq": ("model",),    # decode: shard KV/state over model
    "vocab": ("model",),
    "embed": ("data",),         # FSDP axis for parameters
    "tp": ("model",),           # tensor-parallel flat projection dim
    "heads": ("model",),
    "ff": ("model",),
    "expert": ("model",),
    "expert_cap": ("data",),
    None: (),
}


@dataclasses.dataclass
class ShardingCtx:
    mesh: Optional[Mesh] = None
    rules: dict = dataclasses.field(default_factory=lambda: dict(DEFAULT_RULES))


_CTX = ShardingCtx()


def set_mesh(mesh: Optional[Mesh], rules: Optional[dict] = None) -> None:
    _CTX.mesh = mesh
    _CTX.rules = dict(DEFAULT_RULES)
    if rules:
        _CTX.rules.update(rules)


def get_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def resolve(logical: Sequence, shape: Sequence[int]) -> P:
    """Logical names -> PartitionSpec with per-dim divisibility fallback."""
    mesh = _CTX.mesh
    if mesh is None:
        return P()
    axes = []
    used = set()
    for dim, name in zip(shape, logical):
        cand = [a for a in _CTX.rules.get(name, ()) if a in mesh.shape and a not in used]
        size = 1
        keep = []
        for a in cand:
            if dim % (size * mesh.shape[a]) == 0:
                keep.append(a)
                size *= mesh.shape[a]
        used.update(keep)
        axes.append(tuple(keep) if len(keep) > 1 else (keep[0] if keep else None))
    return P(*axes)


def sharding_for(logical: Sequence, shape: Sequence[int]) -> Optional[NamedSharding]:
    if _CTX.mesh is None:
        return None
    return NamedSharding(_CTX.mesh, resolve(logical, shape))


def replicated() -> Optional[NamedSharding]:
    if _CTX.mesh is None:
        return None
    return NamedSharding(_CTX.mesh, P())


def constrain(x: jax.Array, logical: Sequence) -> jax.Array:
    """with_sharding_constraint by logical names (no-op without a mesh)."""
    if _CTX.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX.mesh, resolve(logical, x.shape)))


def tree_shardings(logical_tree, shape_tree):
    """Map a pytree of logical tuples + shapes to NamedShardings."""
    return jax.tree.map(
        lambda lg, sh: sharding_for(lg, sh),
        logical_tree, shape_tree,
        is_leaf=lambda v: isinstance(v, tuple) and all(
            isinstance(e, (str, type(None))) for e in v),
    )
