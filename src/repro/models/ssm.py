"""Mamba-2 SSD (state-space duality) block -- arXiv:2405.21060.

Chunked matmul formulation (MXU-friendly): intra-chunk attention-like
einsums + inter-chunk state recurrence, matching the paper's minimal
listing.  Decode is a single recurrent state update (O(1) in context
length -- this is why mamba2 runs the long_500k shape).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .sharding import constrain

F32 = jnp.float32


def segsum(x: jax.Array) -> jax.Array:
    """(..., T) -> (..., T, T) with out[i,j] = sum_{k=j+1..i} x[k] (i>=j)."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool))
    return jnp.where(mask, d, -jnp.inf)


def causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                cache: Optional[jax.Array] = None):
    """Depthwise causal conv; x (B,S,C), w (K,C).  Returns (out, tail)."""
    K = w.shape[0]
    if cache is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([cache.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    out = sum(xp[:, k:k + S] * w[k] for k in range(K)) + b
    return out, xp[:, -(K - 1):]


def ssd_chunked(x, a, Bc, Cc, chunk: int, init_state=None):
    """x (B,S,H,P) [pre-scaled by dt], a=(dt*A) (B,S,H), Bc/Cc (B,S,G,N).

    Sequential lax.scan over chunks with a checkpointed body: one chunk's
    intra-chunk L matrix lives at a time (the all-chunks formulation
    materializes (B,H,c,q,q), which blew the HBM budget in the dry-run --
    see EXPERIMENTS.md).  Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    B, S, H, P = x.shape
    G, N = Bc.shape[2], Bc.shape[3]
    if S % chunk:
        # zero-pad to a chunk multiple: a=0 => decay 1, x=B=0 => state
        # untouched; padded outputs are sliced off below.
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0), (0, 0)))
    S_orig, S = S, x.shape[1]
    c = S // chunk
    hg = H // G
    # chunk-major layout for scan xs
    xc = x.reshape(B, c, chunk, H, P).transpose(1, 0, 2, 3, 4)
    ac = a.reshape(B, c, chunk, H).transpose(1, 0, 3, 2).astype(F32)  # (c,B,H,q)
    Bh = Bc.reshape(B, c, chunk, G, N).transpose(1, 0, 2, 3, 4)
    Ch = Cc.reshape(B, c, chunk, G, N).transpose(1, 0, 2, 3, 4)

    if init_state is None:
        init_state = jnp.zeros((B, H, P, N), F32)
    else:
        init_state = init_state.astype(F32)

    def chunk_body(state, xs):
        xq, aq, Bq, Cq = xs                     # (B,q,H,P),(B,H,q),(B,q,G,N)
        Bqh = jnp.repeat(Bq, hg, axis=2)        # (B,q,H,N)
        Cqh = jnp.repeat(Cq, hg, axis=2)
        a_cum = jnp.cumsum(aq, axis=-1)         # (B,H,q)
        L = jnp.exp(segsum(aq)).astype(xq.dtype)           # (B,H,q,q)
        y_diag = jnp.einsum("bqhn,bkhn,bhqk,bkhp->bqhp", Cqh, Bqh, L, xq)
        decay_states = jnp.exp(a_cum[..., -1:] - a_cum).astype(xq.dtype)
        contrib = jnp.einsum("bkhn,bhk,bkhp->bhpn", Bqh, decay_states, xq)
        state_decay = jnp.exp(a_cum).astype(xq.dtype)      # (B,H,q)
        y_off = jnp.einsum("bqhn,bhpn,bhq->bqhp", Cqh,
                           state.astype(xq.dtype), state_decay)
        chunk_decay = jnp.exp(a_cum[..., -1])              # (B,H)
        state2 = state * chunk_decay[..., None, None] + contrib.astype(F32)
        return state2, (y_diag + y_off)

    final_state, ys = jax.lax.scan(jax.checkpoint(chunk_body), init_state,
                                   (xc, ac, Bh, Ch))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)[:, :S_orig]
    return y, final_state


def _gated_rmsnorm(scale, y, z, eps=1e-6):
    g = y * jax.nn.silu(z.astype(F32)).astype(y.dtype)
    gf = g.astype(F32)
    var = jnp.mean(gf * gf, axis=-1, keepdims=True)
    return (gf * jax.lax.rsqrt(var + eps) * scale.astype(F32)).astype(y.dtype)


def mamba2_block(cfg: ModelConfig, p: dict, x: jax.Array, *,
                 cache: Optional[dict] = None, mode: str = "train"):
    """Returns (out (B,S,d), new_cache {state, conv} or None)."""
    s = cfg.ssm
    B, S, d = x.shape
    d_in = s.expand * d
    H = d_in // s.head_dim
    P = s.head_dim
    G, N = s.n_groups, s.d_state

    zxbcdt = x @ p["w_in"]
    zxbcdt = constrain(zxbcdt, ("batch", None, "tp"))
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in: 2 * d_in + 2 * G * N]
    dt_raw = zxbcdt[..., 2 * d_in + 2 * G * N:]
    conv_cache = cache["conv"] if cache is not None else None
    xbc, conv_tail = causal_conv(xbc, p["conv_w"], p["conv_b"], conv_cache)
    xbc = jax.nn.silu(xbc.astype(F32)).astype(x.dtype)
    xr = xbc[..., :d_in].reshape(B, S, H, P)
    Bc = xbc[..., d_in:d_in + G * N].reshape(B, S, G, N)
    Cc = xbc[..., d_in + G * N:].reshape(B, S, G, N)
    dt = jax.nn.softplus(dt_raw.astype(F32) + p["dt_bias"].astype(F32))
    A = -jnp.exp(p["A_log"].astype(F32))                     # (H,)

    if mode == "decode":
        assert S == 1 and cache is not None
        state = cache["state"].astype(F32)                   # (B,H,P,N)
        a = jnp.exp(dt[:, 0] * A)                            # (B,H)
        Bh = jnp.repeat(Bc[:, 0], H // G, axis=1).astype(F32)  # (B,H,N)
        Ch = jnp.repeat(Cc[:, 0], H // G, axis=1).astype(F32)
        xd = (xr[:, 0].astype(F32) * dt[:, 0][..., None])    # (B,H,P)
        state = state * a[..., None, None] + xd[..., None] * Bh[:, :, None, :]
        y = jnp.einsum("bhpn,bhn->bhp", state, Ch)
        y = y + xr[:, 0].astype(F32) * p["D"].astype(F32)[:, None]
        y = y[:, None].astype(x.dtype)                       # (B,1,H,P)
        new_cache = {"state": state, "conv": conv_tail}
    else:
        init = cache["state"] if cache is not None else None
        y, final_state = ssd_chunked(
            xr * dt.astype(x.dtype)[..., None], dt * A, Bc, Cc, s.chunk,
            init_state=init)
        y = y + xr * p["D"].astype(x.dtype)[:, None]
        new_cache = ({"state": final_state.astype(F32), "conv": conv_tail}
                     if mode == "prefill" else None)

    y = y.reshape(B, S, d_in)
    y = _gated_rmsnorm(p["gnorm"], y, z)
    return y @ p["w_out"], new_cache
