"""Parameter schemas: one source of truth for shapes, logical sharding axes
and initialization of every architecture family.

A schema is a pytree of :class:`Spec`; from it we derive
  - ``init_params``      (PRNG materialization, used by smoke tests/examples)
  - ``abstract_params``  (ShapeDtypeStructs, used by the multi-pod dry-run)
  - ``param_shardings``  (NamedShardings via the logical rule table)
Per-layer blocks are stacked along a leading "layers" axis and consumed with
``lax.scan`` so HLO size stays O(1) in depth.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import sharding as shd


@dataclasses.dataclass(frozen=True)
class Spec:
    shape: Tuple[int, ...]
    logical: Tuple
    init: str = "normal"          # normal | zeros | ones
    scale: Optional[float] = None  # stddev override


def _is_spec(x):
    return isinstance(x, Spec)


# --------------------------------------------------------------------------
# component schemas
# --------------------------------------------------------------------------

def norm_schema(d: int) -> dict:
    return {"scale": Spec((d,), (None,), "ones")}


def attn_schema(cfg: ModelConfig, cross: bool = False) -> dict:
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd
    s = {
        "wq": Spec((d, H * hd), ("embed", "tp")),
        "wk": Spec((d, K * hd), ("embed", "tp")),
        "wv": Spec((d, K * hd), ("embed", "tp")),
        "wo": Spec((H * hd, d), ("tp", "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = Spec((H * hd,), ("tp",), "zeros")
        s["bk"] = Spec((K * hd,), ("tp",), "zeros")
        s["bv"] = Spec((K * hd,), ("tp",), "zeros")
    if cfg.qk_norm:
        s["q_norm"] = Spec((hd,), (None,), "ones")
        s["k_norm"] = Spec((hd,), (None,), "ones")
    return s


def mlp_schema(cfg: ModelConfig, d_ff: Optional[int] = None,
               gated: bool = True) -> dict:
    d = cfg.d_model
    ff = d_ff if d_ff is not None else cfg.d_ff
    if gated:   # SwiGLU, gate+up fused
        return {"w_in": Spec((d, 2 * ff), ("embed", "tp")),
                "w_out": Spec((ff, d), ("ff", "embed"))}
    return {"w_in": Spec((d, ff), ("embed", "tp")),
            "b_in": Spec((ff,), ("tp",), "zeros"),
            "w_out": Spec((ff, d), ("ff", "embed")),
            "b_out": Spec((d,), (None,), "zeros")}


def moe_schema(cfg: ModelConfig) -> dict:
    mc = cfg.moe
    d, E, fe = cfg.d_model, mc.num_experts, mc.d_ff_expert
    return {
        "router": Spec((d, E), ("embed", None)),
        "w_in": Spec((E, d, 2 * fe), ("expert", "embed", None)),
        "w_out": Spec((E, fe, d), ("expert", None, "embed")),
    }


def mamba2_schema(cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    nheads = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    proj = 2 * d_in + 2 * s.n_groups * s.d_state + nheads
    return {
        "w_in": Spec((d, proj), ("embed", None)),
        "conv_w": Spec((s.d_conv, conv_dim), (None, None)),
        "conv_b": Spec((conv_dim,), (None,), "zeros"),
        "A_log": Spec((nheads,), (None,), "zeros"),
        "D": Spec((nheads,), (None,), "ones"),
        "dt_bias": Spec((nheads,), (None,), "zeros"),
        "gnorm": Spec((d_in,), (None,), "ones"),
        "w_out": Spec((d_in, d), ("tp", "embed")),
    }


def rglru_schema(cfg: ModelConfig) -> dict:
    h = cfg.hybrid
    d = cfg.d_model
    lw = h.lru_width or d
    nb = cfg.n_heads                       # block-diagonal gate heads
    bw = lw // nb
    return {
        "w_x": Spec((d, lw), ("embed", "tp")),        # recurrent branch in
        "w_gate": Spec((d, lw), ("embed", "tp")),     # gelu gate branch in
        "conv_w": Spec((h.conv_width, lw), (None, "tp")),
        "conv_b": Spec((lw,), ("tp",), "zeros"),
        "wa": Spec((nb, bw, bw), (None, None, None)),  # recurrence gate
        "wi": Spec((nb, bw, bw), (None, None, None)),  # input gate
        "ba": Spec((lw,), ("tp",), "zeros"),
        "bi": Spec((lw,), ("tp",), "zeros"),
        "a_param": Spec((lw,), ("tp",), "ones"),       # Lambda
        "w_out": Spec((lw, d), ("tp", "embed")),
    }


# --------------------------------------------------------------------------
# block and model schemas
# --------------------------------------------------------------------------

def block_schema(cfg: ModelConfig, kind: str) -> dict:
    d = cfg.d_model
    if kind == "attn":
        blk = {"ln1": norm_schema(d), "attn": attn_schema(cfg),
               "ln2": norm_schema(d)}
        if cfg.moe:
            blk["moe"] = moe_schema(cfg)
            if cfg.moe.dense_residual:
                blk["mlp"] = mlp_schema(cfg, d_ff=cfg.moe.d_ff_dense)
        else:
            blk["mlp"] = mlp_schema(cfg)
        return blk
    if kind == "ssm":
        return {"ln": norm_schema(d), "mamba": mamba2_schema(cfg)}
    if kind == "rglru":
        return {"ln1": norm_schema(d), "rglru": rglru_schema(cfg),
                "ln2": norm_schema(d), "mlp": mlp_schema(cfg)}
    if kind == "enc":
        return {"ln1": norm_schema(d), "attn": attn_schema(cfg),
                "ln2": norm_schema(d), "mlp": mlp_schema(cfg, gated=False)}
    if kind == "dec":
        return {"ln1": norm_schema(d), "self_attn": attn_schema(cfg),
                "ln2": norm_schema(d), "cross_attn": attn_schema(cfg, cross=True),
                "ln3": norm_schema(d), "mlp": mlp_schema(cfg, gated=False)}
    raise ValueError(kind)


def _stack(spec_tree: dict, n: int) -> dict:
    return jax.tree.map(
        lambda s: Spec((n,) + s.shape, (None,) + tuple(s.logical), s.init, s.scale),
        spec_tree, is_leaf=_is_spec)


def model_schema(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    top: dict = {
        "embed": Spec((cfg.vocab, d), ("vocab", "embed"), scale=0.02),
        "final_norm": norm_schema(d),
    }
    if not cfg.tie_embeddings:
        top["lm_head"] = Spec((d, cfg.vocab), ("embed", "vocab"))
    if cfg.family in ("dense", "moe", "vlm"):
        top["layers"] = _stack(block_schema(cfg, "attn"), cfg.n_layers)
    elif cfg.family == "ssm":
        top["layers"] = _stack(block_schema(cfg, "ssm"), cfg.n_layers)
    elif cfg.family == "hybrid":
        pat = cfg.hybrid.pattern
        n_groups, rem = divmod(cfg.n_layers, len(pat))
        group = {f"{i}_{k}": block_schema(cfg, k) for i, k in enumerate(pat)}
        top["groups"] = _stack(group, n_groups)
        for j in range(rem):
            top[f"extra_{j}"] = block_schema(cfg, pat[j])
    elif cfg.family == "encdec":
        top["enc_layers"] = _stack(block_schema(cfg, "enc"), cfg.n_enc_layers)
        top["dec_layers"] = _stack(block_schema(cfg, "dec"), cfg.n_layers)
        top["enc_final_norm"] = norm_schema(d)
    else:
        raise ValueError(cfg.family)
    return top


# --------------------------------------------------------------------------
# materialization
# --------------------------------------------------------------------------

def abstract_params(cfg: ModelConfig) -> dict:
    dt = jnp.dtype(cfg.param_dtype)
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, dt),
                        model_schema(cfg), is_leaf=_is_spec)


def param_shardings(cfg: ModelConfig) -> dict:
    return jax.tree.map(lambda s: shd.sharding_for(s.logical, s.shape),
                        model_schema(cfg), is_leaf=_is_spec)


def layer_schema(cfg: ModelConfig, key: str = "layers") -> dict:
    """Per-layer Spec tree (leading stack dim dropped) -- used to re-apply
    FSDP sharding constraints to scanned parameter slices."""
    sch = model_schema(cfg)[key]
    return jax.tree.map(
        lambda s: Spec(s.shape[1:], tuple(s.logical[1:]), s.init, s.scale),
        sch, is_leaf=_is_spec)


def constrain_layer_params(cfg: ModelConfig, p: dict, key: str = "layers") -> dict:
    """Keep scanned per-layer weight slices FSDP-sharded inside the loop so
    XLA cannot hoist a full-parameter all-gather out of the layer scan."""
    from . import sharding as shd
    if shd.get_mesh() is None:
        return p
    sch = layer_schema(cfg, key)
    return jax.tree.map(lambda a, s: shd.constrain(a, s.logical), p, sch,
                        is_leaf=lambda v: _is_spec(v))


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    schema = model_schema(cfg)
    leaves, treedef = jax.tree.flatten(schema, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    dt = jnp.dtype(cfg.param_dtype)

    def make(s: Spec, k):
        if s.init == "zeros":
            return jnp.zeros(s.shape, dt)
        if s.init == "ones":
            return jnp.ones(s.shape, dt)
        fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
        scale = s.scale if s.scale is not None else fan_in ** -0.5
        return (jax.random.normal(k, s.shape, jnp.float32) * scale).astype(dt)

    return jax.tree.unflatten(treedef, [make(s, k) for s, k in zip(leaves, keys)])
