"""Model assembly for every architecture family.

Public API (all pure functions over the schema param pytrees):
  loss_fn(cfg, params, batch)                 -- training loss (train_step core)
  prefill(cfg, params, batch)                 -- build KV/state caches
  decode_step(cfg, params, token, caches, pos)-- one serving token
  init_caches / abstract_caches / cache_shardings
Layers are consumed with lax.scan over stacked parameters; the per-layer
body is optionally wrapped in jax.checkpoint (remat) for training.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import schema
from . import sharding as shd
from .layers import (attention, lm_logits, mlp_gelu, mlp_swiglu, moe_layer,
                     rmsnorm, xent_loss)
from .rglru import rglru_block
from .ssm import mamba2_block

F32 = jnp.float32


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------

def attn_block(cfg, p, x, *, pos, mode, cache, window=0, block_skip=False):
    h, c2 = attention(cfg, p["attn"], rmsnorm(p["ln1"], x, cfg.norm_eps),
                      pos=pos, mode=mode, cache=cache, window=window,
                      block_skip=block_skip)
    x = x + h
    y = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if "moe" in p:
        f = moe_layer(cfg, p["moe"], y)
        if "mlp" in p:                      # arctic: parallel dense residual
            f = f + mlp_swiglu(p["mlp"], y)
    else:
        f = mlp_swiglu(p["mlp"], y)
    return x + f, c2


def ssm_block(cfg, p, x, *, mode, cache):
    h, c2 = mamba2_block(cfg, p["mamba"], rmsnorm(p["ln"], x, cfg.norm_eps),
                         cache=cache, mode=mode)
    return x + h, c2


def rglru_layer_block(cfg, p, x, *, mode, cache):
    h, c2 = rglru_block(cfg, p["rglru"], rmsnorm(p["ln1"], x, cfg.norm_eps),
                        cache=cache, mode=mode)
    x = x + h
    x = x + mlp_swiglu(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps))
    return x, c2


def enc_block(cfg, p, x):
    h, _ = attention(cfg, p["attn"], rmsnorm(p["ln1"], x, cfg.norm_eps),
                     pos=jnp.zeros(x.shape[:2], jnp.int32), mode="train",
                     causal=False)
    x = x + h
    return x + mlp_gelu(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps))


def dec_block(cfg, p, x, *, pos, mode, cache, enc_out=None):
    c_self = cache["self"] if cache is not None else None
    # the cross cache holds *precomputed* encoder K/V: consume it only at
    # decode; at prefill it is a zero placeholder and K/V come from enc_out
    c_cross = cache["cross"] if (cache is not None and mode == "decode") else None
    h, c_self2 = attention(cfg, p["self_attn"],
                           rmsnorm(p["ln1"], x, cfg.norm_eps),
                           pos=pos, mode=mode, cache=c_self)
    x = x + h
    h, c_cross2 = attention(cfg, p["cross_attn"],
                            rmsnorm(p["ln2"], x, cfg.norm_eps),
                            pos=pos, mode="cross", cache=c_cross,
                            kv_states=enc_out)
    x = x + h
    x = x + mlp_gelu(p["mlp"], rmsnorm(p["ln3"], x, cfg.norm_eps))
    new_cache = ({"self": c_self2, "cross": c_cross2}
                 if (mode in ("prefill", "decode")) else None)
    return x, new_cache


def _maybe_remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "full":
        return jax.checkpoint(fn)
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    raise ValueError(policy)


# --------------------------------------------------------------------------
# embeddings / positions
# --------------------------------------------------------------------------

def _embed(cfg, params, batch):
    if cfg.embeds_input and "embeds" in batch:
        x = batch["embeds"].astype(jnp.dtype(cfg.compute_dtype))
    else:
        x = params["embed"][batch["tokens"]].astype(jnp.dtype(cfg.compute_dtype))
    return shd.constrain(x, ("batch", "seq", None))


def _positions(cfg, batch, B, S, offset=0):
    if "positions" in batch:
        return batch["positions"]
    pos = jnp.arange(S, dtype=jnp.int32)[None, :] + offset
    pos = jnp.broadcast_to(pos, (B, S))
    if cfg.rope_style == "mrope":
        pos = jnp.broadcast_to(pos[None], (3, B, S))
    return pos


def _sinusoid(S: int, d: int, offset=0) -> jax.Array:
    pos = jnp.arange(S, dtype=F32)[:, None] + offset
    div = jnp.exp(jnp.arange(0, d, 2, dtype=F32) * (-jnp.log(10000.0) / d))
    pe = jnp.zeros((S, d), F32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div[: (d + 1) // 2]))
    return pe


# --------------------------------------------------------------------------
# backbone traversal (shared by train / prefill / decode)
# --------------------------------------------------------------------------

def _run_layers(cfg, params, x, *, pos, mode, caches, remat="none",
                block_skip=False):
    """Returns (hidden, new_caches)."""
    serve = mode in ("prefill", "decode")
    lc = caches["layers"] if (caches is not None and "layers" in caches) else None
    boundary = ("batch", "seq_act", None)     # sequence-parallel residual
    if cfg.family in ("dense", "moe", "vlm"):
        def body(h, xs):
            p, c = xs
            p = schema.constrain_layer_params(cfg, p)
            h2, c2 = attn_block(cfg, p, h, pos=pos, mode=mode, cache=c,
                                block_skip=block_skip)
            return shd.constrain(h2, boundary), c2
        body = _maybe_remat(body, remat)
        x, cs = jax.lax.scan(body, x, (params["layers"], lc))
        return x, ({"layers": cs} if serve else None)

    if cfg.family == "ssm":
        def body(h, xs):
            p, c = xs
            p = schema.constrain_layer_params(cfg, p)
            h2, c2 = ssm_block(cfg, p, h, mode=mode, cache=c)
            return shd.constrain(h2, boundary), c2
        body = _maybe_remat(body, remat)
        x, cs = jax.lax.scan(body, x, (params["layers"], lc))
        return x, ({"layers": cs} if serve else None)

    if cfg.family == "hybrid":
        pat = cfg.hybrid.pattern
        win = cfg.hybrid.window

        def body(h, xs):
            p, c = xs
            p = schema.constrain_layer_params(cfg, p, key="groups")
            outc = {}
            for i, kind in enumerate(pat):
                key = f"{i}_{kind}"
                if kind == "rglru":
                    h, c2 = rglru_layer_block(cfg, p[key], h, mode=mode,
                                              cache=None if c is None else c[key])
                else:
                    h, c2 = attn_block(cfg, p[key], h, pos=pos, mode=mode,
                                       cache=None if c is None else c[key],
                                       window=win, block_skip=block_skip)
                outc[key] = c2
            return h, outc
        body = _maybe_remat(body, remat)
        gcaches = caches["groups"] if caches is not None else None
        x, cs = jax.lax.scan(body, x, (params["groups"], gcaches))
        out = {"groups": cs} if serve else None
        n_groups = cfg.n_layers // len(pat)
        for j in range(cfg.n_layers - n_groups * len(pat)):
            kind = pat[j]
            key = f"extra_{j}"
            c = caches[key] if caches is not None else None
            if kind == "rglru":
                x, c2 = rglru_layer_block(cfg, params[key], x, mode=mode, cache=c)
            else:
                x, c2 = attn_block(cfg, params[key], x, pos=pos, mode=mode,
                                   cache=c, window=win, block_skip=block_skip)
            if serve:
                out[key] = c2
        return x, out

    raise ValueError(cfg.family)


def _run_decoder_encdec(cfg, params, x, *, pos, mode, caches, enc_out,
                        remat="none"):
    lc = caches["layers"] if (caches is not None and "layers" in caches) else None

    def body(h, xs):
        p, c = xs
        p = schema.constrain_layer_params(cfg, p, key="dec_layers")
        h2, c2 = dec_block(cfg, p, h, pos=pos, mode=mode, cache=c,
                           enc_out=enc_out)
        return shd.constrain(h2, ("batch", "seq_act", None)), c2
    body = _maybe_remat(body, remat)
    x, cs = jax.lax.scan(body, x, (params["dec_layers"], lc))
    return x, ({"layers": cs} if mode in ("prefill", "decode") else None)


def _run_encoder(cfg, params, frames, remat="none"):
    dt = jnp.dtype(cfg.compute_dtype)
    x = frames.astype(dt) + _sinusoid(frames.shape[1], cfg.d_model).astype(dt)
    x = shd.constrain(x, ("batch", "seq", None))

    def body(h, p):
        p = schema.constrain_layer_params(cfg, p, key="enc_layers")
        return shd.constrain(enc_block(cfg, p, h),
                             ("batch", "seq_act", None)), None
    body = _maybe_remat(body, remat)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rmsnorm(params["enc_final_norm"], x, cfg.norm_eps)


# --------------------------------------------------------------------------
# public entry points
# --------------------------------------------------------------------------

def forward(cfg: ModelConfig, params, batch, *, mode="train", caches=None,
            pos_offset=0, remat="none", block_skip=False):
    """Returns (logits, new_caches)."""
    dt = jnp.dtype(cfg.compute_dtype)
    if cfg.family == "encdec":
        enc_out = _run_encoder(cfg, params, batch["frames"], remat=remat)
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = params["embed"][tokens].astype(dt)
        x = x + _sinusoid(S, cfg.d_model, offset=pos_offset).astype(dt)
        pos = _positions(cfg, batch, B, S, offset=pos_offset)
        x, cs = _run_decoder_encdec(cfg, params, x, pos=pos, mode=mode,
                                    caches=caches, enc_out=enc_out, remat=remat)
    else:
        x = _embed(cfg, params, batch)
        B, S = x.shape[0], x.shape[1]
        pos = _positions(cfg, batch, B, S, offset=pos_offset)
        x, cs = _run_layers(cfg, params, x, pos=pos, mode=mode, caches=caches,
                            remat=remat, block_skip=block_skip)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return lm_logits(cfg, params, x), cs


def loss_fn(cfg: ModelConfig, params, batch, *, remat="none",
            block_skip=False) -> jax.Array:
    logits, _ = forward(cfg, params, batch, mode="train", remat=remat,
                        block_skip=block_skip)
    labels = batch.get("labels", batch.get("tokens"))
    return xent_loss(logits[:, :-1], labels[:, 1:])


def prefill(cfg: ModelConfig, params, batch, *, remat="none",
            max_len: Optional[int] = None):
    B = _batch_dim(cfg, batch)
    if cfg.family == "encdec":
        S = batch["tokens"].shape[1]
        enc_len = batch["frames"].shape[1]
    else:
        S = _seq_dim(cfg, batch)
        enc_len = 0
    caches = init_caches(cfg, B, max_len or S, enc_len)
    return forward(cfg, params, batch, mode="prefill", caches=caches,
                   remat=remat)


def decode_step(cfg: ModelConfig, params, token_batch, caches, pos: jax.Array):
    """token_batch: {"tokens": (B,1)} (+ embeds for stub frontends);
    pos: scalar int32 absolute position.  Returns (logits, caches)."""
    return forward(cfg, params, token_batch, mode="decode", caches=caches,
                   pos_offset=pos)


def _batch_dim(cfg, batch):
    key = "frames" if cfg.family == "encdec" else (
        "embeds" if cfg.embeds_input else "tokens")
    return batch[key].shape[0]


def _seq_dim(cfg, batch):
    key = "frames" if cfg.family == "encdec" else (
        "embeds" if cfg.embeds_input else "tokens")
    return batch[key].shape[1]


# --------------------------------------------------------------------------
# caches: concrete init, abstract specs and shardings
# --------------------------------------------------------------------------

def _full_cache_spec(cfg, B, T):
    K, hd = cfg.n_kv, cfg.hd
    dt = jnp.dtype(cfg.compute_dtype)
    return {"k": ((B, T, K, hd), dt, ("batch", "cache_seq", None, None)),
            "v": ((B, T, K, hd), dt, ("batch", "cache_seq", None, None)),
            "len": ((), jnp.int32, ())}


def _local_cache_spec(cfg, B, W):
    K, hd = cfg.n_kv, cfg.hd
    dt = jnp.dtype(cfg.compute_dtype)
    return {"k": ((B, W, K, hd), dt, ("batch", "cache_seq", None, None)),
            "v": ((B, W, K, hd), dt, ("batch", "cache_seq", None, None)),
            "pos": ((W,), jnp.int32, (None,)),
            "len": ((), jnp.int32, ())}


def _ssm_cache_spec(cfg, B):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    dt = jnp.dtype(cfg.compute_dtype)
    return {"state": ((B, H, s.head_dim, s.d_state), F32,
                      ("batch", None, None, None)),
            "conv": ((B, s.d_conv - 1, conv_dim), dt, ("batch", None, "tp"))}


def _rglru_cache_spec(cfg, B):
    h = cfg.hybrid
    lw = h.lru_width or cfg.d_model
    dt = jnp.dtype(cfg.compute_dtype)
    return {"h": ((B, lw), F32, ("batch", "tp")),
            "conv": ((B, h.conv_width - 1, lw), dt, ("batch", None, "tp"))}


def _stack_spec(spec, n):
    return jax.tree.map(
        lambda t: ((n,) + t[0], t[1], (None,) + tuple(t[2])),
        spec, is_leaf=lambda v: isinstance(v, tuple) and len(v) == 3
        and isinstance(v[0], tuple))


def cache_spec(cfg: ModelConfig, B: int, max_len: int, enc_len: int = 0):
    """Pytree of (shape, dtype, logical) describing the serving cache."""
    if cfg.family in ("dense", "moe", "vlm"):
        return {"layers": _stack_spec(_full_cache_spec(cfg, B, max_len),
                                      cfg.n_layers)}
    if cfg.family == "ssm":
        return {"layers": _stack_spec(_ssm_cache_spec(cfg, B), cfg.n_layers)}
    if cfg.family == "hybrid":
        pat = cfg.hybrid.pattern
        W = min(cfg.hybrid.window, max_len)
        group = {}
        for i, kind in enumerate(pat):
            group[f"{i}_{kind}"] = (_rglru_cache_spec(cfg, B) if kind == "rglru"
                                    else _local_cache_spec(cfg, B, W))
        n_groups, rem = divmod(cfg.n_layers, len(pat))
        out = {"groups": _stack_spec(group, n_groups)}
        for j in range(rem):
            out[f"extra_{j}"] = (_rglru_cache_spec(cfg, B)
                                 if pat[j] == "rglru"
                                 else _local_cache_spec(cfg, B, W))
        return out
    if cfg.family == "encdec":
        K, hd = cfg.n_kv, cfg.hd
        dt = jnp.dtype(cfg.compute_dtype)
        per = {"self": _full_cache_spec(cfg, B, max_len),
               "cross": {"k": ((B, enc_len, K, hd), dt,
                               ("batch", "cache_seq", None, None)),
                         "v": ((B, enc_len, K, hd), dt,
                               ("batch", "cache_seq", None, None))}}
        return {"layers": _stack_spec(per, cfg.n_layers)}
    raise ValueError(cfg.family)


def _is_spec3(v):
    return (isinstance(v, tuple) and len(v) == 3 and isinstance(v[0], tuple))


def init_caches(cfg, B, max_len, enc_len: int = 0, like=None):
    spec = cache_spec(cfg, B, max_len, enc_len)

    def mk(t):
        shape, dt, _ = t
        if dt == jnp.int32:
            init = jnp.zeros(shape, dt) - (1 if len(shape) else 0)
            return init if len(shape) else jnp.int32(0)
        return jnp.zeros(shape, dt)

    caches = jax.tree.map(mk, spec, is_leaf=_is_spec3)
    # scan consumes {"layers"/"groups"} stacked; prefill rebuilds caches from
    # scratch, so cross caches start empty (filled by mode="cross").
    return caches


def abstract_caches(cfg, B, max_len, enc_len: int = 0):
    spec = cache_spec(cfg, B, max_len, enc_len)
    return jax.tree.map(lambda t: jax.ShapeDtypeStruct(t[0], t[1]), spec,
                        is_leaf=_is_spec3)


def cache_shardings(cfg, B, max_len, enc_len: int = 0):
    spec = cache_spec(cfg, B, max_len, enc_len)
    return jax.tree.map(lambda t: shd.sharding_for(t[2], t[0]), spec,
                        is_leaf=_is_spec3)
