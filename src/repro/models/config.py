"""Model configuration schema for the architecture zoo.

One :class:`ModelConfig` describes any of the 10 assigned architectures
(dense / MoE / SSM / hybrid / enc-dec / VLM-backbone / audio-backbone).
Reduced smoke-test variants are produced with :func:`reduced`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    dense_residual: bool = False       # arctic-style parallel dense MLP
    d_ff_dense: int = 0
    capacity_factor: float = 1.0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64                 # mamba2 P (headdim)
    chunk: int = 128                   # SSD chunk length
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    window: int = 2048                 # local attention window
    pattern: Tuple[str, ...] = ("rglru", "rglru", "attn")   # Griffin 1:2
    lru_width: Optional[int] = None    # defaults to d_model
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                        # dense|moe|ssm|hybrid|encdec|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None     # default d_model // n_heads
    # --- attention details -------------------------------------------------
    rope_style: str = "full"           # full | partial | mrope | none
    rope_theta: float = 1e6
    rotary_pct: float = 1.0            # chatglm: 0.5 ("RoPE 2d")
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    qk_norm: bool = False              # qwen3
    qkv_bias: bool = False             # qwen1.5 / qwen2-vl / chatglm
    causal: bool = True
    # --- family-specific ----------------------------------------------------
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    n_enc_layers: int = 0              # whisper encoder depth
    # --- embeddings / io -----------------------------------------------------
    tie_embeddings: bool = False
    embeds_input: bool = False         # vlm/audio stub frontend: embeddings in
    norm_eps: float = 1e-6
    # --- numerics ------------------------------------------------------------
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # --- long-context capability (drives long_500k applicability) -----------
    subquadratic: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    def num_params(self) -> int:
        """Analytic parameter count (embeddings + per-layer weights)."""
        d, hd = self.d_model, self.hd
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "moe", "vlm"):
            attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv * hd) + (self.n_heads * hd) * d
            per_layer = attn
            if self.moe:
                router = d * self.moe.num_experts
                experts = self.moe.num_experts * 3 * d * self.moe.d_ff_expert
                per_layer += router + experts
                if self.moe.dense_residual:
                    per_layer += 3 * d * self.moe.d_ff_dense
            else:
                per_layer += 3 * d * self.d_ff
        elif self.family == "ssm":
            s = self.ssm
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            per_layer = d * (2 * d_in + 2 * s.n_groups * s.d_state + nheads) + d_in * d
        elif self.family == "hybrid":
            h = self.hybrid
            lw = h.lru_width or d
            attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv * hd) + (self.n_heads * hd) * d
            rglru = 2 * d * lw + lw * d + 2 * lw * lw // 8   # approx gates
            n_attn = sum(1 for i in range(self.n_layers)
                         if h.pattern[i % len(h.pattern)] == "attn")
            per_layer = 0
            total = (n_attn * (attn + 3 * d * self.d_ff)
                     + (self.n_layers - n_attn) * (rglru + 3 * d * self.d_ff))
            return emb + total
        elif self.family == "encdec":
            attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv * hd) + (self.n_heads * hd) * d
            enc = self.n_enc_layers * (attn + 2 * d * self.d_ff)
            dec = self.n_layers * (2 * attn + 2 * d * self.d_ff)
            return emb + enc + dec
        return emb + self.n_layers * per_layer

    def num_active_params(self) -> int:
        """Active parameters per token (MoE: top-k experts only)."""
        if not self.moe:
            return self.num_params()
        d = self.d_model
        total = self.num_params()
        experts_all = self.n_layers * self.moe.num_experts * 3 * d * self.moe.d_ff_expert
        experts_act = self.n_layers * self.moe.top_k * 3 * d * self.moe.d_ff_expert
        return total - experts_all + experts_act


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Smoke-test-sized variant of the same family (tiny dims, same wiring)."""
    small: dict = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.family != "hybrid" else 5),
        d_model=128,
        n_heads=4,
        n_kv=min(cfg.n_kv, 2) if cfg.n_kv < cfg.n_heads else 4,
        head_dim=32,
        d_ff=256,
        vocab=512,
        param_dtype="float32",
        compute_dtype="float32",
    )
    if cfg.moe:
        # capacity high enough that no token drops: keeps the smoke tests'
        # train/prefill/decode consistency exact (production uses 1.0)
        small["moe"] = MoEConfig(
            num_experts=8, top_k=min(cfg.moe.top_k, 2), d_ff_expert=64,
            dense_residual=cfg.moe.dense_residual, d_ff_dense=128,
            capacity_factor=16.0)
    if cfg.ssm:
        small["ssm"] = SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16,
                                 chunk=16, n_groups=1)
    if cfg.hybrid:
        small["hybrid"] = HybridConfig(window=32, pattern=cfg.hybrid.pattern,
                                       lru_width=128, conv_width=4)
    if cfg.family == "encdec":
        small["n_enc_layers"] = 2
        small["n_layers"] = 2
    if cfg.rope_style == "mrope":
        # sections must sum to head_dim//2 (pairs)
        small["mrope_sections"] = (4, 6, 6)
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
