"""Flash attention in pure JAX with a custom VJP.

Forward: online-softmax streaming over (q-block, kv-block) tiles; saves only
(out, lse) besides the inputs.  Backward: second tiled sweep recomputing the
block probabilities -- O(qb*kb) live memory, no stacked scan residuals
(a plain lax.scan backward would stack its carries, reproducing the full
S x T score tensor; that is why this needs a hand-written VJP).

This is the TPU-shaped algorithm (MXU-aligned tiles, f32 accumulators); on
real hardware the same tiling maps 1:1 onto a Pallas kernel.  GQA layout:
q (B,S,K,G,hd), k/v (B,T,K,hd).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

F32 = jnp.float32
NEG_INF = float("-inf")

#: default tile sizes -- perf knobs swept in EXPERIMENTS.md SPerf
_BLOCKS = {"qb": 512, "kb": 1024}


def set_blocks(qb: int, kb: int) -> None:
    _BLOCKS["qb"], _BLOCKS["kb"] = qb, kb


def get_blocks() -> tuple:
    return _BLOCKS["qb"], _BLOCKS["kb"]


def _mask(qpos, kpos, causal, window):
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m = m & (kpos[None, :] <= qpos[:, None])
    if window:
        m = m & (kpos[None, :] > qpos[:, None] - window)
    return m


def _fwd_impl(q, k, v, causal, window, qb, kb):
    B, S, K, G, hd = q.shape
    T = k.shape[1]
    qb = min(qb, S)
    kb = min(kb, T)
    nq, nk = S // qb, T // kb
    scale = hd ** -0.5
    qs = q.reshape(B, nq, qb, K, G, hd)
    ks = k.reshape(B, nk, kb, K, hd)
    vs = v.reshape(B, nk, kb, K, hd)

    def q_step(_, qi):
        qblk = qs[:, qi].astype(F32) * scale
        qpos = qi * qb + jnp.arange(qb)
        m0 = jnp.full((B, K, G, qb), NEG_INF, F32)
        l0 = jnp.zeros((B, K, G, qb), F32)
        a0 = jnp.zeros((B, qb, K, G, hd), F32)

        def kv_step(carry, kj):
            m, l, acc = carry
            kblk = ks[:, kj].astype(F32)
            vblk = vs[:, kj].astype(F32)
            kpos = kj * kb + jnp.arange(kb)
            s = jnp.einsum("bskgh,btkh->bkgst", qblk, kblk)
            s = jnp.where(_mask(qpos, kpos, causal, window), s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            safe = jnp.logical_not(jnp.isinf(m_new))
            corr = jnp.where(safe, jnp.exp(m - m_new), 0.0)
            p = jnp.where(safe[..., None], jnp.exp(s - m_new[..., None]), 0.0)
            l_new = l * corr + p.sum(axis=-1)
            a_new = (acc * corr.transpose(0, 3, 1, 2)[..., None]
                     + jnp.einsum("bkgst,btkh->bskgh", p, vblk))
            return (m_new, l_new, a_new), None

        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        lt = l.transpose(0, 3, 1, 2)[..., None]
        out = acc / jnp.where(lt == 0, 1.0, lt)
        lse = jnp.where(l > 0, m + jnp.log(jnp.where(l > 0, l, 1.0)), NEG_INF)
        return None, (out.astype(q.dtype), lse)          # lse: (B,K,G,qb)

    _, (outs, lses) = jax.lax.scan(q_step, None, jnp.arange(nq))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, K, G, hd)
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(B, K, G, S)
    return out, lse


def _bwd_impl(res, dout, causal, window, qb, kb):
    q, k, v, out, lse = res
    B, S, K, G, hd = q.shape
    T = k.shape[1]
    qb = min(qb, S)
    kb = min(kb, T)
    nq, nk = S // qb, T // kb
    scale = hd ** -0.5
    qs = q.reshape(B, nq, qb, K, G, hd)
    ks = k.reshape(B, nk, kb, K, hd)
    vs = v.reshape(B, nk, kb, K, hd)
    dos = dout.reshape(B, nq, qb, K, G, hd)
    lses = lse.reshape(B, K, G, nq, qb)
    # D_i = rowsum(dout * out)  (B,S,K,G) -> blocked (B,K,G,nq,qb)
    delta = (dout.astype(F32) * out.astype(F32)).sum(-1)
    deltas = delta.transpose(0, 2, 3, 1).reshape(B, K, G, nq, qb)

    def q_step(carry, qi):
        dk_acc, dv_acc = carry
        qblk = qs[:, qi].astype(F32) * scale
        doblk = dos[:, qi].astype(F32)
        lseb = lses[:, :, :, qi]
        dlt = deltas[:, :, :, qi]
        qpos = qi * qb + jnp.arange(qb)

        def kv_step(carry2, kj):
            dq_blk, dk_a, dv_a = carry2
            kblk = ks[:, kj].astype(F32)
            vblk = vs[:, kj].astype(F32)
            kpos = kj * kb + jnp.arange(kb)
            s = jnp.einsum("bskgh,btkh->bkgst", qblk, kblk)
            s = jnp.where(_mask(qpos, kpos, causal, window), s, NEG_INF)
            safe = jnp.logical_not(jnp.isinf(lseb))
            p = jnp.where(safe[..., None],
                          jnp.exp(s - jnp.where(safe, lseb, 0.0)[..., None]),
                          0.0)                           # (B,K,G,qb,kb)
            dv_a = dv_a.at[:, kj].add(
                jnp.einsum("bkgst,bskgh->btkh", p, doblk))
            dp = jnp.einsum("bskgh,btkh->bkgst", doblk, vblk)
            ds = p * (dp - dlt[..., None])
            dq_blk = dq_blk + jnp.einsum("bkgst,btkh->bskgh", ds, kblk)
            # qblk is pre-scaled, so this already carries the 1/sqrt(hd)
            dk_a = dk_a.at[:, kj].add(
                jnp.einsum("bkgst,bskgh->btkh", ds, qblk))
            return (dq_blk, dk_a, dv_a), None

        dq0 = jnp.zeros((B, qb, K, G, hd), F32)
        (dq_blk, dk_acc, dv_acc), _ = jax.lax.scan(
            kv_step, (dq0, dk_acc, dv_acc), jnp.arange(nk))
        return (dk_acc, dv_acc), dq_blk * scale

    dk0 = jnp.zeros((B, nk, kb, K, hd), F32)
    dv0 = jnp.zeros((B, nk, kb, K, hd), F32)
    (dk, dv), dqs = jax.lax.scan(q_step, (dk0, dv0), jnp.arange(nq))
    dq = dqs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, K, G, hd)
    return (dq.astype(q.dtype), dk.reshape(B, T, K, hd).astype(k.dtype),
            dv.reshape(B, T, K, hd).astype(v.dtype))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal=True, window=0, qb=512, kb=1024):
    out, _ = _fwd_impl(q, k, v, causal, window, qb, kb)
    return out


def _fwd_rule(q, k, v, causal, window, qb, kb):
    out, lse = _fwd_impl(q, k, v, causal, window, qb, kb)
    return out, (q, k, v, out, lse)


def _bwd_rule(causal, window, qb, kb, res, dout):
    return _bwd_impl(res, dout, causal, window, qb, kb)


flash_attention.defvjp(_fwd_rule, _bwd_rule)
