"""Loop-aware static analysis of optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body exactly once,
which silently undercounts every scan-over-layers model by its depth.  The
optimized HLO, however, annotates loops with ``known_trip_count`` -- so this
module re-derives the three roofline inputs by walking the HLO text with
per-computation execution multipliers:

  * ``flops``            -- 2 * numel(out) * contracted for every dot, inside
                            fusions included, x trip counts;
  * ``traffic_bytes``    -- operand+result bytes of every top-level op in an
                            executable computation (fusion = one kernel, so
                            its boundary IS the HBM traffic), x trip counts;
  * ``collective_bytes`` -- result bytes of all-reduce / all-gather /
                            reduce-scatter / all-to-all / collective-permute,
                            x trip counts, split per collective type.

Shapes in post-SPMD HLO are per-device, so all numbers are *per-device*.
"""
from __future__ import annotations

import collections
import dataclasses
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OPLINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s*([\w\-]+)\((.*)$")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERANDS = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast")
_NO_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "after-all", "add-dependency", "partition-id",
               "replica-id", "opt-barrier"}


def _shape_elems(type_str: str) -> List[Tuple[str, int]]:
    out = []
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append((dt, n))
    return out


def shape_bytes(type_str: str) -> int:
    return sum(n * DTYPE_BYTES[dt] for dt, n in _shape_elems(type_str))


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str                      # operand list + attributes
    operands: List[str]
    called: List[str]
    trip: Optional[int]


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, str]         # param name -> type str
    ops: List[Op]


_COMMENT = re.compile(r"/\*.*?\*/")


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        line = _COMMENT.sub("", line)
        if cur is None:
            m = _COMP_HDR.match(line.strip()) if "{" in line else None
            if m and "->" in line:
                params = {}
                for pm in re.finditer(r"([\w.\-]+):\s*((?:\([^)]*\)|[^,]+))",
                                      m.group(2)):
                    params[pm.group(1)] = pm.group(2)
                cur = Computation(m.group(1), params, [])
            continue
        s = line.strip()
        if s == "}" or s.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OPLINE.match(line)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        # operand segment = up to the matching close paren at depth 0
        depth, end = 1, len(rest)
        for idx, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = idx
                    break
        operand_seg, attr_seg = rest[:end], rest[end:]
        operands = _OPERANDS.findall(operand_seg)
        called = _CALLED.findall(attr_seg)
        bm = _BRANCHES.search(attr_seg)
        if bm:
            called += _OPERANDS.findall(bm.group(1))
        tm = _TRIP.search(attr_seg)
        cur.ops.append(Op(name, type_str, opcode, rest, operands, called,
                          int(tm.group(1)) if tm else None))
    return comps


@dataclasses.dataclass
class HLOStats:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: collections.defaultdict(float))
    collective_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: collections.defaultdict(float))
    dot_flops_by_comp: Dict[str, float] = dataclasses.field(
        default_factory=dict)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def _dot_flops(op: Op, symtab: Dict[str, str]) -> float:
    out = _shape_elems(op.type_str)
    out_elems = sum(n for _, n in out)
    lhs = symtab.get(op.operands[0]) if op.operands else None
    if lhs is None:
        return 0.0
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    dims = []
    sm = _SHAPE.search(lhs)
    if sm:
        dims = [int(d) for d in sm.group(2).split(",") if d]
    contracted = 1
    if cm:
        for ci in cm.group(1).split(","):
            if ci and int(ci) < len(dims):
                contracted *= dims[int(ci)]
    return 2.0 * out_elems * contracted


def analyze(text: str) -> HLOStats:
    comps = parse_hlo(text)
    entry = None
    for name in comps:
        if name.startswith("main") or ".main" in name or entry is None:
            if entry is None or "main" in name:
                entry = name
    # call-graph multipliers
    mult: Dict[str, float] = collections.defaultdict(float)
    fusion_internal: set = set()
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    # BFS through the call graph, propagating execution multipliers
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps.get(cname)
        if comp is None:
            continue
        for op in comp.ops:
            factor = float(op.trip) if (op.opcode == "while" and op.trip) else 1.0
            for callee in op.called:
                if callee not in comps:
                    continue
                if op.opcode == "fusion":
                    fusion_internal.add(callee)
                if op.opcode == "while" and callee.endswith(
                        tuple(f"{k}" for k in ())):
                    pass
                extra = mult[cname] * (factor if op.opcode == "while" else 1.0)
                mult[callee] += extra
                if callee not in seen:
                    seen.add(callee)
                    order.append(callee)

    stats = HLOStats()
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        symtab = dict(comp.params)
        for op in comp.ops:
            symtab[op.name] = op.type_str
        comp_dot = 0.0
        for op in comp.ops:
            if op.opcode in ("dot", "convolution"):
                comp_dot += _dot_flops(op, symtab)
            if cname in fusion_internal:
                continue                      # traffic counted at call site
            if op.opcode in _NO_TRAFFIC or op.opcode == "while":
                continue
            out_b = shape_bytes(op.type_str)
            in_b = sum(shape_bytes(symtab.get(o, "")) for o in op.operands)
            stats.traffic_bytes += m * (out_b + in_b)
            for coll in COLLECTIVES:
                if op.opcode == coll or op.opcode == coll + "-start":
                    stats.collective_bytes[coll] += m * out_b
                    stats.collective_counts[coll] += m
        if comp_dot:
            stats.flops += m * comp_dot
            stats.dot_flops_by_comp[cname] = m * comp_dot
    return stats
