"""Assigned input shapes and abstract input specs per (arch x shape) cell.

Shapes (LM family, seq_len x global_batch):
  train_4k     4,096 x 256    (training       -> train_step)
  prefill_32k  32,768 x 32    (inference      -> prefill step)
  decode_32k   32,768 x 128   (decode: 1 new token, KV cache of seq_len)
  long_500k    524,288 x 1    (long-context decode; sub-quadratic archs only)

``input_specs`` returns ShapeDtypeStructs with shardings attached (the
dry-run's stand-ins: weak-type-correct, shardable, no device allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import abstract_caches, cache_shardings
from repro.models.config import ModelConfig
from repro.models import sharding as shd

SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}


def shape_applicable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, ("pure full-attention architecture: O(L^2) attention at "
                       "524k tokens is not runnable; long_500k is assigned to "
                       "SSM/hybrid archs only (see DESIGN.md)")
    return True, ""


def _sds(shape, dtype, logical):
    sh = shd.sharding_for(logical, shape)
    if sh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sh)


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """Abstract model inputs for one cell.  For decode shapes this includes
    the KV/state caches (the serve_step signature is (params, batch, caches,
    pos))."""
    info = SHAPES[shape_name]
    B, S = info["batch"], info["seq"]
    kind = info["kind"]
    dt = jnp.dtype(cfg.compute_dtype)

    def token_batch(b, s):
        out = {}
        if cfg.family == "encdec":
            out["frames"] = _sds((b, s, cfg.d_model), dt, ("batch", "seq", None))
            out["tokens"] = _sds((b, s), jnp.int32, ("batch", "seq"))
        elif cfg.embeds_input:
            out["embeds"] = _sds((b, s, cfg.d_model), dt, ("batch", "seq", None))
            out["labels"] = _sds((b, s), jnp.int32, ("batch", "seq"))
            if cfg.rope_style == "mrope":
                out["positions"] = _sds((3, b, s), jnp.int32,
                                        (None, "batch", "seq"))
        else:
            out["tokens"] = _sds((b, s), jnp.int32, ("batch", "seq"))
        return out

    if kind in ("train", "prefill"):
        return {"batch": token_batch(B, S)}

    # decode: one new token against caches of length S
    caches = abstract_caches(cfg, B, S, enc_len=S if cfg.family == "encdec" else 0)
    cshard = cache_shardings(cfg, B, S, enc_len=S if cfg.family == "encdec" else 0)
    caches = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s)
        if s is not None else a, caches, cshard)
    tok = {}
    if cfg.family == "encdec":
        tok["frames"] = _sds((B, 1, cfg.d_model), dt, ("batch", None, None))
        tok["tokens"] = _sds((B, 1), jnp.int32, ("batch", None))
    elif cfg.embeds_input:
        tok["embeds"] = _sds((B, 1, cfg.d_model), dt, ("batch", None, None))
        if cfg.rope_style == "mrope":
            tok["positions"] = _sds((3, B, 1), jnp.int32, (None, "batch", None))
    else:
        tok["tokens"] = _sds((B, 1), jnp.int32, ("batch", None))
    return {"batch": tok, "caches": caches,
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}
