"""Production mesh construction (single-pod v5e-256 and 2-pod 512-chip).

Defined as functions (never module-level constants) so importing this
module does not touch jax device state.

jax version compat: ``jax.sharding.AxisType`` (and the ``axis_types``
kwarg of ``jax.make_mesh`` / the modern ``AbstractMesh`` signature) only
exist on jax >= 0.5; on the 0.4.x line meshes take no axis types and
``AbstractMesh`` takes a ``((name, size), ...)`` shape tuple.  The
``make_mesh_compat`` / ``abstract_mesh_compat`` helpers below paper over
the difference and are the only mesh constructors the rest of the repo
(and the test suite) should use.
"""
from __future__ import annotations

from ..compat import (HAS_AXIS_TYPES, abstract_mesh_compat,  # noqa: F401
                      make_mesh_compat)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_mesh_for(devices: int, model_parallel: int = 1, pods: int = 1):
    """Generic mesh helper for examples/tests on arbitrary device counts."""
    data = devices // (model_parallel * pods)
    if pods > 1:
        return make_mesh_compat((pods, data, model_parallel),
                                ("pod", "data", "model"))
    return make_mesh_compat((data, model_parallel), ("data", "model"))


def make_solver_mesh(*, multi_pod: bool = False):
    """Flat 2-D processor grid for the distributed p(l)-CG solver: the
    Poisson domain is decomposed over ("data","model") as a (16,16) (or
    (32,16) across pods) grid of subdomains."""
    return make_production_mesh(multi_pod=multi_pod)
