"""Production mesh construction (single-pod v5e-256 and 2-pod 512-chip).

Defined as functions (never module-level constants) so importing this
module does not touch jax device state.

jax version compat: ``jax.sharding.AxisType`` (and the ``axis_types``
kwarg of ``jax.make_mesh`` / the modern ``AbstractMesh`` signature) only
exist on jax >= 0.5; on the 0.4.x line meshes take no axis types and
``AbstractMesh`` takes a ``((name, size), ...)`` shape tuple.  The
``make_mesh_compat`` / ``abstract_mesh_compat`` helpers below paper over
the difference and are the only mesh constructors the rest of the repo
(and the test suite) should use.
"""
from __future__ import annotations

from ..compat import (HAS_AXIS_TYPES, abstract_mesh_compat,  # noqa: F401
                      make_mesh_compat)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_mesh_for(devices: int, model_parallel: int = 1, pods: int = 1):
    """Generic mesh helper for examples/tests on arbitrary device counts."""
    data = devices // (model_parallel * pods)
    if pods > 1:
        return make_mesh_compat((pods, data, model_parallel),
                                ("pod", "data", "model"))
    return make_mesh_compat((data, model_parallel), ("data", "model"))


def make_solver_mesh(*, multi_pod: bool = False):
    """Flat 2-D processor grid for the distributed p(l)-CG solver: the
    Poisson domain is decomposed over ("data","model") as a (16,16) (or
    (32,16) across pods) grid of subdomains.  Pass the result straight to
    ``repro.core.solve(A, b, mesh=...)``."""
    if multi_pod:
        # fold the pod axis into rows: the solver engine wants a flat
        # 2-axis grid (32 x 16 subdomains)
        return make_mesh_compat((32, 16), ("data", "model"))
    return make_production_mesh(multi_pod=False)


def make_solver_mesh_for(devices: int, ny: int | None = None,
                         nx: int | None = None):
    """Flat 2-D solver processor grid for an arbitrary device count.

    The column axis gets the largest power of two whose square fits in
    ``devices`` and that divides ``ny``; the remaining devices become
    rows, trimmed until they divide ``nx`` -- so the decomposition in
    ``solve(..., mesh=...)`` is legal on an (nx, ny) grid whenever both
    extents are passed.  Device counts that don't factor cleanly use the
    largest legal subset (e.g. 4 of 5 devices).  This is the mesh the
    launchers hand to the mesh-aware front-end.
    """
    mp = 1
    while mp * mp <= devices and (ny is None or ny % mp == 0):
        mp *= 2
    mp = max(mp // 2, 1)
    rows = max(devices // mp, 1)
    while rows > 1 and nx is not None and nx % rows:
        rows -= 1
    return make_mesh_compat((rows, mp), ("data", "model"))
