"""Production mesh construction (single-pod v5e-256 and 2-pod 512-chip).

Defined as functions (never module-level constants) so importing this
module does not touch jax device state.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_mesh_for(devices: int, model_parallel: int = 1, pods: int = 1):
    """Generic mesh helper for examples/tests on arbitrary device counts."""
    data = devices // (model_parallel * pods)
    if pods > 1:
        return jax.make_mesh((pods, data, model_parallel),
                             ("pod", "data", "model"),
                             axis_types=(AxisType.Auto,) * 3)
    return jax.make_mesh((data, model_parallel), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)


def make_solver_mesh(*, multi_pod: bool = False):
    """Flat 2-D processor grid for the distributed p(l)-CG solver: the
    Poisson domain is decomposed over ("data","model") as a (16,16) (or
    (32,16) across pods) grid of subdomains."""
    return make_production_mesh(multi_pod=multi_pod)
