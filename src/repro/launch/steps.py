"""Step builders: train_step / prefill_step / decode_step used by the
trainer, the server, and the multi-pod dry-run."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import loss_fn, prefill, decode_step, param_shardings
from repro.models import sharding as shd
from repro.models.config import ModelConfig
from repro.training.optim import AdamWConfig, adamw_update


def build_train_step(cfg: ModelConfig, ocfg: AdamWConfig, *,
                     remat: str = "full", block_skip: bool = False,
                     microbatches: int = 1):
    """Full training step: (micro-batched) fwd+bwd, gradient accumulation,
    AdamW update.  ``microbatches > 1`` bounds activation memory to one
    microbatch (standard large-model practice; the f32 accumulator is
    sharded like the params)."""
    def constrain_like_params(tree):
        if shd.get_mesh() is None:
            return tree
        # keep stacked per-layer gradients sharded like the params: an
        # unconstrained backward-scan accumulator materializes replicated
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            param_shardings(cfg))

    def grad_fn(params, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, remat=remat,
                              block_skip=block_skip))(params)
        return loss, constrain_like_params(grads)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = grad_fn(params, batch)
        else:
            mb_batch = jax.tree.map(
                lambda a: a.reshape((a.shape[0], microbatches,
                                     a.shape[1] // microbatches)
                                    + a.shape[2:]).swapaxes(0, 1)
                if a.ndim >= 2 and a.shape[0] == 3          # mrope positions
                else a.reshape((microbatches, a.shape[0] // microbatches)
                               + a.shape[1:]), batch)

            def mb_body(acc, mb):
                loss, grads = grad_fn(params, mb)
                acc = jax.tree.map(lambda a, g: a + g.astype(a.dtype),
                                   acc, grads)
                return constrain_like_params(acc), loss

            acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)
            acc0 = constrain_like_params(acc0)
            acc, losses = jax.lax.scan(mb_body, acc0, mb_batch)
            grads = jax.tree.map(
                lambda a, p: (a / microbatches).astype(p.dtype), acc, params)
            loss = losses.mean()
        new_params, new_opt = adamw_update(params, grads, opt_state, ocfg)
        new_params = constrain_like_params(new_params)
        return new_params, new_opt, {"loss": loss}
    return train_step


def build_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        logits, caches = prefill(cfg, params, batch)
        return logits[:, -1:], caches
    return prefill_step


def build_decode_step(cfg: ModelConfig):
    def serve_step(params, batch, caches, pos):
        logits, caches = decode_step(cfg, params, batch, caches, pos)
        # greedy token for the serving loop; logits stay available
        next_tok = jnp.argmax(logits[:, -1], axis=-1)
        return next_tok, logits, caches
    return serve_step
