import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""SPerf hillclimbing driver: hypothesis -> change -> re-lower -> validate.

Each experiment re-runs one dry-run cell with a code/config knob changed and
records the three roofline terms under experiments/dryrun/<mesh>/<tag>.json.
The narrative (hypothesis, napkin math, confirmed/refuted) lives in
EXPERIMENTS.md SPerf; this script produces the numbers.

  PYTHONPATH=src python -m repro.launch.hillclimb --cell whisper_prefill
"""
import argparse
import json
import pathlib

from repro.launch.dryrun import OUT_DIR, run_cell


def _report(rec, label):
    if not rec.get("ok") or rec.get("skipped"):
        print(f"{label}: FAIL {rec.get('error', '')[:200]}")
        return
    r = rec["roofline"]
    print(f"{label}: tc={r['t_compute_s']:.3f} tm={r['t_memory_s']:.3f} "
          f"tn={r['t_collective_s']:.3f} dom={r['dominant']} "
          f"mem={rec['memory']['peak_per_device']/1e9:.1f}GB "
          f"useful={r['model_flops_ratio']:.3f}")


def whisper_prefill(out):
    """Cell: whisper-large-v3 / prefill_32k / single (worst useful-flops
    ratio, memory-dominated).  Knob: flash tile sizes -- traffic of the
    streaming KV read scales with nq = S/qb."""
    from repro.models import flash
    for qb, kb in [(512, 1024), (1024, 2048), (2048, 4096)]:
        flash.set_blocks(qb, kb)
        rec = run_cell("whisper-large-v3", "prefill_32k", "single", out,
                       force=True, extra_tag=f"qb{qb}_kb{kb}")
        _report(rec, f"whisper prefill qb={qb} kb={kb}")
    flash.set_blocks(512, 1024)


def mistral_train(out):
    """Cell: mistral-large-123b / train_4k / multi (most collective-bound).
    Knob: gradient-accumulation depth -- FSDP weight gathers repeat per
    microbatch, so halving microbatches should ~halve gather bytes at the
    cost of 2x activation memory."""
    for micro in (8, 4, 2):
        rec = run_cell("mistral-large-123b", "train_4k", "multi", out,
                       force=True, extra_tag=f"micro{micro}",
                       step_overrides={"microbatches": micro})
        _report(rec, f"mistral train micro={micro}")


def mistral_train_remat(out):
    """Same cell, remat policy: 'dots' saves matmul outputs (no recompute of
    the big einsums in the backward) -- trades memory for a lower compute
    term and fewer regathers in the rematerialized segments."""
    for remat in ("full", "dots"):
        rec = run_cell("mistral-large-123b", "train_4k", "multi", out,
                       force=True, extra_tag=f"remat_{remat}",
                       step_overrides={"remat": remat, "microbatches": 8})
        _report(rec, f"mistral train remat={remat}")


def moe_train(out):
    """Cell: qwen3-moe / train_4k / single (collective-bound MoE).
    Knob: microbatches (gather amplification) -- same hypothesis family as
    mistral but with expert all-gathers in the mix."""
    for micro in (32, 8, 2):
        rec = run_cell("qwen3-moe-235b-a22b", "train_4k", "single", out,
                       force=True, extra_tag=f"micro{micro}",
                       step_overrides={"microbatches": micro})
        _report(rec, f"qwen3-moe train micro={micro}")


CELLS = {
    "whisper_prefill": whisper_prefill,
    "mistral_train": mistral_train,
    "mistral_train_remat": mistral_train_remat,
    "moe_train": moe_train,
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=list(CELLS) + ["all"], default="all")
    ap.add_argument("--out", default=str(OUT_DIR))
    args = ap.parse_args()
    out = pathlib.Path(args.out)
    names = list(CELLS) if args.cell == "all" else [args.cell]
    for n in names:
        print(f"=== {n} ===", flush=True)
        CELLS[n](out)


if __name__ == "__main__":
    main()
