"""Training launcher: fault-tolerant loop with checkpoint/auto-resume.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b --steps 50 \\
      --reduced --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On this CPU container use --reduced (smoke-sized config); on a TPU fleet
drop --reduced and the production mesh is built from the visible devices.
``--optimizer newton_pcg`` trains with the paper's deep-pipelined CG as a
second-order method (the technique as a first-class training feature).
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import ARCHS, get_config, get_reduced
from repro.launch.mesh import make_mesh_for
from repro.launch.steps import build_train_step
from repro.models import init_params, loss_fn
from repro.models import sharding as shd
from repro.training import (AdamWConfig, CheckpointManager, NewtonPCGConfig,
                            NewtonPCGTrainer, Prefetcher, StragglerMonitor,
                            adamw_init)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCHS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adamw8bit", "newton_pcg"])
    ap.add_argument("--pipeline-depth", default="2",
                    help="p(l)-CG depth for newton_pcg: an int, or 'auto' "
                         "to calibrate against measured HVP latency")
    ap.add_argument("--inner-comm", default=None,
                    choices=["blocking", "overlap", "ring", "auto"],
                    help="reduction policy of the newton_pcg inner solve "
                         "on a mesh")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    ndev = len(jax.devices())
    mesh = (make_mesh_for(ndev, model_parallel=args.model_parallel)
            if ndev > 1 else None)
    if mesh is not None and args.optimizer != "newton_pcg":
        # newton_pcg keeps the global sharding context UNSET: its GGN
        # mesh operator runs the model shard-locally inside shard_map
        # (where global sharding constraints cannot apply) and shards
        # the flat parameter vector along the FSDP axis itself
        shd.set_mesh(mesh)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    monitor = StragglerMonitor(
        heartbeat_path=(f"{args.ckpt_dir}/heartbeat.json"
                        if args.ckpt_dir else None))
    start_step = 0

    if args.optimizer == "newton_pcg":
        depth = (args.pipeline_depth if args.pipeline_depth == "auto"
                 else int(args.pipeline_depth))
        ncfg = NewtonPCGConfig(l=depth, lr=args.lr)
        lf = lambda p, b: loss_fn(cfg, p, b, remat=args.remat)  # noqa: E731
        trainer = NewtonPCGTrainer(lf, ncfg, mesh=mesh,
                                   comm=args.inner_comm, monitor=monitor)
        step_fn = trainer.step
        opt_state = None
        if ckpt and ckpt.latest_step() is not None:
            start_step, tree, _ = ckpt.restore()
            params = tree["params"]
            print(f"resumed from step {start_step}")
    else:
        ocfg = AdamWConfig(lr=args.lr,
                           eightbit=args.optimizer == "adamw8bit")
        opt_state = adamw_init(params, ocfg)
        train_step = build_train_step(cfg, ocfg, remat=args.remat,
                                      microbatches=args.microbatches)
        step_fn = jax.jit(train_step)
        if ckpt and ckpt.latest_step() is not None:
            start_step, tree, _ = ckpt.restore()
            params, opt_state = tree["params"], tree["opt"]
            print(f"resumed from step {start_step}")

    pf = Prefetcher(cfg, args.batch, args.seq, start_step=start_step,
                    seed=args.seed)
    it = iter(pf)
    try:
        for _ in range(args.steps - start_step):
            step, batch = next(it)
            t0 = time.time()
            if args.optimizer == "newton_pcg":
                params, stats = step_fn(params, batch)
                loss = float(stats["loss"])
            else:
                params, opt_state, aux = step_fn(params, opt_state, batch)
                loss = float(aux["loss"])
            dt = time.time() - t0
            slow = monitor.record(step, dt)
            print(f"step {step:5d} loss {loss:9.4f} {dt*1e3:8.1f} ms"
                  + ("  [straggler]" if slow else ""), flush=True)
            if ckpt and (step + 1) % args.ckpt_every == 0:
                tree = {"params": params}
                if opt_state is not None:
                    tree["opt"] = opt_state
                ckpt.save_async(step + 1, tree)
        if ckpt:
            tree = {"params": params}
            if opt_state is not None:
                tree["opt"] = opt_state
            ckpt.wait()
            ckpt.save(args.steps, tree)
    finally:
        pf.close()
    print(f"done: {args.steps} steps, mean {monitor.mean_step_s*1e3:.1f} "
          f"ms/step, stragglers flagged: {monitor.flagged}")
    return params


if __name__ == "__main__":
    main()
