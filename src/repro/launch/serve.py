"""Serving launcher: batched prefill + greedy decode loop.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --reduced \\
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, get_reduced
from repro.launch.mesh import make_mesh_for
from repro.launch.steps import build_decode_step
from repro.models import init_params, prefill
from repro.models import sharding as shd


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCHS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    ndev = len(jax.devices())
    if ndev > 1:
        shd.set_mesh(make_mesh_for(ndev, model_parallel=args.model_parallel))
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    B, P = args.batch, args.prompt_len
    max_len = P + args.gen

    if cfg.family == "encdec":
        batch = {"frames": jnp.asarray(
            rng.standard_normal((B, P, cfg.d_model)), jnp.float32),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, P)),
                                  jnp.int32)}
    elif cfg.embeds_input:
        batch = {"embeds": jnp.asarray(
            rng.standard_normal((B, P, cfg.d_model)), jnp.float32)}
    else:
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, P)),
                                       jnp.int32)}

    t0 = time.time()
    pre = jax.jit(lambda p, b: prefill(cfg, p, b, max_len=max_len))
    logits, caches = pre(params, batch)
    next_tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    print(f"prefill {P} tokens x {B}: {time.time()-t0:.2f}s")

    serve_step = jax.jit(build_decode_step(cfg))
    toks = [np.asarray(next_tok)]
    t0 = time.time()
    for i in range(args.gen - 1):
        if cfg.family == "encdec":
            tb = {"frames": batch["frames"][:, :1] * 0, "tokens": next_tok}
        elif cfg.embeds_input:
            tb = {"embeds": params["embed"][next_tok[:, 0]][:, None]
                  .astype(jnp.float32)}
        else:
            tb = {"tokens": next_tok}
        nt, logits, caches = serve_step(params, tb, caches,
                                        jnp.int32(P + i))
        next_tok = nt[:, None]
        toks.append(np.asarray(next_tok))
    dt = time.time() - t0
    out = np.concatenate(toks, axis=1)
    print(f"decoded {args.gen} tokens x {B} in {dt:.2f}s "
          f"({args.gen*B/max(dt,1e-9):.1f} tok/s)")
    print("sample token ids:", out[0][:16].tolist())
    return out


if __name__ == "__main__":
    main()
