import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST be the first two lines: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell and extract memory / cost / collective-schedule evidence.

  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k --mesh multi

Results are cached as JSON under experiments/dryrun/<mesh>/<arch>__<shape>.json
and aggregated by benchmarks/roofline.py into EXPERIMENTS.md tables.
"""
import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, input_specs, shape_applicable
from repro.launch.steps import build_decode_step, build_prefill_step, build_train_step
from repro.models import abstract_params, param_shardings
from repro.models import sharding as shd
from repro.training.optim import AdamWConfig, abstract_adamw_state

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

#: archs whose fp32 Adam state would overflow a single pod's HBM -> 8-bit
EIGHTBIT = {"arctic-480b", "mistral-large-123b", "qwen3-moe-235b-a22b"}

#: gradient-accumulation microbatches per train step (activation memory
#: control; chosen per-arch from the dry-run iteration log)
MICROBATCH = {
    "arctic-480b": 16,
    "qwen3-moe-235b-a22b": 32,
    "mistral-large-123b": 8,
    "qwen1.5-32b": 4,
    "qwen3-14b": 2,
    "recurrentgemma-9b": 4,
    "mamba2-370m": 4,
    "whisper-large-v3": 4,
    "chatglm3-6b": 2,
}

# v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link


def _attach(tree_abs, tree_shard):
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s)
        if s is not None else a, tree_abs, tree_shard)


def _opt_shardings(cfg, state_abs):
    """m/v follow the param logical axes; 8-bit q/s blocks inherit them too
    (the quantization splits only the last axis, so leading shardings
    survive -- see training/optim.py)."""
    from repro.models.schema import Spec, model_schema
    sch = model_schema(cfg)

    def mv(sub):
        def leaf(spec, a):
            if isinstance(a, dict):            # q8 {q, s}
                ql = tuple(spec.logical) + (None,)
                return {"q": shd.sharding_for(ql, a["q"].shape),
                        "s": shd.sharding_for(spec.logical, a["s"].shape)}
            return shd.sharding_for(spec.logical, a.shape)
        return jax.tree.map(leaf, sch, sub,
                            is_leaf=lambda v: isinstance(v, Spec))
    return {"m": mv(state_abs["m"]), "v": mv(state_abs["v"]),
            "count": shd.replicated()}


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: pathlib.Path,
             force: bool = False, extra_tag: str = "", step_overrides=None):
    cell_dir = out_dir / mesh_kind
    cell_dir.mkdir(parents=True, exist_ok=True)
    tag = f"{arch}__{shape_name}" + (f"__{extra_tag}" if extra_tag else "")
    path = cell_dir / f"{tag}.json"
    if path.exists() and not force:
        return json.loads(path.read_text())

    cfg = get_config(arch)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind, "ok": False,
           "tag": extra_tag}
    ok, why = shape_applicable(cfg, shape_name)
    if not ok:
        rec.update({"skipped": True, "reason": why, "ok": True})
        path.write_text(json.dumps(rec, indent=1))
        return rec

    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    shd.set_mesh(mesh, rules={"optflat": ("data", "model")})
    t0 = time.time()
    try:
        p_abs = _attach(abstract_params(cfg), param_shardings(cfg))
        specs = input_specs(cfg, shape_name)
        kind = SHAPES[shape_name]["kind"]
        overrides = step_overrides or {}
        if kind == "train":
            ocfg = AdamWConfig(eightbit=arch in EIGHTBIT)
            s_abs = abstract_adamw_state(p_abs, ocfg)
            s_abs = _attach(s_abs, _opt_shardings(cfg, s_abs))
            # microbatch must stay divisible by the batch-sharding axes
            bdiv = 1
            for ax in ("pod", "data"):
                bdiv *= mesh.shape.get(ax, 1)
            B_glob = SHAPES[shape_name]["batch"]
            micro = overrides.get("microbatches", MICROBATCH.get(arch, 1))
            while micro > 1 and (B_glob % micro or (B_glob // micro) % bdiv):
                micro //= 2
            step = build_train_step(
                cfg, ocfg,
                remat=overrides.get("remat", "full"),
                block_skip=overrides.get("block_skip", False),
                microbatches=max(micro, 1))
            args = (p_abs, s_abs, specs["batch"])
        elif kind == "prefill":
            step = build_prefill_step(cfg)
            args = (p_abs, specs["batch"])
        else:
            step = build_decode_step(cfg)
            args = (p_abs, specs["batch"], specs["caches"], specs["pos"])

        lowered = jax.jit(step).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        txt = compiled.as_text()
        st = hlo_analysis.analyze(txt)
        n_dev = mesh.size

        N = cfg.num_params()
        Na = cfg.num_active_params()
        B, S = SHAPES[shape_name]["batch"], SHAPES[shape_name]["seq"]
        if kind == "train":
            model_flops = 6.0 * Na * B * S
        elif kind == "prefill":
            model_flops = 2.0 * Na * B * S
        else:
            model_flops = 2.0 * Na * B
        model_flops_dev = model_flops / n_dev

        rec.update({
            "ok": True,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "devices": n_dev,
            "memory": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "peak_per_device": ma.argument_size_in_bytes
                + ma.temp_size_in_bytes,
                "fits_16GB": (ma.argument_size_in_bytes + ma.temp_size_in_bytes)
                < 16e9,
            },
            "xla_cost": {"flops": ca.get("flops"),
                         "bytes": ca.get("bytes accessed")},
            "hlo": {
                "flops_per_device": st.flops,
                "traffic_bytes_per_device": st.traffic_bytes,
                "collective_bytes": dict(st.collective_bytes),
                "collective_counts": dict(st.collective_counts),
                "total_collective_bytes": st.total_collective_bytes,
            },
            "params": {"total": N, "active": Na},
            "model_flops_per_device": model_flops_dev,
            "roofline": {
                "t_compute_s": st.flops / PEAK_FLOPS,
                "t_memory_s": st.traffic_bytes / HBM_BW,
                "t_collective_s": st.total_collective_bytes / ICI_BW,
                "model_flops_ratio": (model_flops_dev / st.flops
                                      if st.flops else None),
            },
        })
        terms = rec["roofline"]
        dom = max(("t_compute_s", "t_memory_s", "t_collective_s"),
                  key=lambda k: terms[k])
        rec["roofline"]["dominant"] = dom
    except Exception as e:  # noqa: BLE001 -- record the failure for triage
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCHS + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=str(OUT_DIR))
    args = ap.parse_args()
    out = pathlib.Path(args.out)

    cells = []
    archs = ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    for a in archs:
        for s in shapes:
            cells.append((a, s))
    for a, s in cells:
        t0 = time.time()
        rec = run_cell(a, s, args.mesh, out, force=args.force)
        status = ("SKIP" if rec.get("skipped")
                  else "ok" if rec.get("ok") else "FAIL")
        extra = ""
        if rec.get("ok") and not rec.get("skipped"):
            mem = rec["memory"]["peak_per_device"] / 1e9
            dom = rec["roofline"]["dominant"]
            extra = f"mem/dev={mem:.2f}GB dom={dom}"
        if status == "FAIL":
            extra = rec.get("error", "")[:160]
        print(f"[{args.mesh}] {a:24s} {s:12s} {status:4s} "
              f"({time.time()-t0:6.1f}s) {extra}", flush=True)


if __name__ == "__main__":
    main()
