"""Solver launcher: the paper's own workload -- p(l)-CG Poisson solves.

Single device the solve goes through the unified ``repro.core.solve``
front-end (any registered --method, incl. batched --nrhs > 1); with
multiple devices it runs the distributed shard_map engine.

  PYTHONPATH=src python -m repro.launch.solve --nx 200 --l 2 --tol 1e-5
  PYTHONPATH=src python -m repro.launch.solve --method plcg_scan --nrhs 8
  PYTHONPATH=src python -m repro.launch.solve --dryrun            # 16x16 mesh
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--nx", type=int, default=200)
    ap.add_argument("--ny", type=int, default=0)
    ap.add_argument("--l", type=int, default=2)
    ap.add_argument("--iters", type=int, default=1500)
    ap.add_argument("--tol", type=float, default=1e-5)
    ap.add_argument("--method", type=str, default="plcg_scan",
                    help="registered repro.core.solve method for the "
                    "single-device path (cg|pcg|plcg|plcg_scan|dlanczos|"
                    "plminres)")
    ap.add_argument("--nrhs", type=int, default=1,
                    help="number of right-hand sides; > 1 runs the batched "
                    "vmap(scan) multi-RHS engine")
    ap.add_argument("--backend", type=str, default=None,
                    help="scan-engine kernel backend: fused|pallas|ref|auto")
    ap.add_argument("--dryrun", action="store_true",
                    help="lower+compile on the production 16x16 (or 2x16x16 "
                    "with --multi-pod) mesh and report roofline terms")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args(argv)

    if args.dryrun:
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.shifts import chebyshev_shifts
    from repro.distributed import DistPoisson, dist_plcg
    from repro.distributed.plcg_dist import dist_plcg_solve
    from repro.launch.mesh import (make_mesh_compat, make_mesh_for,
                                   make_solver_mesh)

    ny = args.ny or args.nx
    sigma = chebyshev_shifts(0.0, 8.0, args.l)

    if args.dryrun:
        from repro.launch import hlo_analysis
        from repro.launch.dryrun import HBM_BW, ICI_BW, PEAK_FLOPS
        mesh = make_solver_mesh(multi_pod=args.multi_pod)
        # the solver mesh is a flat 2-D processor grid; multi-pod folds the
        # pod axis into rows (32 x 16 subdomains)
        if args.multi_pod:
            mesh = make_mesh_compat((32, 16), ("data", "model"))
        px, py = mesh.shape["data"], mesh.shape["model"]
        nx = max(args.nx, px * 128)       # production-scale local blocks
        nyy = max(ny, py * 128)
        op = DistPoisson(nx, nyy, mesh)
        b = jax.ShapeDtypeStruct((nx, nyy), jnp.float32)
        t0 = time.time()
        fn = lambda bb: dist_plcg(op, bb, l=args.l, iters=args.iters,  # noqa: E731
                                  sigma=sigma, tol=args.tol)
        lowered = jax.jit(fn).lower(b)
        compiled = lowered.compile()
        ma = compiled.memory_analysis()
        st = hlo_analysis.analyze(compiled.as_text())
        rec = {
            "arch": "poisson2d", "mesh": "multi" if args.multi_pod else "single",
            "grid": [nx, nyy], "l": args.l, "iters": args.iters,
            "compile_s": round(time.time() - t0, 1),
            "memory": {"peak_per_device":
                       ma.argument_size_in_bytes + ma.temp_size_in_bytes},
            "hlo": {"flops_per_device": st.flops,
                    "traffic_bytes_per_device": st.traffic_bytes,
                    "collective_bytes": dict(st.collective_bytes),
                    "collective_counts": dict(st.collective_counts)},
            "roofline": {
                "t_compute_s": st.flops / PEAK_FLOPS,
                "t_memory_s": st.traffic_bytes / HBM_BW,
                "t_collective_s": st.total_collective_bytes / ICI_BW,
            },
        }
        out = pathlib.Path("experiments/dryrun/solver")
        out.mkdir(parents=True, exist_ok=True)
        name = f"poisson2d__{'multi' if args.multi_pod else 'single'}__l{args.l}.json"
        (out / name).write_text(json.dumps(rec, indent=1))
        print(json.dumps(rec["roofline"], indent=1))
        print("memory/device GB:",
              rec["memory"]["peak_per_device"] / 1e9)
        return rec

    # real solve on available devices
    ndev = len(jax.devices())
    from repro.operators import poisson2d
    A = poisson2d(args.nx, ny)
    xs = np.ones((args.nx, ny))
    b_flat = np.asarray(A @ xs.reshape(-1))

    if ndev == 1:
        # single device: the unified front-end drives any registered method
        from repro.core import solve
        if args.nrhs > 1:
            rng = np.random.default_rng(0)
            B = np.stack([b_flat] + [np.asarray(A @ rng.standard_normal(A.n))
                                     for _ in range(args.nrhs - 1)])
        else:
            B = b_flat
        t0 = time.time()
        r = solve(A, B, method=args.method, l=args.l, tol=args.tol,
                  maxiter=args.iters, sigma=sigma, backend=args.backend)
        dt = time.time() - t0
        x = np.asarray(r.x)
        res = np.linalg.norm(b_flat - A @ (x[0] if args.nrhs > 1 else x))
        print(f"{args.method} (l={args.l}, nrhs={args.nrhs}) on "
              f"{args.nx}x{ny}: {r.iters} iters, {dt:.2f}s, "
              f"|b-Ax| = {res:.3e}, converged={r.converged}")
        return x

    mp = 1
    while mp * mp <= ndev and ny % mp == 0:
        mp *= 2
    mp //= 2
    mesh = make_mesh_for(ndev, model_parallel=max(mp, 1))
    op = DistPoisson(args.nx, ny, mesh)
    b = jnp.asarray(b_flat.reshape(args.nx, ny))
    t0 = time.time()
    x, resn, info = dist_plcg_solve(op, b, l=args.l, maxiter=args.iters,
                                    sigma=sigma, tol=args.tol)
    x = np.asarray(x)
    dt = time.time() - t0
    res = np.linalg.norm(b_flat - (A @ x.reshape(-1)))
    print(f"p({args.l})-CG on {args.nx}x{ny} over {ndev} devices: "
          f"{len(resn)} iters, {dt:.2f}s, |b-Ax| = {res:.3e}, "
          f"converged={info['converged']}, restarts={info['restarts']}")
    return x


if __name__ == "__main__":
    main()
