"""Solver launcher: the paper's own workload -- p(l)-CG Poisson solves.

Every path goes through the unified ``repro.core.solve`` front-end: on a
single device it dispatches any registered --method (incl. batched
--nrhs > 1); with multiple devices it passes ``mesh=`` so the same call
runs the mesh execution layer (shard_map domain decomposition inside,
vmap RHS batching outside, one fused psum per iteration).

  PYTHONPATH=src python -m repro.launch.solve --nx 200 --l 2 --tol 1e-5
  PYTHONPATH=src python -m repro.launch.solve --method plcg_scan --nrhs 8
  PYTHONPATH=src python -m repro.launch.solve --l auto --comm auto  # calibrated
  PYTHONPATH=src python -m repro.launch.solve --dryrun            # 16x16 mesh

``--serve --requests N`` switches to the prepared-solver serving mode:
one ``repro.core.session.Solver`` is built up front (validation /
normalization / sweep building once), N requests stream through a
``SolverPool`` that micro-batches them into padded batched sweeps
(``--max-batch`` lanes per flush), and the per-request outcomes plus
occupancy/compile stats are reported:

  PYTHONPATH=src python -m repro.launch.solve --serve --requests 32 \\
      --nx 64 --l 2 --max-batch 8
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time


def _print_auto(info: dict) -> None:
    """One line per calibrated decision: the chosen (l, comm, budget)
    and the measured latencies that justified it (SolveResult.info["auto"],
    see repro.core.autotune)."""
    lat = info["latencies"]
    glred = " ".join(f"{m}={v:.0f}us"
                     for m, v in sorted(lat["glred_us"].items()))
    print(f"  auto: l={info['l']} comm={info['comm']} "
          f"budget={info['budget']} ({info['source']}; "
          f"spmv={lat['spmv_us']:.0f}us glred {glred}; "
          f"model score {info['score_us']:.0f}us/iter)")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--nx", type=int, default=200)
    ap.add_argument("--ny", type=int, default=0)
    ap.add_argument("--l", type=str, default="2",
                    help="pipeline depth: an int, or auto to calibrate the "
                    "depth from measured latencies at session construction "
                    "(repro.core.autotune; the decision is reported)")
    ap.add_argument("--iters", type=int, default=1500)
    ap.add_argument("--tol", type=float, default=1e-5)
    ap.add_argument("--method", type=str, default="plcg_scan",
                    help="registered repro.core.solve method (single device: "
                    "cg|pcg|plcg|plcg_scan|dlanczos|plminres; on a mesh: "
                    "cg|plcg|plcg_scan)")
    ap.add_argument("--nrhs", type=int, default=1,
                    help="number of right-hand sides; > 1 runs the batched "
                    "multi-RHS engine (vmap(scan) on one device, "
                    "shard_map(vmap(scan)) on a mesh)")
    ap.add_argument("--backend", type=str, default=None,
                    help="scan-engine kernel backend: fused|pallas|ref|auto "
                    "(single-device only; the mesh path bypasses it)")
    ap.add_argument("--prec", type=str, default="none",
                    choices=["none", "jacobi", "blockjacobi", "chebyshev"],
                    help="preconditioner ladder: jacobi folds into the "
                    "fused megakernel, blockjacobi/chebyshev run "
                    "shard-local on a mesh (one psum per iteration)")
    ap.add_argument("--comm", type=str, default=None,
                    choices=["blocking", "overlap", "ring", "auto"],
                    help="mesh reduction schedule: blocking psum (default), "
                    "split psum_scatter + delayed all_gather (overlap), "
                    "staged ppermute ring (mesh runs only), or auto to pick "
                    "the measured-fastest schedule at session construction")
    ap.add_argument("--comm-depth", type=int, default=None,
                    help="overlap staging depth d, 1 <= d <= l "
                    "(--comm overlap only; default l)")
    ap.add_argument("--restart", type=str, default="auto",
                    help="in-scan breakdown recovery: auto (default), an "
                    "int cap of per-lane re-seeds, or none to disable "
                    "(plcg_scan; see the engine's restart= knob)")
    ap.add_argument("--residual-replacement", type=int, default=None,
                    help="period (committed updates) of the in-scan "
                    "true-residual recompute r = b - Ax (plcg_scan; "
                    "counters deep-pipeline residual drift)")
    ap.add_argument("--dryrun", action="store_true",
                    help="lower+compile on the production 16x16 (or 32x16 "
                    "with --multi-pod) mesh and report roofline terms")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--serve", action="store_true",
                    help="prepared-solver serving mode: build one Solver, "
                    "stream --requests RHS through a micro-batching "
                    "SolverPool, report per-request outcomes + occupancy")
    ap.add_argument("--requests", type=int, default=16,
                    help="number of serving requests (--serve only)")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="max lanes per pooled flush (--serve only)")
    args = ap.parse_args(argv)

    if args.dryrun:
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.shifts import chebyshev_shifts
    from repro.launch.mesh import make_solver_mesh, make_solver_mesh_for

    ny = args.ny or args.nx
    l = args.l if args.l == "auto" else int(args.l)
    if l == "auto" and args.dryrun:
        ap.error("--dryrun lowers one fixed-depth sweep; pass an int --l")
    # with l="auto" the depth is unknown until the session calibrates, so
    # the engine derives sigma from the (default) spectrum after resolution
    sigma = None if l == "auto" else chebyshev_shifts(0.0, 8.0, l)

    if args.dryrun:
        from repro.distributed import DistPoisson, plcg_mesh_sweep
        from repro.launch import hlo_analysis
        from repro.launch.dryrun import HBM_BW, ICI_BW, PEAK_FLOPS
        mesh = make_solver_mesh(multi_pod=args.multi_pod)
        px, py = mesh.shape["data"], mesh.shape["model"]
        nx = max(args.nx, px * 128)       # production-scale local blocks
        nyy = max(ny, py * 128)
        op = DistPoisson(nx, nyy, mesh)
        fn = plcg_mesh_sweep(op, l=l, iters=args.iters,
                             sigma=tuple(sigma), tol=args.tol)
        b = jax.ShapeDtypeStruct((nx, nyy), jnp.float32)
        t0 = time.time()
        lowered = fn.lower(b, b, args.iters)
        compiled = lowered.compile()
        ma = compiled.memory_analysis()
        st = hlo_analysis.analyze(compiled.as_text())
        rec = {
            "arch": "poisson2d", "mesh": "multi" if args.multi_pod else "single",
            "grid": [nx, nyy], "l": l, "iters": args.iters,
            "compile_s": round(time.time() - t0, 1),
            "memory": {"peak_per_device":
                       ma.argument_size_in_bytes + ma.temp_size_in_bytes},
            "hlo": {"flops_per_device": st.flops,
                    "traffic_bytes_per_device": st.traffic_bytes,
                    "collective_bytes": dict(st.collective_bytes),
                    "collective_counts": dict(st.collective_counts)},
            "roofline": {
                "t_compute_s": st.flops / PEAK_FLOPS,
                "t_memory_s": st.traffic_bytes / HBM_BW,
                "t_collective_s": st.total_collective_bytes / ICI_BW,
            },
        }
        out = pathlib.Path("experiments/dryrun/solver")
        out.mkdir(parents=True, exist_ok=True)
        name = f"poisson2d__{'multi' if args.multi_pod else 'single'}__l{l}.json"
        (out / name).write_text(json.dumps(rec, indent=1))
        print(json.dumps(rec["roofline"], indent=1))
        print("memory/device GB:",
              rec["memory"]["peak_per_device"] / 1e9)
        return rec

    # real solve on available devices -- ONE front-end call either way
    from repro.core import solve
    from repro.operators import poisson2d
    ndev = len(jax.devices())
    A = poisson2d(args.nx, ny)
    b_flat = np.asarray(A @ np.ones(args.nx * ny))
    if args.nrhs > 1:
        rng = np.random.default_rng(0)
        B = np.stack([b_flat] + [np.asarray(A @ rng.standard_normal(A.n))
                                 for _ in range(args.nrhs - 1)])
    else:
        B = b_flat
    mesh = (make_solver_mesh_for(ndev, ny, nx=args.nx) if ndev > 1
            else None)
    comm = None
    if args.comm_depth is not None and args.comm != "overlap":
        ap.error("--comm-depth requires --comm overlap")
    if args.comm == "auto":
        comm = "auto"       # sentinel, resolved at session construction
    elif args.comm is not None:
        from repro.core import CommPolicy
        comm = CommPolicy(mode=args.comm, depth=args.comm_depth)
    if args.restart == "auto":
        restart = "auto"
    elif args.restart.lower() in ("none", "off"):
        restart = None
    else:
        restart = int(args.restart)
    stab_kw = {}
    if args.method in ("plcg_scan",):
        stab_kw = {"restart": restart,
                   "residual_replacement": args.residual_replacement}
    M = None
    if args.prec == "jacobi":
        from repro.operators import jacobi
        M = jacobi(A)
    elif args.prec == "blockjacobi":
        from repro.core import BlockJacobi
        M = (BlockJacobi.for_mesh(A, mesh) if mesh is not None
             else BlockJacobi((args.nx, ny)))
    elif args.prec == "chebyshev":
        from repro.core import Chebyshev
        M = Chebyshev(A, spectrum=(0.5, 8.0), degree=3)
    if args.serve:
        # prepared-solver serving mode: setup once, micro-batch requests
        from repro.core.session import Solver, SolverPool
        t0 = time.time()
        solver = Solver(A, args.method, l=l, tol=args.tol,
                        maxiter=args.iters,
                        sigma=None if M is not None else sigma,
                        M=M, backend=args.backend, mesh=mesh, comm=comm,
                        **stab_kw)
        pool = SolverPool(solver, max_batch=args.max_batch)
        setup_s = time.time() - t0
        rng = np.random.default_rng(1)
        shape = (args.nx, ny) if mesh is not None else (A.n,)
        reqs = [np.asarray(A @ rng.standard_normal(A.n)).reshape(shape)
                for _ in range(args.requests)]
        t0 = time.time()
        handles = [pool.submit(rb) for rb in reqs]
        pool.flush()
        results = [h.result() for h in handles]
        dt = time.time() - t0
        nconv = sum(1 for r in results if r.converged)
        where = (f"{ndev}-device mesh {dict(mesh.shape)}" if mesh
                 else "1 device")
        print(f"served {args.requests} requests ({args.method}, l={solver.l}, "
              f"prec={args.prec}) on {args.nx}x{ny} over {where}: "
              f"setup {setup_s:.2f}s, drain {dt:.2f}s "
              f"({args.requests / max(dt, 1e-9):.1f} req/s), "
              f"{nconv}/{args.requests} converged")
        if solver.auto is not None:
            _print_auto(solver.auto.as_info())
        print(f"  batches={pool.stats['batches']} "
              f"occupancy={pool.occupancy:.3f} "
              f"lanes={pool.stats['lanes_real']}/"
              f"{pool.stats['lanes_padded']} "
              f"prepared_sweeps={solver.prepared_sweeps}")
        worst = max(range(len(results)),
                    key=lambda j: np.linalg.norm(
                        reqs[j].reshape(-1)
                        - np.asarray(A @ np.asarray(
                            results[j].x).reshape(-1))))
        res = np.linalg.norm(reqs[worst].reshape(-1) - np.asarray(
            A @ np.asarray(results[worst].x).reshape(-1)))
        print(f"  worst |b-Ax| = {res:.3e} (request {worst}, "
              f"{results[worst].iters} iters)")
        return results

    t0 = time.time()
    # with a preconditioner the engine derives the shift interval from
    # M.precond_spectrum; the hand-picked (0, 8) sigma is only for M=None
    r = solve(A, B, method=args.method, l=l, tol=args.tol,
              maxiter=args.iters, sigma=None if M is not None else sigma,
              M=M, backend=args.backend, mesh=mesh, comm=comm, **stab_kw)
    dt = time.time() - t0
    x = np.asarray(r.x).reshape(args.nrhs, -1) if args.nrhs > 1 \
        else np.asarray(r.x).reshape(-1)
    res = np.linalg.norm(b_flat - A @ (x[0] if args.nrhs > 1 else x))
    where = f"{ndev}-device mesh {dict(mesh.shape)}" if mesh else "1 device"
    print(f"{args.method} (l={r.info.get('l', l)}, nrhs={args.nrhs}, "
          f"prec={args.prec}, comm={r.info.get('comm', 'n/a')}) "
          f"on {args.nx}x{ny} over {where}: "
          f"{r.iters} iters, {dt:.2f}s, |b-Ax| = {res:.3e}, "
          f"converged={r.converged}")
    if "auto" in r.info:
        _print_auto(r.info["auto"])
    if args.nrhs > 1 and "per_rhs_iters" in r.info:
        # a batched lane that hits square-root breakdown re-seeds itself
        # in-scan when restart= is enabled (per-lane counters below);
        # with restart=None it freezes with breakdown=True -- either way
        # make the per-lane outcome visible instead of just reporting
        # converged=False for the whole batch
        print("  per-lane iters:",
              [int(k) for k in r.info["per_rhs_iters"]],
              "converged:",
              [bool(c) for c in r.info["per_rhs_converged"]],
              "breakdown:",
              [bool(c) for c in r.info.get("per_rhs_breakdown", [])],
              "restarts:",
              [int(c) for c in r.info.get("per_rhs_restarts", [])],
              "replacements:",
              [int(c) for c in r.info.get("per_rhs_replacements", [])])
    elif r.restarts or r.replacements:
        print(f"  in-scan recovery: {r.restarts} restart(s), "
              f"{r.replacements} residual replacement(s)")
    if M is not None and args.nrhs == 1:
        from repro.core import residual_gap
        gap = residual_gap(A, b_flat, r)
        print(f"residual gap (attainable accuracy): true={gap['true_resnorm']:.3e} "
              f"implicit={gap['implicit_resnorm']:.3e} rel_gap={gap['rel_gap']:.1e}")
    return x


if __name__ == "__main__":
    main()
