"""Pallas TPU megakernel: the fused p(l)-CG iteration body.

One launch per iteration computes everything in the scan body that touches
an n-vector (paper arXiv:1801.04728 Alg. 3):

* **(K1)** the 5-point stencil SPMV ``t_hat = A z_i`` -- fused in-kernel
  when the operator is the paper's 2-D Poisson stencil (``stencil_hw``
  given); otherwise ``t`` (and ``t_hat``) stream in as inputs.  A
  *diagonal* preconditioner apply ``t = M^{-1} t_hat`` (the ``inv_diag``
  hint of ``repro.core.precond.Jacobi``) also runs in-kernel -- a scalar
  inverse diagonal rides the packed scalar operand, a vector one streams
  as an ``(n, 1)`` operand -- so preconditioned p(l)-CG keeps ONE launch
  per steady-state iteration;
* **(K4)** the sliding-window AXPY recurrences: the new basis vector
  ``v_c = (z_{c-l} - sum_k g_k v_{c-2l+k}) / g_cc``, the new auxiliary
  vector ``z_{i+1} = (t - gamma z_i - delta z_{i-1}) / delta'`` (and the
  ``zhat`` recurrence when preconditioned), including the warmup-phase
  variant ``z_{i+1} = t - sigma_i z_i`` selected in-kernel on the
  ``steady`` flag;
* **(K5)** the 2l+1 dot products of the next reduction payload, computed
  against the *updated* windows while they are still resident in VMEM.

Windows are **lane-major** ``(n, window)``: the 2l+1-entry band of one
grid point is contiguous, each basis vector is read from HBM exactly once
per iteration, and under ``vmap`` (the batched multi-RHS engine) the
batching rule appends a grid dimension so a ``(B, n, window)`` batch is
still ONE launch.  Per iteration the kernel replaces one launch each for
the SPMV, the v-AXPY, and two multi-dots (plus their intermediate HBM
round-trips) with a single pass: traffic drops from ~(10l+9)n to (6l+7)n
words and launch count from 4+ to 1.

Scalar recurrences (K2/K3/K6) stay in jnp: they are O(l^2) latency-bound
work that would only force the kernel shape dynamic.

All math runs in ``promote_types(dtype, float32)`` -- f64 solver paths
(x64, interpret mode) keep full precision so ``backend="fused"`` is
bit-comparable to the inline jnp body.

Grid: 1-D over row-blocks of n (over grid rows of the (H, W) domain when
the stencil is fused, so vertical stencil neighbors come from the
prev/next block trick of ``stencil2d``).  The dot payload accumulates
across grid steps into a revisited output block -- the canonical Pallas
reduction pattern.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: scal layout: [steady, s_warm, gam, dlt, dsub, gcc, invd, g_0 .. g_{2l-1}]
#: (invd is the scalar inverse diagonal for diag="scalar", else unused)
N_FIXED_SCALARS = 7


def _make_kernel(l: int, has_zh: bool, has_stencil: bool, diag: str,
                 nblocks: int, acc):
    m = 2 * l + 1
    has_diag = diag != "none"

    def kernel(*refs):
        it = iter(refs)
        scal_ref = next(it)
        v_ref = next(it)
        z_ref = next(it)
        zh_ref = next(it) if has_zh else None
        invd_ref = next(it) if diag == "vector" else None
        if has_stencil:
            zp_ref, zc_ref, zn_ref = next(it), next(it), next(it)
        elif has_diag:
            th_ref = next(it)                   # t computed in-kernel
        else:
            t_ref = next(it)
            th_ref = next(it) if has_zh else None
        vo_ref = next(it)
        zo_ref = next(it)
        zho_ref = next(it) if has_zh else None
        d_ref = next(it)

        i = pl.program_id(0)
        scal = scal_ref[...].astype(acc)            # (1, 7 + 2l)
        steady = scal[0, 0] > 0.5
        s_warm, gam, dlt = scal[0, 1], scal[0, 2], scal[0, 3]
        dsub, gcc = scal[0, 4], scal[0, 5]
        g = scal[:, N_FIXED_SCALARS:]               # (1, 2l)

        V = v_ref[...].astype(acc)                  # (bs, 2l+1)
        Z = z_ref[...].astype(acc)                  # (bs, l+1)

        # ---- (K1) SPMV: in-kernel 5-point stencil or streamed t --------
        if has_stencil:
            xc = zc_ref[...].astype(acc)            # (bh, W2d)
            top = jnp.where(i == 0, jnp.zeros_like(xc[-1:, :]),
                            zp_ref[-1:, :].astype(acc))
            bot = jnp.where(i == nblocks - 1, jnp.zeros_like(xc[:1, :]),
                            zn_ref[:1, :].astype(acc))
            up = jnp.concatenate([top, xc[:-1]], axis=0)
            down = jnp.concatenate([xc[1:], bot], axis=0)
            zc_col = jnp.zeros_like(xc[:, :1])      # Dirichlet halos
            left = jnp.concatenate([zc_col, xc[:, :-1]], axis=1)
            right = jnp.concatenate([xc[:, 1:], zc_col], axis=1)
            traw = (4.0 * xc - up - down - left - right).reshape(-1, 1)
            # the SPMV stream is storage-dtype under the precision
            # policy: round the in-kernel result exactly like the
            # streamed-t tiers store it (identity when storage is the
            # accumulation dtype)
            traw = traw.astype(zo_ref.dtype).astype(acc)
        elif has_diag:
            traw = th_ref[...].astype(acc)          # (bs, 1)
        if has_diag:
            # in-kernel diagonal preconditioner: t = M^{-1} t_hat
            # (the preconditioned stream is storage-dtype too)
            th = traw
            iv = (scal[0, 6] if diag == "scalar"
                  else invd_ref[...].astype(acc))
            t = (iv * traw).astype(zo_ref.dtype).astype(acc)
        elif has_stencil:
            t = th = traw
        else:
            t = t_ref[...].astype(acc)              # (bs, 1)
            th = th_ref[...].astype(acc) if has_zh else t

        # ---- (K4) v recurrence (steady only; warmup keeps the window) --
        vnew = (Z[:, l - 1:l]
                - (V[:, :2 * l] * g).sum(axis=1, keepdims=True)) / gcc
        V2 = jnp.where(steady, jnp.concatenate([vnew, V[:, :-1]], axis=1),
                       V)
        # ---- (K4) z recurrence with in-kernel warmup select ------------
        znew = jnp.where(steady,
                         (t - gam * Z[:, :1] - dsub * Z[:, 1:2]) / dlt,
                         t - s_warm * Z[:, :1])
        Z2 = jnp.concatenate([znew, Z[:, :-1]], axis=1)
        lhs = znew
        if has_zh:
            Zh = zh_ref[...].astype(acc)            # (bs, 3)
            zhnew = jnp.where(
                steady, (th - gam * Zh[:, :1] - dsub * Zh[:, 1:2]) / dlt,
                th - s_warm * Zh[:, :1])
            zho_ref[...] = jnp.concatenate(
                [zhnew, Zh[:, :-1]], axis=1).astype(zho_ref.dtype)
            lhs = zhnew
        vo_ref[...] = V2.astype(vo_ref.dtype)
        zo_ref[...] = Z2.astype(zo_ref.dtype)

        # ---- (K5) payload dots against the updated windows -------------
        # dot the windows AS STORED: under a low-precision storage dtype
        # the Gram payload must describe the basis later iterations read
        # back (and match the per-kernel tier, which dots the rounded
        # windows); identity casts when storage == accumulation dtype
        V2s = V2.astype(vo_ref.dtype).astype(acc)
        Z2s = Z2.astype(zo_ref.dtype).astype(acc)
        vd = (V2s[:, :l + 1] * lhs).sum(axis=0)     # (l+1,)
        zd = (Z2s[:, :l] * lhs).sum(axis=0)         # (l,)

        @pl.when(i == 0)
        def _init():
            d_ref[...] = jnp.zeros_like(d_ref)

        d_ref[...] += jnp.concatenate([vd, zd]).reshape(1, m)

    return kernel


@functools.partial(jax.jit,
                   static_argnames=("l", "stencil_hw", "diag", "bn",
                                    "interpret"))
def fused_body(Vw, Zw, scal, Zhw=None, t=None, t_hat=None, invd=None, *,
               l: int, stencil_hw=None, diag: str = "none", bn: int = 2048,
               interpret: bool | None = None):
    """One fused p(l)-CG body step on lane-major windows.

    Args:
      Vw: (n, 2l+1) basis window, slot 0 newest.
      Zw: (n, l+1) auxiliary window, slot 0 newest.
      scal: (1, 7+2l) packed scalars
        ``[steady, s_warm, gam, dlt, dsub, gcc, invd, g...]`` (the invd
        slot carries the scalar inverse diagonal for ``diag="scalar"``).
      Zhw: (n, 3) zhat window (preconditioned runs) or None.
      t: (n,) preconditioned SPMV result; None computes it in-kernel
        (from the fused 5-point stencil and/or the diagonal apply).
      t_hat: (n,) unpreconditioned SPMV result (required with ``Zhw``
        unless the stencil is fused in-kernel).
      invd: (n, 1) inverse diagonal operand for ``diag="vector"``.
      stencil_hw: (H, W) 2-D grid shape of the Poisson domain; set =>
        the (K1) SPMV runs in-kernel.
      diag: "none" | "scalar" | "vector" -- in-kernel diagonal
        preconditioner mode (requires ``Zhw``).
      bn: row-block size (rounded down to divide n; with the stencil
        fused, blocks are whole grid rows, ``bn // W`` of them).

    Returns:
      (Vw2, Zw2, Zhw2 | None, dots) with ``dots`` the (2l+1,) payload
      ``[vd_0..vd_l, zd_0..zd_{l-1}]`` in the accumulation dtype.
    """
    n, m = Vw.shape
    if m != 2 * l + 1:
        raise ValueError(f"Vw must be (n, 2l+1), got {Vw.shape} for l={l}")
    has_zh = Zhw is not None
    has_stencil = stencil_hw is not None
    has_diag = diag != "none"
    if has_diag and not has_zh:
        raise ValueError("in-kernel diag preconditioner needs the Zhw "
                         "window")
    if has_stencil and has_zh and not has_diag:
        raise ValueError("in-kernel SPMV with a preconditioner requires "
                         "the diag mode (general prec => stream t/t_hat)")
    if has_stencil or has_diag:
        if t is not None:
            raise ValueError("t is computed in-kernel with the stencil/"
                             "diag fused; pass t=None")
    elif t is None:
        raise ValueError("with nothing fused in-kernel (no stencil_hw, "
                         "diag='none') the streamed t operand is required")
    if has_diag and not has_stencil and t_hat is None:
        raise ValueError("the in-kernel diag apply needs the streamed "
                         "t_hat operand when the stencil is not fused")
    if has_stencil:
        H, W2d = stencil_hw
        if H * W2d != n:
            raise ValueError(f"stencil_hw {stencil_hw} != n={n}")
        bh = max(min(bn // W2d, H), 1)
        while H % bh:
            bh -= 1
        nblocks, bs = H // bh, bh * W2d
    else:
        bs = min(bn, n)
        while n % bs:
            bs //= 2
        nblocks = n // bs
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    acc = jnp.promote_types(Vw.dtype, jnp.float32)
    ns = scal.shape[-1]

    row = lambda i: (i, 0)          # noqa: E731
    fix = lambda i: (0, 0)          # noqa: E731
    in_specs = [pl.BlockSpec((1, ns), fix),
                pl.BlockSpec((bs, m), row),
                pl.BlockSpec((bs, l + 1), row)]
    operands = [scal, Vw, Zw]
    if has_zh:
        in_specs.append(pl.BlockSpec((bs, 3), row))
        operands.append(Zhw)
    if diag == "vector":
        in_specs.append(pl.BlockSpec((bs, 1), row))
        operands.append(invd.reshape(n, 1))
    if has_stencil:
        z2d = Zw[:, 0].reshape(H, W2d)
        in_specs += [
            pl.BlockSpec((bh, W2d), lambda i: (jnp.maximum(i - 1, 0), 0)),
            pl.BlockSpec((bh, W2d), row),
            pl.BlockSpec((bh, W2d),
                         lambda i: (jnp.minimum(i + 1, nblocks - 1), 0)),
        ]
        operands += [z2d, z2d, z2d]
    elif has_diag:
        in_specs.append(pl.BlockSpec((bs, 1), row))
        operands.append(t_hat.reshape(n, 1))
    else:
        in_specs.append(pl.BlockSpec((bs, 1), row))
        operands.append(t.reshape(n, 1))
        if has_zh:
            in_specs.append(pl.BlockSpec((bs, 1), row))
            operands.append(t_hat.reshape(n, 1))

    out_specs = [pl.BlockSpec((bs, m), row),
                 pl.BlockSpec((bs, l + 1), row)]
    out_shape = [jax.ShapeDtypeStruct((n, m), Vw.dtype),
                 jax.ShapeDtypeStruct((n, l + 1), Zw.dtype)]
    if has_zh:
        out_specs.append(pl.BlockSpec((bs, 3), row))
        out_shape.append(jax.ShapeDtypeStruct((n, 3), Zhw.dtype))
    out_specs.append(pl.BlockSpec((1, m), fix))
    out_shape.append(jax.ShapeDtypeStruct((1, m), acc))

    outs = pl.pallas_call(
        _make_kernel(l, has_zh, has_stencil, diag, nblocks, acc),
        grid=(nblocks,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)
    Vw2, Zw2 = outs[0], outs[1]
    Zhw2 = outs[2] if has_zh else None
    return Vw2, Zw2, Zhw2, outs[-1][0]
