"""Jitted public entry points for the Pallas kernels.

On CPU (this container) the kernels run with ``interpret=True`` -- the
kernel bodies execute exactly, validating the TPU code path; on TPU they
compile to Mosaic.  ``use_pallas=False`` falls back to the jnp oracles
(used by default inside the distributed solver on CPU where interpret-mode
dispatch overhead would dominate).
"""
from __future__ import annotations

import jax

from . import ref
from .multidot import multidot
from .stencil2d import stencil2d
from .window_axpy import window_axpy


def stencil2d_apply(x, halo_n, halo_s, halo_w, halo_e, *, use_pallas=None):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return stencil2d(x, halo_n, halo_s, halo_w, halo_e)
    return ref.stencil2d_ref(x, halo_n, halo_s, halo_w, halo_e)


def multidot_apply(W, z, *, use_pallas=None):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return multidot(W, z)
    return ref.multidot_ref(W, z)


def window_axpy_apply(V, z, g, gcc, *, use_pallas=None):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return window_axpy(V, z, g, gcc)
    return ref.window_axpy_ref(V, z, g, gcc)
