"""Jitted public entry points for the Pallas kernels.

On CPU (this container) the kernels run with ``interpret=True`` -- the
kernel bodies execute exactly, validating the TPU code path; on TPU they
compile to Mosaic.  ``use_pallas=False`` falls back to the jnp oracles
(used by default inside the distributed solver on CPU where interpret-mode
dispatch overhead would dominate).

Window arguments are lane-major ``(n, window)`` throughout (see
``fused_body`` for the layout rationale).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .fused_body import N_FIXED_SCALARS, fused_body
from .multidot import multidot
from .stencil2d import stencil2d, stencil2d_batched
from .window_axpy import window_axpy


def _bcast_unbatched(axis_size, in_batched, args):
    """custom_vmap helper: lift unbatched operands to the lane axis."""
    return tuple(
        a if b else jnp.broadcast_to(a, (axis_size,) + jnp.shape(a))
        for a, b in zip(args, in_batched))


# The halo stencil carries an explicit lane-batched variant: under
# ``jax.vmap`` (the mesh engine's multi-RHS path, vmap INSIDE shard_map)
# the SPMV over all RHS lanes must stay ONE launch streaming (B, bh, W)
# bricks, rather than relying on the generic pallas batching rule.  The
# custom_vmap wrappers below install ``stencil2d_batched`` (and its jnp
# oracle) as that rule.

@jax.custom_batching.custom_vmap
def _stencil2d_pallas(x, hn, hs, hw, he):
    return stencil2d(x, hn, hs, hw, he)


@_stencil2d_pallas.def_vmap
def _stencil2d_pallas_vmap(axis_size, in_batched, x, hn, hs, hw, he):
    args = _bcast_unbatched(axis_size, in_batched, (x, hn, hs, hw, he))
    return stencil2d_batched(*args), True


@jax.custom_batching.custom_vmap
def _stencil2d_ref(x, hn, hs, hw, he):
    return ref.stencil2d_ref(x, hn, hs, hw, he)


@_stencil2d_ref.def_vmap
def _stencil2d_ref_vmap(axis_size, in_batched, x, hn, hs, hw, he):
    args = _bcast_unbatched(axis_size, in_batched, (x, hn, hs, hw, he))
    return ref.stencil2d_batched_ref(*args), True


def stencil2d_apply(x, halo_n, halo_s, halo_w, halo_e, *, use_pallas=None):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return _stencil2d_pallas(x, halo_n, halo_s, halo_w, halo_e)
    return _stencil2d_ref(x, halo_n, halo_s, halo_w, halo_e)


def multidot_apply(W, z, *, use_pallas=None):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return multidot(W, z)
    return ref.multidot_ref(W, z)


def window_axpy_apply(V, z, g, gcc, *, use_pallas=None):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return window_axpy(V, z, g, gcc)
    return ref.window_axpy_ref(V, z, g, gcc)


def fused_body_apply(Vw, Zw, Zhw, t, t_hat, *, l, steady, s_warm, gam, dlt,
                     dsub, gcc, g, invd=None, stencil_hw=None,
                     use_pallas=None):
    """Dispatch one fused p(l)-CG body step (see ``fused_body``).

    Scalars (``steady`` .. ``gcc``, the scalar inverse diagonal when
    ``invd`` is 0-d, plus the 2l band coefficients ``g``) are packed into
    one (1, 7+2l) operand so the kernel signature stays static across
    iterations.  ``invd`` (scalar or ``(n,)``) folds a diagonal
    preconditioner apply into the kernel; a general preconditioner
    instead streams its externally computed ``t``.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return ref.fused_body_ref(Vw, Zw, Zhw, t, t_hat, l=l, steady=steady,
                                  s_warm=s_warm, gam=gam, dlt=dlt, dsub=dsub,
                                  gcc=gcc, g=g, invd=invd,
                                  stencil_hw=stencil_hw)
    acc = jnp.promote_types(Vw.dtype, jnp.float32)
    invd = None if invd is None else jnp.asarray(invd)
    diag = ("none" if invd is None
            else ("scalar" if invd.ndim == 0 else "vector"))
    invd_s = invd if diag == "scalar" else jnp.zeros((), acc)
    scal = jnp.concatenate([
        jnp.stack([jnp.where(steady, 1.0, 0.0).astype(acc),
                   s_warm.astype(acc), gam.astype(acc), dlt.astype(acc),
                   dsub.astype(acc), gcc.astype(acc), invd_s.astype(acc)]),
        g.astype(acc),
    ]).reshape(1, N_FIXED_SCALARS + 2 * l)
    return fused_body(Vw, Zw, scal, Zhw, t, t_hat,
                      invd if diag == "vector" else None, l=l,
                      stencil_hw=stencil_hw, diag=diag)
