"""Pallas TPU kernel: fused multi-dot -- the (K5) payload of p(l)-CG.

Computes the 2l+1 dot products of one iteration, ``out[k] = <Wrow_k, z>``,
in a single pass over ``z``: the window matrix W (the stacked sliding-window
basis vectors) streams through VMEM chunk-by-chunk together with exactly one
copy of z.  A naive implementation reads z once *per dot*; fusing cuts HBM
traffic from 2(2l+1)n to (2l+2)n words -- the memory-bound win reported in
EXPERIMENTS.md SPerf (beyond-paper optimization: the paper fuses the
*reduction*, we additionally fuse the local reads).

Accumulation across grid steps revisits the same output block (sequential
TPU grid), the canonical Pallas reduction pattern.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(w_ref, z_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = w_ref[...].astype(jnp.float32)            # (m, bn)
    z = z_ref[...].astype(jnp.float32)            # (1, bn)
    o_ref[...] += (w * z).sum(axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def multidot(W, z, *, bn: int = 2048, interpret: bool | None = None):
    """out (m,) = W (m, n) @ z (n,) in one fused pass (f32 accumulation)."""
    m, n = W.shape
    bn = min(bn, n)
    while n % bn:
        bn //= 2
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    out = pl.pallas_call(
        _kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((m, bn), lambda i: (0, i)),
            pl.BlockSpec((1, bn), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((m, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        interpret=interpret,
    )(W, z.reshape(1, n))
    return out[:, 0]
