"""Pallas TPU kernel: fused multi-dot -- the (K5) payload of p(l)-CG.

Computes the 2l+1 dot products of one iteration, ``out[k] = <W[:, k], z>``,
in a single pass over ``z``: the window matrix W (the sliding-window basis
vectors stacked **lane-major**, shape ``(n, m)`` so the m-entry band of one
grid point is contiguous) streams through VMEM chunk-by-chunk together with
exactly one copy of z.  A naive implementation reads z once *per dot*;
fusing cuts HBM traffic from 2(2l+1)n to (2l+2)n words -- the memory-bound
win reported in EXPERIMENTS.md SPerf (beyond-paper optimization: the paper
fuses the *reduction*, we additionally fuse the local reads).

Accumulation dtype is ``promote_types(dtype, float32)``: bf16/f32 inputs
accumulate in f32 like the TPU MXU, f64 inputs (x64 solver paths, interpret
mode) keep full f64 so the kernel tiers stay bit-comparable to the inline
jnp math.

Accumulation across grid steps revisits the same output block (sequential
TPU grid), the canonical Pallas reduction pattern.  Under ``vmap`` (the
batched multi-RHS engine) the batching rule appends one grid dimension, so
a ``(B, n, m)`` window still lowers to ONE kernel launch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(acc, w_ref, z_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = w_ref[...].astype(acc)                    # (bn, m)
    z = z_ref[...].astype(acc)                    # (bn, 1)
    o_ref[...] += (w * z).sum(axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def multidot(W, z, *, bn: int = 2048, interpret: bool | None = None):
    """out (m,) = W.T (m, n) @ z (n,) for lane-major W (n, m), one fused
    pass, ``promote_types(dtype, f32)`` accumulation."""
    n, m = W.shape
    bn = min(bn, n)
    while n % bn:
        bn //= 2
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    acc = jnp.promote_types(W.dtype, jnp.float32)
    out = pl.pallas_call(
        functools.partial(_kernel, acc),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, m), lambda i: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, m), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, m), acc),
        interpret=interpret,
    )(W, z.reshape(n, 1))
    return out[0]
