"""Pallas TPU kernel: fused sliding-window AXPY -- the (K4) v-recurrence.

Computes  v_new = (z - sum_k g[k] * V[k]) / gcc  (paper Alg. 2 line 17) in a
single pass: every chunk of the 2l window vectors is read once and combined
in VMEM, instead of 2l separate AXPY sweeps (2l reads + 2l-1 writes of the
accumulator).  HBM traffic drops from ~(4l+1)n to (2l+2)n words.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(v_ref, z_ref, g_ref, o_ref):
    V = v_ref[...].astype(jnp.float32)            # (m, bn)
    z = z_ref[...].astype(jnp.float32)            # (1, bn)
    g = g_ref[...].astype(jnp.float32)            # (m+1, 1); g[m] = gcc
    acc = z - (V * g[:-1]).sum(axis=0, keepdims=True)
    o_ref[...] = (acc / g[-1:]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def window_axpy(V, z, g, gcc, *, bn: int = 2048,
                interpret: bool | None = None):
    """v_new (n,) = (z - g @ V) / gcc ; V (m, n), g (m,)."""
    m, n = V.shape
    bn = min(bn, n)
    while n % bn:
        bn //= 2
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    gfull = jnp.concatenate([g.astype(jnp.float32),
                             jnp.asarray([gcc], jnp.float32)]).reshape(m + 1, 1)
    out = pl.pallas_call(
        _kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((m, bn), lambda i: (0, i)),
            pl.BlockSpec((1, bn), lambda i: (0, i)),
            pl.BlockSpec((m + 1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), V.dtype),
        interpret=interpret,
    )(V, z.reshape(1, n), gfull)
    return out[0]
