"""Pallas TPU kernel: fused sliding-window AXPY -- the (K4) v-recurrence.

Computes  v_new = (z - sum_k g[k] * V[:, k]) / gcc  (paper Alg. 2 line 17)
in a single pass over the **lane-major** window ``V (n, m)`` (the m-entry
band of one grid point is contiguous): every chunk of the m window vectors
is read once and combined in VMEM, instead of m separate AXPY sweeps
(m reads + m-1 writes of the accumulator).  HBM traffic drops from
~(2m+1)n to (m+2)n words.

Accumulation dtype is ``promote_types(dtype, float32)`` (f64 in, f64
accumulated) so the kernel tier stays bit-comparable to the inline jnp
math on the x64 solver paths.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(acc, v_ref, z_ref, g_ref, o_ref):
    V = v_ref[...].astype(acc)                    # (bn, m)
    z = z_ref[...].astype(acc)                    # (bn, 1)
    g = g_ref[...].astype(acc)                    # (1, m+1); g[0, m] = gcc
    out = z - (V * g[:, :-1]).sum(axis=1, keepdims=True)
    o_ref[...] = (out / g[:, -1:]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def window_axpy(V, z, g, gcc, *, bn: int = 2048,
                interpret: bool | None = None):
    """v_new (n,) = (z - V @ g) / gcc ; lane-major V (n, m), g (m,)."""
    n, m = V.shape
    bn = min(bn, n)
    while n % bn:
        bn //= 2
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    acc = jnp.promote_types(V.dtype, jnp.float32)
    gfull = jnp.concatenate([g.astype(acc),
                             jnp.asarray([gcc], acc)]).reshape(1, m + 1)
    out = pl.pallas_call(
        functools.partial(_kernel, acc),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, m), lambda i: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, m + 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), V.dtype),
        interpret=interpret,
    )(V, z.reshape(n, 1), gfull)
    return out[:, 0]
