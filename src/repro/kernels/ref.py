"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

Window arguments are **lane-major** ``(n, window)`` to match the kernel
layout (the band of one grid point is contiguous); accumulation is
``promote_types(dtype, float32)`` exactly like the kernels (bf16/f32
accumulate in f32, f64 stays f64 on the x64 solver paths).
"""
from __future__ import annotations

import jax.numpy as jnp


def stencil2d_ref(x, halo_n, halo_s, halo_w, halo_e):
    acc = jnp.promote_types(x.dtype, jnp.float32)
    xa = x.astype(acc)
    up = jnp.concatenate([halo_n.reshape(1, -1).astype(acc), xa[:-1]], 0)
    down = jnp.concatenate([xa[1:], halo_s.reshape(1, -1).astype(acc)], 0)
    left = jnp.concatenate([halo_w.reshape(-1, 1).astype(acc), xa[:, :-1]], 1)
    right = jnp.concatenate([xa[:, 1:], halo_e.reshape(-1, 1).astype(acc)], 1)
    return (4.0 * xa - up - down - left - right).astype(x.dtype)


def stencil2d_batched_ref(x, halo_n, halo_s, halo_w, halo_e):
    """Batched (B, H, W) oracle of ``stencil2d_batched`` (lane-leading)."""
    acc = jnp.promote_types(x.dtype, jnp.float32)
    xa = x.astype(acc)
    hn = halo_n[:, None, :].astype(acc)
    hs = halo_s[:, None, :].astype(acc)
    hw = halo_w[:, :, None].astype(acc)
    he = halo_e[:, :, None].astype(acc)
    up = jnp.concatenate([hn, xa[:, :-1, :]], axis=1)
    down = jnp.concatenate([xa[:, 1:, :], hs], axis=1)
    left = jnp.concatenate([hw, xa[:, :, :-1]], axis=2)
    right = jnp.concatenate([xa[:, :, 1:], he], axis=2)
    return (4.0 * xa - up - down - left - right).astype(x.dtype)


def multidot_ref(W, z):
    """out (m,) = W.T @ z for lane-major W (n, m)."""
    acc = jnp.promote_types(W.dtype, jnp.float32)
    return (W.astype(acc) * z.astype(acc)[:, None]).sum(axis=0)


def window_axpy_ref(V, z, g, gcc):
    """v_new (n,) = (z - V @ g) / gcc for lane-major V (n, m)."""
    acc_t = jnp.promote_types(V.dtype, jnp.float32)
    out = z.astype(acc_t) - (V.astype(acc_t)
                             * g.astype(acc_t)[None, :]).sum(axis=1)
    return (out / gcc).astype(V.dtype)


def fused_body_ref(Vw, Zw, Zhw, t, t_hat, *, l, steady, s_warm, gam, dlt,
                   dsub, gcc, g, invd=None, stencil_hw=None):
    """jnp oracle of the fused p(l)-CG body megakernel.

    Same contract as ``fused_body`` (lane-major windows, in-body warmup
    select, payload dots against the updated windows); with
    ``stencil_hw`` the 5-point Dirichlet stencil is applied to
    ``Zw[:, 0]`` in place of a streamed ``t_hat``, and ``invd`` (scalar
    or ``(n,)``) applies the in-body diagonal preconditioner
    ``t = invd * t_hat``.  Returns (Vw2, Zw2, Zhw2 | None, dots).
    """
    acc = jnp.promote_types(Vw.dtype, jnp.float32)
    V = Vw.astype(acc)
    Z = Zw.astype(acc)
    if t is None and stencil_hw is not None:
        H, W2d = stencil_hw
        x = Z[:, 0].reshape(H, W2d)
        zr = jnp.zeros_like
        # the SPMV stream is storage-dtype (see fused_body)
        t_hat = stencil2d_ref(x, zr(x[0]), zr(x[0]), zr(x[:, 0]),
                              zr(x[:, 0])).reshape(-1).astype(
                                  Zw.dtype).astype(acc)
        t = t_hat
    if invd is not None:
        iv = jnp.asarray(invd, acc)
        t = ((iv if iv.ndim == 0 else iv.reshape(-1))
             * t_hat.astype(acc)).astype(Zw.dtype).astype(acc)
    t = t.astype(acc)[:, None]
    vnew = (Z[:, l - 1:l]
            - (V[:, :2 * l] * g.astype(acc)[None, :]).sum(
                axis=1, keepdims=True)) / gcc
    V2 = jnp.where(steady, jnp.concatenate([vnew, V[:, :-1]], axis=1), V)
    znew = jnp.where(steady, (t - gam * Z[:, :1] - dsub * Z[:, 1:2]) / dlt,
                     t - s_warm * Z[:, :1])
    Z2 = jnp.concatenate([znew, Z[:, :-1]], axis=1)
    lhs = znew
    Zh2 = None
    if Zhw is not None:
        Zh = Zhw.astype(acc)
        th = t_hat.astype(acc)[:, None]
        zhnew = jnp.where(
            steady, (th - gam * Zh[:, :1] - dsub * Zh[:, 1:2]) / dlt,
            th - s_warm * Zh[:, :1])
        Zh2 = jnp.concatenate([zhnew, Zh[:, :-1]],
                              axis=1).astype(Zhw.dtype)
        lhs = zhnew
    # dots consume the windows AS STORED (see fused_body: the payload
    # must describe the basis later iterations read back)
    vd = ((V2.astype(Vw.dtype).astype(acc))[:, :l + 1] * lhs).sum(axis=0)
    zd = ((Z2.astype(Zw.dtype).astype(acc))[:, :l] * lhs).sum(axis=0)
    return (V2.astype(Vw.dtype), Z2.astype(Zw.dtype), Zh2,
            jnp.concatenate([vd, zd]))
