"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax.numpy as jnp


def stencil2d_ref(x, halo_n, halo_s, halo_w, halo_e):
    up = jnp.concatenate([halo_n.reshape(1, -1).astype(x.dtype), x[:-1]], 0)
    down = jnp.concatenate([x[1:], halo_s.reshape(1, -1).astype(x.dtype)], 0)
    left = jnp.concatenate([halo_w.reshape(-1, 1).astype(x.dtype), x[:, :-1]], 1)
    right = jnp.concatenate([x[:, 1:], halo_e.reshape(-1, 1).astype(x.dtype)], 1)
    return 4.0 * x - up - down - left - right


def multidot_ref(W, z):
    return (W.astype(jnp.float32) @ z.astype(jnp.float32))


def window_axpy_ref(V, z, g, gcc):
    acc = z.astype(jnp.float32) - g.astype(jnp.float32) @ V.astype(jnp.float32)
    return (acc / gcc).astype(V.dtype)
