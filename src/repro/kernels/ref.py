"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax.numpy as jnp


def stencil2d_ref(x, halo_n, halo_s, halo_w, halo_e):
    up = jnp.concatenate([halo_n.reshape(1, -1).astype(x.dtype), x[:-1]], 0)
    down = jnp.concatenate([x[1:], halo_s.reshape(1, -1).astype(x.dtype)], 0)
    left = jnp.concatenate([halo_w.reshape(-1, 1).astype(x.dtype), x[:, :-1]], 1)
    right = jnp.concatenate([x[:, 1:], halo_e.reshape(-1, 1).astype(x.dtype)], 1)
    return 4.0 * x - up - down - left - right


def multidot_ref(W, z):
    # accumulate in at-least-f32 (f64 stays f64 so the x64 solver paths keep
    # their full precision; bf16/f32 accumulate in f32 like the TPU kernel)
    acc = jnp.promote_types(W.dtype, jnp.float32)
    return W.astype(acc) @ z.astype(acc)


def window_axpy_ref(V, z, g, gcc):
    acc_t = jnp.promote_types(V.dtype, jnp.float32)
    acc = z.astype(acc_t) - g.astype(acc_t) @ V.astype(acc_t)
    return (acc / gcc).astype(V.dtype)
