"""Pallas TPU kernel: 5-point stencil SPMV on a local 2-D subdomain.

This is kernel (K1) of the p(l)-CG iteration (paper Alg. 3): the local part
of ``y = A x`` for the unscaled Poisson stencil (diag 4, neighbors -1), with
halo rows/columns received from the 4 mesh neighbors (repro.distributed
performs the ``ppermute`` exchange; the kernel is purely local).

TPU mapping: the grid tiles the local block over rows; each step holds a
(bh, W) tile in VMEM plus its row-neighbors, so vertical neighbor access
never leaves VMEM.  W should be a multiple of 128 (lane width); bh a
multiple of 8 (f32 sublanes).

``stencil2d_batched`` is the multi-RHS variant: the B lanes of a
``(B, H, W)`` batch ride the leading block axis (the same lane-leading
layout as the ``(B, n, window)`` batched scan-engine kernels), so the
local SPMV over ALL right-hand sides is ONE ``pallas_call`` whose grid
still only tiles rows -- each grid step streams a ``(B, bh, W)`` brick.
``repro.kernels.ops`` installs it as the ``jax.vmap`` rule of the
single-lane kernel (``custom_vmap``), which is how the mesh engine's
``shard_map(vmap(plcg_scan))`` path lowers its halo SPMV to one launch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(nblocks, xp_ref, xc_ref, xn_ref, hn_ref, hs_ref, hw_ref, he_ref,
            o_ref):
    i = pl.program_id(0)
    acc = jnp.promote_types(xc_ref.dtype, jnp.float32)
    xc = xc_ref[...].astype(acc)
    top_halo = jnp.where(i == 0, hn_ref[...].astype(acc),
                         xp_ref[-1:, :].astype(acc))
    bot_halo = jnp.where(i == nblocks - 1, hs_ref[...].astype(acc),
                         xn_ref[:1, :].astype(acc))
    up = jnp.concatenate([top_halo, xc[:-1]], axis=0)
    down = jnp.concatenate([xc[1:], bot_halo], axis=0)
    left = jnp.concatenate([hw_ref[...].astype(acc), xc[:, :-1]], axis=1)
    right = jnp.concatenate([xc[:, 1:], he_ref[...].astype(acc)], axis=1)
    o_ref[...] = (4.0 * xc - up - down - left - right).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bh", "interpret"))
def stencil2d(x, halo_n, halo_s, halo_w, halo_e, *, bh: int = 256,
              interpret: bool | None = None):
    """y = A_local x with Dirichlet halos.

    x: (H, W) local block; halo_n/halo_s: (W,); halo_w/halo_e: (H,).
    """
    H, W = x.shape
    bh = min(bh, H)
    while H % bh:
        bh //= 2
    nblocks = H // bh
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    dtype = x.dtype
    hn = halo_n.reshape(1, W).astype(dtype)
    hs = halo_s.reshape(1, W).astype(dtype)
    hw = halo_w.reshape(H, 1).astype(dtype)
    he = halo_e.reshape(H, 1).astype(dtype)
    kernel = functools.partial(_kernel, nblocks)
    return pl.pallas_call(
        kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((bh, W), lambda i: (jnp.maximum(i - 1, 0), 0)),
            pl.BlockSpec((bh, W), lambda i: (i, 0)),
            pl.BlockSpec((bh, W), lambda i: (jnp.minimum(i + 1, nblocks - 1), 0)),
            pl.BlockSpec((1, W), lambda i: (0, 0)),
            pl.BlockSpec((1, W), lambda i: (0, 0)),
            pl.BlockSpec((bh, 1), lambda i: (i, 0)),
            pl.BlockSpec((bh, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bh, W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, W), dtype),
        interpret=interpret,
    )(x, x, x, hn, hs, hw, he)


def _kernel_batched(nblocks, xp_ref, xc_ref, xn_ref, hn_ref, hs_ref, hw_ref,
                    he_ref, o_ref):
    i = pl.program_id(0)
    acc = jnp.promote_types(xc_ref.dtype, jnp.float32)
    xc = xc_ref[...].astype(acc)                            # (B, bh, W)
    top_halo = jnp.where(i == 0, hn_ref[...].astype(acc),
                         xp_ref[:, -1:, :].astype(acc))
    bot_halo = jnp.where(i == nblocks - 1, hs_ref[...].astype(acc),
                         xn_ref[:, :1, :].astype(acc))
    up = jnp.concatenate([top_halo, xc[:, :-1, :]], axis=1)
    down = jnp.concatenate([xc[:, 1:, :], bot_halo], axis=1)
    left = jnp.concatenate([hw_ref[...].astype(acc), xc[:, :, :-1]], axis=2)
    right = jnp.concatenate([xc[:, :, 1:], he_ref[...].astype(acc)], axis=2)
    o_ref[...] = (4.0 * xc - up - down - left - right).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bh", "interpret"))
def stencil2d_batched(x, halo_n, halo_s, halo_w, halo_e, *, bh: int = 256,
                      interpret: bool | None = None):
    """y = A_local x for all B lanes in ONE launch.

    x: (B, H, W) lane-leading local batch; halo_n/halo_s: (B, W);
    halo_w/halo_e: (B, H).  Grid and VMEM tiling are identical to the
    single-lane kernel -- lanes only widen each block to (B, bh, W).
    """
    B, H, W = x.shape
    bh = min(bh, H)
    while H % bh:
        bh //= 2
    nblocks = H // bh
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    dtype = x.dtype
    hn = halo_n.reshape(B, 1, W).astype(dtype)
    hs = halo_s.reshape(B, 1, W).astype(dtype)
    hw = halo_w.reshape(B, H, 1).astype(dtype)
    he = halo_e.reshape(B, H, 1).astype(dtype)
    kernel = functools.partial(_kernel_batched, nblocks)
    return pl.pallas_call(
        kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((B, bh, W), lambda i: (0, jnp.maximum(i - 1, 0), 0)),
            pl.BlockSpec((B, bh, W), lambda i: (0, i, 0)),
            pl.BlockSpec((B, bh, W),
                         lambda i: (0, jnp.minimum(i + 1, nblocks - 1), 0)),
            pl.BlockSpec((B, 1, W), lambda i: (0, 0, 0)),
            pl.BlockSpec((B, 1, W), lambda i: (0, 0, 0)),
            pl.BlockSpec((B, bh, 1), lambda i: (0, i, 0)),
            pl.BlockSpec((B, bh, 1), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((B, bh, W), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, W), dtype),
        interpret=interpret,
    )(x, x, x, hn, hs, hw, he)
