"""Jaxpr introspection: count Pallas kernel launches in a traced function.

The fused-iteration acceptance gate is structural, not wall-clock (CPU
interpret-mode timings are not probative of TPU launch overhead): the
``backend="fused"`` scan body must contain exactly ONE ``pallas_call``
equation where the ``backend="pallas"`` tier has one per hot-path kernel.
Counting equations in the traced jaxpr verifies that without running
anything.
"""
from __future__ import annotations

import jax


def count_pallas_calls(fn, *args, **kwargs) -> int:
    """Number of ``pallas_call`` equations anywhere in ``fn``'s jaxpr
    (recursing into scan/cond/jit sub-jaxprs; cond counts every branch)."""
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    return _count(closed.jaxpr, set())


def _count(jaxpr, seen: set) -> int:
    if id(jaxpr) in seen:       # guard against shared sub-jaxprs
        return 0
    seen.add(id(jaxpr))
    total = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            total += 1
        for sub in _sub_jaxprs(eqn.params):
            total += _count(sub, seen)
    return total


def _sub_jaxprs(obj):
    """Yield every Jaxpr reachable from an eqn params value."""
    if isinstance(obj, jax.core.Jaxpr):
        yield obj
    elif isinstance(obj, jax.core.ClosedJaxpr):
        yield obj.jaxpr
    elif isinstance(obj, dict):
        for v in obj.values():
            yield from _sub_jaxprs(v)
    elif isinstance(obj, (list, tuple)):
        for v in obj:
            yield from _sub_jaxprs(v)
