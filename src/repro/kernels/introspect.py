"""Jaxpr introspection: count primitive equations in a traced function.

The structural acceptance gates of this repo are counted, not timed (CPU
interpret-mode timings are not probative of TPU launch overhead or of
collective latency):

* the ``backend="fused"`` scan body must contain exactly ONE
  ``pallas_call`` equation where the ``backend="pallas"`` tier has one
  per hot-path kernel (:func:`count_pallas_calls`);
* the mesh engine's scan body must contain exactly ONE ``psum`` for the
  stacked (nrhs, 2l+1) payload, vs TWO for the classic-CG baseline
  (:func:`count_primitive_in_scan_bodies` with ``"psum"``);
* under ``comm="overlap"`` the body must instead contain exactly one
  ``reduce_scatter`` + one ``all_gather`` and ZERO bare psums -- the
  split reduction is structurally in flight
  (:func:`count_collectives_in_scan_bodies` returns all four collective
  counts at once), and the staging depth is visible as the scattered
  slot block in the scan carry (:func:`scan_carry_shapes`).

Counting equations in the traced jaxpr verifies all of this without
running anything.
"""
from __future__ import annotations

import jax


def jit_cache_size(fn) -> int:
    """Number of XLA compilations a jitted callable holds (-1 if the
    callable exposes no cache, e.g. a plain function).

    The serving-layer acceptance gate counts compilations, not time: a
    prepared ``repro.core.session.Solver`` must show ZERO cache growth
    across repeated same-shape calls after the first (each new RHS shape
    or tol override adds exactly one entry).
    """
    try:
        return int(fn._cache_size())
    except AttributeError:
        return -1


def count_primitive(fn, primitive: str, *args, **kwargs) -> int:
    """Number of ``primitive`` equations anywhere in ``fn``'s jaxpr
    (recursing into scan/cond/jit sub-jaxprs; cond counts every branch)."""
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    return _count(closed.jaxpr, primitive, set())


def count_pallas_calls(fn, *args, **kwargs) -> int:
    """Number of ``pallas_call`` equations anywhere in ``fn``'s jaxpr."""
    return count_primitive(fn, "pallas_call", *args, **kwargs)


def count_primitive_in_scan_bodies(fn, primitive: str, *args,
                                   **kwargs) -> list[int]:
    """Per-``lax.scan``-body counts of ``primitive`` equations.

    One entry per scan equation reachable from ``fn``'s jaxpr, in
    traversal order -- i.e. the per-*iteration* cost of each loop.  For
    the mesh solver sweeps (one scan) this returns ``[psums_per_iter]``.
    """
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    bodies: list = []
    _collect_scan_bodies(closed.jaxpr, bodies, set())
    return [_count(b, primitive, set()) for b in bodies]


#: jaxpr primitive names of the collectives a comm policy can emit (note
#: ``psum_scatter`` traces as ``reduce_scatter``).
COLLECTIVE_PRIMITIVES = ("psum", "reduce_scatter", "all_gather", "ppermute")


def count_collectives_in_scan_bodies(fn, *args, **kwargs) -> list[dict]:
    """Per-scan-body counts of every collective primitive at once.

    One dict per scan equation (same order as
    :func:`count_primitive_in_scan_bodies`), mapping each name in
    :data:`COLLECTIVE_PRIMITIVES` to its per-iteration count -- the
    structural signature of a comm policy: blocking
    ``{"psum": 1, ...}``, overlap ``{"psum": 0, "reduce_scatter": 1,
    "all_gather": 1, ...}``, ring all-zeros except ``ppermute`` (halo
    hops + reduction hops).
    """
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    bodies: list = []
    _collect_scan_bodies(closed.jaxpr, bodies, set())
    return [{p: _count(b, p, set()) for p in COLLECTIVE_PRIMITIVES}
            for b in bodies]


def collective_payload_shapes_in_scan_bodies(fn, *args,
                                             **kwargs) -> list[list[tuple]]:
    """Per-scan-body ``(primitive, operand shape)`` pairs for every
    collective equation -- the payload-width signature of the per-
    iteration reduction.

    The stability path of ``plcg_scan`` (``restart=`` /
    ``rr_period=``) widens the fused scalar payload by exactly one slot
    (the re-seed residual M-norm rides along): a blocking mesh sweep
    shows ``[("psum", (2l+2,))]`` per body instead of ``[("psum",
    (2l+1,))]`` -- still ONE collective, so the per-iteration collective
    *count* signature of every ``comm=`` policy is unchanged.  Under
    batched lanes the lane axis prepends (``(nrhs, 2l+2)``).
    """
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    bodies: list = []
    _collect_scan_bodies(closed.jaxpr, bodies, set())
    out = []
    for b in bodies:
        pairs: list = []
        _collect_collective_shapes(b, pairs, set())
        out.append(pairs)
    return out


def collective_payload_dtypes_in_scan_bodies(fn, *args,
                                             **kwargs) -> list[list[tuple]]:
    """Per-scan-body ``(primitive, operand shape, operand dtype)`` triples
    for every collective equation -- the full payload signature of the
    per-iteration reduction.

    The precision-policy acceptance gate: a ``precision="bf16"`` storage
    policy must change what each shard streams through HBM *locally* and
    NOTHING about the wire -- same collective primitives, same payload
    shapes, and payload dtype equal to the policy's f32/f64 *compute*
    dtype (never bfloat16).  Asserted structurally here, without running
    the mesh program.
    """
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    bodies: list = []
    _collect_scan_bodies(closed.jaxpr, bodies, set())
    out = []
    for b in bodies:
        pairs: list = []
        _collect_collective_shapes(b, pairs, set(), with_dtype=True)
        out.append(pairs)
    return out


def _collect_collective_shapes(jaxpr, out: list, seen: set,
                               with_dtype: bool = False) -> None:
    if id(jaxpr) in seen:
        return
    seen.add(id(jaxpr))
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in COLLECTIVE_PRIMITIVES:
            aval = eqn.invars[0].aval
            out.append((eqn.primitive.name, tuple(aval.shape), aval.dtype)
                       if with_dtype
                       else (eqn.primitive.name, tuple(aval.shape)))
        for sub in _sub_jaxprs(eqn.params):
            _collect_collective_shapes(sub, out, seen, with_dtype)


def scan_carry_shapes(fn, *args, **kwargs) -> list[list[tuple]]:
    """Per-scan carry layouts: one list of ``(shape...)`` tuples per scan
    equation reachable from ``fn``'s jaxpr, in traversal order.

    The in-flight reduction queue lives in the scan carry, so its
    staging depth is readable here without running anything: a blocking
    p(l)-CG sweep carries one ``(l, 2l+1)`` payload block, an overlap
    sweep a ``(d, ceil((2l+1)/nshards))`` scattered-shard block (plus an
    ``(l-d, 2l+1)`` gathered block when ``d < l``), a ring sweep two
    ``(l, 2l+1)`` hop buffers.
    """
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    shapes: list = []
    _collect_scan_carries(closed.jaxpr, shapes, set())
    return shapes


def _collect_scan_carries(jaxpr, out: list, seen: set) -> None:
    if id(jaxpr) in seen:
        return
    seen.add(id(jaxpr))
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "scan":
            nc, ncarry = eqn.params["num_consts"], eqn.params["num_carry"]
            out.append([tuple(v.aval.shape)
                        for v in eqn.invars[nc:nc + ncarry]])
        for sub in _sub_jaxprs(eqn.params):
            _collect_scan_carries(sub, out, seen)


def _count(jaxpr, primitive: str, seen: set) -> int:
    if id(jaxpr) in seen:       # guard against shared sub-jaxprs
        return 0
    seen.add(id(jaxpr))
    total = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == primitive:
            total += 1
        for sub in _sub_jaxprs(eqn.params):
            total += _count(sub, primitive, seen)
    return total


def _collect_scan_bodies(jaxpr, out: list, seen: set) -> None:
    if id(jaxpr) in seen:
        return
    seen.add(id(jaxpr))
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "scan":
            out.append(eqn.params["jaxpr"].jaxpr)
        for sub in _sub_jaxprs(eqn.params):
            _collect_scan_bodies(sub, out, seen)


def _sub_jaxprs(obj):
    """Yield every Jaxpr reachable from an eqn params value."""
    if isinstance(obj, jax.core.Jaxpr):
        yield obj
    elif isinstance(obj, jax.core.ClosedJaxpr):
        yield obj.jaxpr
    elif isinstance(obj, dict):
        for v in obj.values():
            yield from _sub_jaxprs(v)
    elif isinstance(obj, (list, tuple)):
        for v in obj:
            yield from _sub_jaxprs(v)
