"""jax version compatibility shims (0.4.x <-> 0.5+).

The production code targets the modern jax API (``jax.shard_map``,
``jax.sharding.AxisType``); the pinned CI / container toolchain ships a
0.4.x jaxlib where those live under older names.  Every use of the
affected APIs in this repo goes through this module.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5
    from jax.sharding import AxisType as _AxisType
except ImportError:  # jax 0.4.x: no explicit axis types
    _AxisType = None

HAS_AXIS_TYPES = _AxisType is not None


def make_mesh_compat(axis_shapes, axis_names):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if HAS_AXIS_TYPES:
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                             axis_types=(_AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))


def abstract_mesh_compat(axis_shapes, axis_names):
    """Version-portable ``jax.sharding.AbstractMesh`` constructor."""
    from jax.sharding import AbstractMesh
    if HAS_AXIS_TYPES:
        return AbstractMesh(tuple(axis_shapes), tuple(axis_names),
                            axis_types=(_AxisType.Auto,) * len(axis_names))
    return AbstractMesh(tuple(zip(axis_names, axis_shapes)))


def shard_map_compat(f, *, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` (new) / ``jax.experimental.shard_map`` (0.4.x).

    ``check`` maps onto ``check_vma`` on the new API and ``check_rep`` on
    the old one (same semantics: validate replication of outputs).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=check)
